"""Model assembly: decoder-only LMs (dense / MoE / SSM / hybrid), the
whisper-style encoder-decoder, and VLM-backbone variants — all built from one
``ArchConfig`` and executed as a ``lax.scan`` over layer *groups* (one
pattern period per scan step; see configs/base.py).

Public API (all pure functions over param pytrees):
  init_params(key, cfg)                      -> params
  forward(params, cfg, tokens, ...)          -> logits (full sequence)
  init_cache(cfg, batch, cache_len, dtype)   -> stacked per-layer caches
  decode_step(params, cfg, token, cache)     -> (logits, new_cache)
  encode(params, cfg, frames)                -> encoder memory (enc-dec only)

Caches are node-free (serving is per-deployment); training state carries the
extra leading ``node`` axis added by repro.train.trainer.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import layers as L
from repro.models import mamba as Mb
from repro.models import moe as Moe
from repro.models import rwkv as Rk

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _attn_spec(
    cfg: ArchConfig, *, window: int | None, flash: bool = False
) -> L.AttnSpec:
    return L.AttnSpec(
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.hd,
        rope_theta=cfg.rope_theta,
        causal=True,
        window=window,
        flash=flash,
    )


def _init_norm(cfg: ArchConfig, dtype) -> PyTree:
    p = {"w": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "ln":
        p["b"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _init_layer(key, cfg: ArchConfig, spec: LayerSpec, dtype) -> PyTree:
    k1, k2 = jax.random.split(key)
    p: PyTree = {"norm1": _init_norm(cfg, dtype), "norm2": _init_norm(cfg, dtype)}
    if spec.mixer == "attn":
        p["attn"] = L.init_attention(k1, cfg.d_model, _attn_spec(cfg, window=None), dtype)
    elif spec.mixer == "mamba":
        p["mamba"] = Mb.init_mamba(k1, cfg.d_model, cfg.mamba, dtype)
    elif spec.mixer == "rwkv":
        p["rwkv"] = Rk.init_rwkv(k1, cfg.d_model, cfg.rwkv, dtype)
    if spec.ffn == "dense":
        p["ffn"] = L.init_ffn(k2, cfg.d_model, cfg.d_ff, dtype)
    elif spec.ffn == "moe":
        p["moe"] = Moe.init_moe(k2, cfg.d_model, cfg.moe, dtype)
    elif spec.ffn == "rwkv":
        p["ffn"] = Rk.init_rwkv_ffn(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _init_group(key, cfg: ArchConfig, dtype) -> PyTree:
    keys = jax.random.split(key, cfg.period)
    return {
        f"layer{i}": _init_layer(keys[i], cfg, spec, dtype)
        for i, spec in enumerate(cfg.pattern)
    }


def init_params(key, cfg: ArchConfig) -> PyTree:
    """Full parameter pytree; layer groups stacked on a leading scan axis."""
    dtype = cfg.dtype()
    k_emb, k_blocks, k_head, k_enc = jax.random.split(key, 4)
    group_keys = jax.random.split(k_blocks, cfg.num_groups)
    blocks = jax.vmap(lambda k: _init_group(k, cfg, dtype))(group_keys)
    params: PyTree = {
        "embed": (
            jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model)) * cfg.d_model**-0.5
        ).astype(dtype),
        "blocks": blocks,
        "final_norm": _init_norm(cfg, dtype),
        "lm_head": (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size)) * cfg.d_model**-0.5
        ).astype(dtype),
    }
    if cfg.enc_dec:
        enc_keys = jax.random.split(k_enc, cfg.enc_layers + cfg.num_layers + 1)
        enc_blocks = jax.vmap(
            lambda k: {
                "attn": L.init_attention(k, cfg.d_model, _attn_spec(cfg, window=None), dtype),
                "ffn": L.init_ffn(jax.random.fold_in(k, 1), cfg.d_model, cfg.d_ff, dtype),
                "norm1": _init_norm(cfg, dtype),
                "norm2": _init_norm(cfg, dtype),
            }
        )(enc_keys[: cfg.enc_layers])
        # cross-attention params for each decoder group
        cross = jax.vmap(
            lambda k: {
                f"layer{i}": {
                    "attn": L.init_attention(
                        jax.random.fold_in(k, i), cfg.d_model, _attn_spec(cfg, window=None), dtype
                    ),
                    "norm": _init_norm(cfg, dtype),
                }
                for i in range(cfg.period)
            }
        )(jax.random.split(enc_keys[-1], cfg.num_groups))
        params["encoder"] = {"blocks": enc_blocks, "final_norm": _init_norm(cfg, dtype)}
        params["cross"] = cross
    return params


def param_count(params: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------


def _apply_layer(
    p: PyTree,
    x: jax.Array,
    cfg: ArchConfig,
    spec: LayerSpec,
    *,
    window: int | None,
    cache: PyTree | None,
    cross: PyTree | None,
    memory: jax.Array | None,
    positions: jax.Array | None,
    flash: bool = False,
) -> tuple[jax.Array, PyTree | None, jax.Array]:
    """Pre-norm residual layer. Returns (x, new_cache, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.norm(x, p["norm1"], cfg.norm)
    new_cache: PyTree = {}
    if spec.mixer == "attn":
        aspec = _attn_spec(cfg, window=window, flash=flash)
        y, c = L.attention_layer(
            p["attn"], h, aspec,
            positions=positions,
            cache=None if cache is None else cache["mixer"],
        )
        new_cache["mixer"] = c
    elif spec.mixer == "mamba":
        y, c = Mb.mamba_block(
            p["mamba"], h, cfg.mamba, cache=None if cache is None else cache["mixer"]
        )
        new_cache["mixer"] = c
    else:  # rwkv
        y, c = Rk.rwkv_block(
            p["rwkv"], h, cfg.rwkv, cache=None if cache is None else cache["mixer"]
        )
        new_cache["mixer"] = c
    x = x + y

    if cross is not None and memory is not None:
        h = L.norm(x, cross["norm"], cfg.norm)
        aspec = _attn_spec(cfg, window=None)
        hkv, hd = cfg.num_kv_heads, cfg.hd
        b, t, _ = memory.shape
        mk = (memory @ cross["attn"]["wk"]).reshape(b, t, hkv, hd)
        mv = (memory @ cross["attn"]["wv"]).reshape(b, t, hkv, hd)
        y, _ = L.attention_layer(cross["attn"], h, aspec, cross_kv=(mk, mv))
        x = x + y

    h = L.norm(x, p["norm2"], cfg.norm)
    if spec.ffn == "dense":
        y = L.swiglu_ffn(p["ffn"], h) if cfg.ffn_act == "swiglu" else L.gelu_ffn(p["ffn"], h)
        new_cache["ffn"] = None
    elif spec.ffn == "moe":
        y, aux = Moe.moe_ffn(p["moe"], h, cfg.moe)
        new_cache["ffn"] = None
    elif spec.ffn == "rwkv":
        y, c = Rk.rwkv_ffn(p["ffn"], h, cache=None if cache is None else cache["ffn"])
        new_cache["ffn"] = c
    else:
        y = jnp.zeros_like(x)
        new_cache["ffn"] = None
    return x + y, new_cache, aux


def _apply_group(
    gp: PyTree,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    window: int | None,
    cache: PyTree | None,
    cross: PyTree | None,
    memory: jax.Array | None,
    positions: jax.Array | None,
    flash: bool = False,
) -> tuple[jax.Array, PyTree | None, jax.Array]:
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: PyTree = {}
    for i, spec in enumerate(cfg.pattern):
        name = f"layer{i}"
        x, c, aux = _apply_layer(
            gp[name], x, cfg, spec,
            window=window,
            cache=None if cache is None else cache[name],
            cross=None if cross is None else cross[name],
            memory=memory,
            positions=positions,
            flash=flash,
        )
        new_cache[name] = c
        aux_total = aux_total + aux
    return x, new_cache, aux_total


# ---------------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "window", "remat", "last_only", "act_sharding"),
)
def forward(
    params: PyTree,
    cfg: ArchConfig,
    tokens: jax.Array,
    *,
    prefix_embeds: jax.Array | None = None,
    memory: jax.Array | None = None,
    window: int | None = None,
    remat: bool = False,
    last_only: bool = False,
    act_sharding=None,
) -> tuple[jax.Array, jax.Array]:
    """tokens: (B, S) int32 -> (logits (B, S_total, V), moe_aux).

    prefix_embeds: (B, P, d) continuous embeddings prepended to the token
    embeddings (VLM patch stub). memory: (B, T, d) encoder output (enc-dec).
    remat: activation-checkpoint each layer group (training memory policy).
    """
    x = params["embed"][tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s)
    window = window if window is not None else (cfg.sliding_window if cfg.always_window else None)

    cross_stack = params.get("cross")

    def group_body(carry, scanned):
        x, aux = carry
        gp = scanned["gp"]
        cross = scanned.get("cross")
        x, _, a = _apply_group(
            gp, x, cfg, window=window, cache=None,
            cross=cross, memory=memory, positions=positions,
        )
        return (x, aux + a), None

    def body(carry, scanned):
        inner = jax.checkpoint(group_body, prevent_cse=False) if remat else group_body
        (x, aux), ys = inner(carry, scanned)
        if act_sharding is not None:
            # Pin the residual-stream layout (the scan carry saved per step):
            # left to itself GSPMD picks a batch-replicated layout for the
            # carry, costing L x full-batch activations per device. Applied
            # OUTSIDE the checkpointed region so the saved stack is the bf16
            # carry, not an f32 remat residual.
            x = jax.lax.with_sharding_constraint(x, act_sharding)
        return (x, aux), ys

    scanned = {"gp": params["blocks"]}
    if cross_stack is not None:
        scanned["cross"] = cross_stack
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), scanned)
    x = L.norm(x, params["final_norm"], cfg.norm)
    if last_only:
        # Prefill: slice BEFORE the head matmul — XLA does not reliably push
        # the slice through it, and full 32k-seq logits are ~34 GB/device.
        return x[:, -1] @ params["lm_head"], aux
    logits = x @ params["lm_head"]
    return logits, aux


@functools.partial(jax.jit, static_argnames=("cfg",))
def encode(params: PyTree, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """Whisper-style encoder over precomputed frame embeddings (B, T, d)."""
    x = frames.astype(cfg.dtype())
    spec = L.AttnSpec(
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
        causal=False, use_rope=True, rope_theta=cfg.rope_theta,
    )

    def body(x, lp):
        h = L.norm(x, lp["norm1"], cfg.norm)
        y, _ = L.attention_layer(lp["attn"], h, spec)
        x = x + y
        h = L.norm(x, lp["norm2"], cfg.norm)
        return x + L.gelu_ffn(lp["ffn"], h), None

    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return L.norm(x, params["encoder"]["final_norm"], cfg.norm)


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------


def init_cache(
    cfg: ArchConfig,
    batch: int,
    cache_len: int,
    dtype=None,
    *,
    kv_quant: bool = False,
    per_slot: bool = False,
) -> PyTree:
    """Stacked per-group caches. For attention the cache is a ring buffer of
    ``cache_len`` (callers pass window size for sliding-window archs).
    kv_quant=True stores int8 values + per-(token, head) f32 scales.
    per_slot=True gives every batch row its own position counter (``index``
    is (batch,) instead of a shared scalar) — the continuous-batching engine's
    layout, where each slot is at a different point in its own sequence."""
    dtype = dtype or cfg.dtype()
    index = jnp.zeros((batch,) if per_slot else (), jnp.int32)

    def one_layer(spec: LayerSpec) -> PyTree:
        c: PyTree = {}
        if spec.mixer == "attn":
            kv_shape = (batch, cache_len, cfg.num_kv_heads, cfg.hd)
            if kv_quant:
                c["mixer"] = {
                    "k": jnp.zeros(kv_shape, jnp.int8),
                    "v": jnp.zeros(kv_shape, jnp.int8),
                    "k_scale": jnp.zeros(kv_shape[:-1] + (1,), jnp.float32),
                    "v_scale": jnp.zeros(kv_shape[:-1] + (1,), jnp.float32),
                    "index": index,
                }
            else:
                c["mixer"] = {
                    "k": jnp.zeros(kv_shape, dtype),
                    "v": jnp.zeros(kv_shape, dtype),
                    "index": index,
                }
        elif spec.mixer == "mamba":
            c["mixer"] = Mb.init_mamba_cache(batch, cfg.d_model, cfg.mamba, dtype)
        else:
            c["mixer"] = Rk.init_rwkv_cache(batch, cfg.d_model, cfg.rwkv, dtype)
        c["ffn"] = (
            {"shift": jnp.zeros((batch, cfg.d_model), dtype)}
            if spec.ffn == "rwkv"
            else None
        )
        return c

    one_group = {f"layer{i}": one_layer(s) for i, s in enumerate(cfg.pattern)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_groups,) + x.shape), one_group
    )


@functools.partial(jax.jit, static_argnames=("cfg", "window", "flash"))
def prefill_forward(
    params: PyTree,
    cfg: ArchConfig,
    tokens: jax.Array,
    cache: PyTree,
    *,
    length: jax.Array | None = None,
    memory: jax.Array | None = None,
    window: int | None = None,
    flash: bool = False,
) -> tuple[jax.Array, PyTree]:
    """Full-prompt prefill: ONE forward pass that writes the whole KV cache.

    tokens: (B, S) int32, right-padded to a common S when lengths differ;
    length: (B,) true prompt lengths (defaults to S). Returns the f32 logits
    of each row's LAST REAL token, (B, V), plus the filled cache — the state
    ``decode_step`` continues from.

    The cache must be fresh (positions start at 0). Padded positions do get
    K/V entries, but the written ``index`` = true length marks them future /
    unwritten to the decode-side ring reconstruction, so they are never
    attended (and are progressively overwritten as decoding advances). That
    argument needs S <= ring length: with S > ring, padded slots wrap BELOW
    the written index and decode would attend them as real past tokens, so
    ``length`` combined with a prompt wider than the attention cache ring
    raises. (Full-length rows — length=None — may exceed the ring; the
    prompt then degrades to documented sliding-window semantics.)
    Recurrent mixers (mamba/rwkv) consume the sequence through their chunked
    scan paths, so padding is NOT safe for them — callers must pass exact
    lengths (the serve engine restricts itself to attention-only patterns).
    Per-row ``length`` needs a per-slot cache (``init_cache(per_slot=True)``);
    a scalar-index cache cannot represent rows at different positions.

    MoE FFNs use capacity-based per-group routing, so chunked prefill matches
    ``forward``'s (training) numerics, while token-at-a-time decode routes
    each step as its own tiny group — the two legitimately differ for MoE
    patterns. Dense / rwkv-ffn patterns are step-exact either way.

    flash=True routes every attention layer through the Pallas kernel
    (kernels/flash_attention.py); False uses the pure-JAX reference path.
    """
    if length is not None:
        rings = []

        def _ring_len(path, leaf):
            if str(getattr(path[-1], "key", path[-1])) == "k":
                rings.append(leaf.shape[-3])  # (..., B, T, Hkv, hd)
            return leaf

        jax.tree_util.tree_map_with_path(_ring_len, cache)
        if rings and tokens.shape[1] > min(rings):
            raise ValueError(
                f"right-padded prefill (length given) needs padded width <= "
                f"the attention cache ring ({tokens.shape[1]} > {min(rings)}): "
                "with S > ring, padded K/V wraps below the written index and "
                "decode attends it as real past context — shorten the pad "
                "width or grow the cache"
            )
    x = params["embed"][tokens]
    window = window if window is not None else (cfg.sliding_window if cfg.always_window else None)
    cross_stack = params.get("cross")

    def body(x, scanned):
        gp, gc = scanned["gp"], scanned["cache"]
        cross = scanned.get("cross")
        x, new_c, _ = _apply_group(
            gp, x, cfg, window=window, cache=gc,
            cross=cross, memory=memory, positions=None, flash=flash,
        )
        return x, new_c

    scanned = {"gp": params["blocks"], "cache": cache}
    if cross_stack is not None:
        scanned["cross"] = cross_stack
    x, new_cache = jax.lax.scan(body, x, scanned)
    x = L.norm(x, params["final_norm"], cfg.norm)
    if length is None:
        last = x[:, -1]
    else:
        length = jnp.asarray(length, jnp.int32)
        lvec = jnp.broadcast_to(length, x.shape[:1])
        last = jnp.take_along_axis(x, (lvec - 1)[:, None, None], axis=1)[:, 0]

        def fix(path, leaf):
            name = str(getattr(path[-1], "key", path[-1]))
            if name != "index":
                return leaf
            if length.ndim == 1 and leaf.ndim == 1:
                raise ValueError(
                    "per-row prompt lengths need a per-slot cache "
                    "(init_cache(..., per_slot=True)); this cache has a "
                    "scalar index shared by the whole batch"
                )
            return jnp.broadcast_to(length.astype(leaf.dtype), leaf.shape)

        new_cache = jax.tree_util.tree_map_with_path(fix, new_cache)
    # Slice BEFORE the head matmul (cf. forward's last_only note): full-seq
    # logits at serving scale are a multi-GB transient for nothing.
    logits = (last @ params["lm_head"]).astype(jnp.float32)
    return logits, new_cache


@functools.partial(jax.jit, static_argnames=("cfg", "window"))
def decode_step(
    params: PyTree,
    cfg: ArchConfig,
    token: jax.Array,
    cache: PyTree,
    *,
    memory: jax.Array | None = None,
    window: int | None = None,
) -> tuple[jax.Array, PyTree]:
    """One-token decode. token: (B,) int32. Returns (logits (B, V), cache)."""
    x = params["embed"][token][:, None, :]  # (B, 1, d)
    window = window if window is not None else (cfg.sliding_window if cfg.always_window else None)
    cross_stack = params.get("cross")

    def body(x, scanned):
        gp, gc = scanned["gp"], scanned["cache"]
        cross = scanned.get("cross")
        x, new_c, _ = _apply_group(
            gp, x, cfg, window=window, cache=gc,
            cross=cross, memory=memory, positions=None,
        )
        return x, new_c

    scanned = {"gp": params["blocks"], "cache": cache}
    if cross_stack is not None:
        scanned["cross"] = cross_stack
    x, new_cache = jax.lax.scan(body, x, scanned)
    x = L.norm(x, params["final_norm"], cfg.norm)
    logits = (x[:, 0] @ params["lm_head"]).astype(jnp.float32)
    return logits, new_cache
