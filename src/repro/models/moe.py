"""Mixture-of-Experts FFN: top-k router + capacity-based dispatch/combine.

TPU-native (GShard/Switch) formulation: routing produces one-hot dispatch
tensors and expert compute is a dense batched einsum over an explicit
``expert`` axis — no gather/scatter, fully shardable. The expert axis is
sharded over the `model` mesh axis (expert parallelism); the dispatch einsum
``(T,E,C),(T,d)->(E,C,d)`` lowers to the all-to-all the MoE literature
expects.

FLOPs honesty for the roofline: with capacity factor f, expert FLOPs are
``2 * E * C * d * ff * 3`` where ``E*C = f * k * T`` — i.e. proportional to
*active* (top-k) compute, not total experts. Router + dispatch overhead is
``O(T*E*C)`` and is reported separately by the roofline notes.

Load-balancing: standard switch auxiliary loss (mean_prob * mean_assignment
per expert, scaled by E) is returned alongside the output so the trainer can
add it — router collapse is the classic decentralized-MoE failure mode and
the DecAvg gossip *averages router weights across nodes*, which the
EXPERIMENTS §Perf notes discuss.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import swiglu_ffn

PyTree = Any


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    dense_d_ff: int = 0
    # Routing-group size: dispatch/combine one-hots are materialized per
    # group, never for the full token stream. The (Tg, E, Cg) tensor is
    # O(Tg^2 * cf * k) bytes *independent of E*; ungrouped 32k-prefill
    # dispatch is a multi-TB tensor (observed 8 TB/device at dbrx).
    group_size: int = 2048


def init_moe(key, d_model: int, spec: MoESpec, dtype) -> PyTree:
    e, ff = spec.num_experts, spec.d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s_in = d_model**-0.5
    s_out = ff**-0.5
    p = {
        "router": (jax.random.normal(k1, (d_model, e)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (e, d_model, ff)) * s_in).astype(dtype),
        "w_in": (jax.random.normal(k3, (e, d_model, ff)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(k4, (e, ff, d_model)) * s_out).astype(dtype),
    }
    if spec.dense_residual:
        from repro.models.layers import init_ffn

        p["dense"] = init_ffn(k5, d_model, spec.dense_d_ff or spec.d_ff, dtype)
    return p


def _capacity(tokens: int, spec: MoESpec) -> int:
    c = int(spec.capacity_factor * spec.top_k * tokens / spec.num_experts)
    return max(c, 1)


def _moe_group(p: PyTree, xt: jax.Array, spec: MoESpec) -> tuple[jax.Array, jax.Array]:
    """Route + dispatch + expert FFN + combine for ONE token group.

    xt: (Tg, d). Returns (out (Tg, d), aux scalar). The expert axis is the
    EP-sharded one; the dispatch einsum lowers to the all-to-all.
    """
    t, d = xt.shape
    e, k = spec.num_experts, spec.top_k
    c = _capacity(t, spec)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (Tg, k)
    gate_vals = gate_vals / (gate_vals.sum(axis=-1, keepdims=True) + 1e-9)

    # Position of each (token, choice) within its expert's capacity buffer:
    # cumulative count of prior assignments to the same expert.
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)  # (Tg, k, E)
    flat = onehot.reshape(t * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(t, k, e)
    pos = (pos_in_expert * onehot).sum(-1)  # (Tg, k)
    keep = pos < c  # overflow tokens are dropped (standard capacity behavior)

    # Dispatch (Tg, E, C) and combine (gate-weighted) tensors.
    pos_oh = jax.nn.one_hot(pos, c, dtype=jnp.float32) * keep[..., None]
    disp = jnp.einsum("tke,tkc->tec", onehot.astype(jnp.float32), pos_oh)
    comb = jnp.einsum("tk,tke,tkc->tec", gate_vals, onehot.astype(jnp.float32), pos_oh)

    ex_in = jnp.einsum("tec,td->ecd", disp, xt.astype(jnp.float32)).astype(xt.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ex_in, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", ex_in, p["w_in"]
    )
    ex_out = jnp.einsum("ecf,efd->ecd", h, p["w_out"])
    out = jnp.einsum("tec,ecd->td", comb, ex_out.astype(jnp.float32)).astype(xt.dtype)

    # Switch aux loss: E * sum_e fraction_tokens_e * mean_prob_e.
    frac = onehot[:, 0, :].astype(jnp.float32).mean(axis=0)  # top-1 assignment share
    mean_prob = probs.mean(axis=0)
    aux = e * jnp.sum(frac * mean_prob)
    return out, aux


def moe_ffn(p: PyTree, x: jax.Array, spec: MoESpec) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    Tokens are flattened to T = B*S and processed in routing groups of
    ``spec.group_size`` via a checkpointed ``lax.map`` — capacity (and token
    dropping) is per-group, the GShard convention, and peak dispatch memory
    is one group's (Tg, E, Cg) tensor instead of the full stream's.
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    g = min(spec.group_size, t)
    pad = (-t) % g
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    ngroups = (t + pad) // g
    xg = xt.reshape(ngroups, g, d)

    if ngroups == 1:
        out, aux = _moe_group(p, xg[0], spec)
    else:
        body = jax.checkpoint(
            lambda xs: _moe_group(p, xs, spec), prevent_cse=False
        )
        out, auxes = jax.lax.map(body, xg)
        out = out.reshape(ngroups * g, d)
        aux = auxes.mean()
    out = out.reshape(-1, d)[:t].reshape(b, s, d)

    if spec.dense_residual:
        out = out + swiglu_ffn(p["dense"], x)
    return out, aux
