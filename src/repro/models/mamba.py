"""Mamba (selective SSM) block in pure JAX, TPU-adapted.

The CUDA reference implements the selective scan as a fused kernel holding
the recurrent state in SRAM. The TPU-native adaptation here is a *chunked
associative scan*: the sequence is split into chunks; within a chunk the
linear recurrence ``h_t = a_t * h_{t-1} + b_t`` is solved by
``jax.lax.associative_scan`` (log-depth, fully parallel, MXU/VPU friendly),
and a short ``lax.scan`` carries the state across chunks. Peak memory is
O(B * chunk * d_inner * d_state) instead of O(B * S * d_inner * d_state) —
the difference between 137 MB/device and 550 TB at jamba scale.

Decode mode is the exact single-step recurrence with (conv_state, ssm_state)
carried in the KV-cache pytree.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    chunk: int = 256

    def inner(self, d_model: int) -> int:
        return self.expand * d_model

    def rank(self, d_model: int) -> int:
        return self.dt_rank or max(1, (d_model + 15) // 16)


def init_mamba(key, d_model: int, spec: MambaSpec, dtype) -> PyTree:
    di = spec.inner(d_model)
    dr = spec.rank(d_model)
    ks = jax.random.split(key, 7)
    s = d_model**-0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (d_model, 2 * di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (spec.d_conv, di)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (jax.random.normal(ks[2], (di, dr + 2 * spec.d_state)) * di**-0.5).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (dr, di)) * dr**-0.5).astype(dtype),
        "dt_bias": jnp.full((di,), -4.0, jnp.float32),  # softplus(-4) ~ small init dt
        "a_log": jnp.log(
            jnp.tile(jnp.arange(1, spec.d_state + 1, dtype=jnp.float32), (di, 1))
        ),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (di, d_model)) * di**-0.5).astype(dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, state: jax.Array | None):
    """Depthwise causal conv over time. x: (B, S, di); w: (K, di).

    Returns (y, new_state) where state holds the last K-1 inputs."""
    k = w.shape[0]
    if state is None:
        ctx = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(
        ctx[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    new_state = ctx[:, -(k - 1) :, :] if k > 1 else None
    return (y + b[None, None, :]).astype(x.dtype), new_state


def _ssm_chunked(a: jax.Array, bx: jax.Array, c: jax.Array, h0: jax.Array, chunk: int):
    """Solve h_t = a_t ⊙ h_{t-1} + bx_t, y_t = sum_n c_tn h_tn.

    a, bx: (B, S, di, n); c: (B, S, n); h0: (B, di, n).
    Chunked associative scan (see module docstring). Returns (y, h_last).
    """
    b_, s, di, n = a.shape
    pad = (-s) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nchunks = (s + pad) // chunk
    ac = a.reshape(b_, nchunks, chunk, di, n).swapaxes(0, 1)
    bc = bx.reshape(b_, nchunks, chunk, di, n).swapaxes(0, 1)

    def combine(left, right):
        (al, bl), (ar, br) = left, right
        return al * ar, bl * ar + br

    def outer(h, inputs):
        ach, bch = inputs  # (B, chunk, di, n)
        # Prefix-solve the recurrence inside the chunk (identity-prefixed h).
        aa, bb = jax.lax.associative_scan(combine, (ach, bch), axis=1)
        hc = aa * h[:, None] + bb  # (B, chunk, di, n): h_t for every t
        return hc[:, -1], hc

    h_last, hs = jax.lax.scan(outer, h0, (ac, bc))
    hs = hs.swapaxes(0, 1).reshape(b_, nchunks * chunk, di, n)[:, :s]
    y = jnp.einsum("bsdn,bsn->bsd", hs, c)
    return y, h_last


def mamba_block(
    p: PyTree,
    x: jax.Array,
    spec: MambaSpec,
    *,
    cache: PyTree | None = None,
) -> tuple[jax.Array, PyTree | None]:
    """x: (B, S, d_model) -> (y, new_cache).

    cache = {"conv": (B, K-1, di), "ssm": (B, di, n)} for decode (S == 1).
    """
    b, s, d = x.shape
    di = spec.inner(d)
    n = spec.d_state

    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xs, new_conv = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_state)
    xs = jax.nn.silu(xs)

    proj = (xs @ p["x_proj"]).astype(jnp.float32)  # (B, S, dr + 2n)
    dr = spec.rank(d)
    dt, bmat, cmat = jnp.split(proj, [dr, dr + n], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])  # (B,S,di)
    a = -jnp.exp(p["a_log"])  # (di, n)
    a_bar = jnp.exp(dt[..., None] * a[None, None])  # (B,S,di,n)
    bx = (dt[..., None] * bmat[:, :, None, :]) * xs.astype(jnp.float32)[..., None]

    h0 = (
        cache["ssm"].astype(jnp.float32)
        if cache is not None
        else jnp.zeros((b, di, n), jnp.float32)
    )
    if s == 1 and cache is not None:
        h = a_bar[:, 0] * h0 + bx[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])[:, None]
        h_last = h
    else:
        y, h_last = _ssm_chunked(a_bar, bx, cmat, h0, spec.chunk)

    y = y + p["d_skip"][None, None] * xs.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": h_last}
    return y.astype(x.dtype), new_cache


def init_mamba_cache(batch: int, d_model: int, spec: MambaSpec, dtype) -> PyTree:
    di = spec.inner(d_model)
    return {
        "conv": jnp.zeros((batch, spec.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, spec.d_state), jnp.float32),
    }
