"""Core transformer layers: norms, RoPE, GQA attention (full / chunked /
sliding-window / cached-decode), SwiGLU FFN.

Conventions:
- Pure functions over explicit param dicts; no framework objects.
- Params live in ``param_dtype`` (bf16 at scale); matmuls run in the param
  dtype with f32 accumulation where it matters (norm stats, softmax, RoPE).
- Shapes: activations (B, S, d); attention weights are (d, H*hd) etc. so the
  head axis is a trailing reshape — this keeps every matmul 128-aligned for
  the MXU and lets the `model` mesh axis shard the fused head dim.
- Long sequences: `attention` switches to an online-softmax scan over KV
  chunks (flash-attention recurrence in pure JAX) so the (S, S) logits
  matrix is never materialized — required for prefill_32k to fit HBM.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# ---------------------------------------------------------------------------
# Norms & embeddings
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm(x: jax.Array, p: PyTree, kind: str = "rms") -> jax.Array:
    if kind == "rms":
        return rms_norm(x, p["w"])
    return layer_norm(x, p["w"], p["b"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,). Rotates pairs (even, odd)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., ::2], xf[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------


def _mask_bias(qpos: jax.Array, kpos: jax.Array, *, causal: bool, window: int | None) -> jax.Array:
    """(..., S, T) additive bias: 0 where attendable, -inf where masked."""
    ok = jnp.ones(qpos.shape[:-1] + (qpos.shape[-1], kpos.shape[-1]), dtype=bool)
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    if causal:
        ok &= k <= q
    if window is not None:
        ok &= k > q - window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def dense_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    qpos: jax.Array,
    kpos: jax.Array,
    *,
    causal: bool,
    window: int | None,
) -> jax.Array:
    """Materialized-logits attention. q: (B,S,H,hd), k/v: (B,T,Hkv,hd)."""
    b, s, h, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = hd**-0.5
    qf = q.astype(jnp.float32).reshape(b, s, hkv, g, hd)
    logits = jnp.einsum("bshgd,bthd->bhgst", qf, k.astype(jnp.float32)) * scale
    bias = _mask_bias(qpos, kpos, causal=causal, window=window)  # (B,S,T) or (S,T)
    while bias.ndim < logits.ndim:
        bias = bias[:, None] if bias.ndim >= 3 else bias[None]
    probs = jax.nn.softmax(logits + bias, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    qpos: jax.Array,
    kpos: jax.Array,
    *,
    causal: bool,
    window: int | None,
    kv_chunk: int = 1024,
    q_chunk: int = 1024,
) -> jax.Array:
    """2D-tiled online-softmax attention (flash recurrence in pure JAX).

    Outer scan over query chunks, inner scan over KV chunks — peak extra
    memory is one (B, q_chunk, H, kv_chunk) logits tile, never (S, T).
    Required for prefill_32k to fit HBM (a KV-only tiling still materializes
    an S-long tile per chunk: 67 GB/device at 32 k, observed).
    """
    b, s, h, hd = q.shape
    if s > q_chunk:
        pad_q = (-s) % q_chunk
        if pad_q:
            q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
            qpos = jnp.pad(qpos, (0, pad_q), constant_values=jnp.iinfo(jnp.int32).max - 1)
        nq = (s + pad_q) // q_chunk
        qs = q.reshape(b, nq, q_chunk, h, hd).swapaxes(0, 1)
        qps = qpos.reshape(nq, q_chunk)

        def do_chunk(args):
            qc, qp = args
            return chunked_attention(
                qc, k, v, qp, kpos,
                causal=causal, window=window,
                kv_chunk=kv_chunk, q_chunk=q_chunk,
            )

        out = jax.lax.map(do_chunk, (qs, qps))
        out = out.swapaxes(0, 1).reshape(b, nq * q_chunk, h, hd)[:, :s]
        return out
    t, hkv = k.shape[1], k.shape[2]
    if t % kv_chunk:
        pad = (-t) % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
        t += pad
    g = h // hkv
    scale = hd**-0.5
    qf = (q.astype(jnp.float32) * scale).reshape(b, s, hkv, g, hd)
    nchunks = t // kv_chunk
    kc = k.reshape(b, nchunks, kv_chunk, hkv, hd)
    vc = v.reshape(b, nchunks, kv_chunk, hkv, hd)
    pc = kpos.reshape(nchunks, kv_chunk)

    def step(carry, inputs):
        m, l, acc = carry  # (B,S,hkv,g,1), (B,S,hkv,g,1), (B,S,hkv,g,hd)
        kb, vb, pb = inputs  # (B,C,hkv,hd), (B,C,hkv,hd), (C,)
        logits = jnp.einsum("bshgd,bchd->bshgc", qf, kb.astype(jnp.float32))
        bias = _mask_bias(qpos, pb, causal=causal, window=window)  # (S, C)
        # Finite mask value: a fully-masked chunk must not poison the online
        # max with -inf (exp(-inf - -inf) = nan); bogus contributions from
        # all-masked chunks are wiped by `corr` once a real chunk arrives
        # (every causal query attends at least itself, so one always does).
        bias = jnp.maximum(bias, -1e9)
        logits = logits + bias[None, :, None, None, :]
        m_new = jnp.maximum(m, logits.max(axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.einsum("bshgc,bchd->bshgd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, s, hkv, g, 1), -jnp.inf, jnp.float32),
        jnp.zeros((b, s, hkv, g, 1), jnp.float32),
        jnp.zeros((b, s, hkv, g, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        step,
        init,
        (jnp.swapaxes(kc, 0, 1), jnp.swapaxes(vc, 0, 1), pc),
    )
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(b, s, h, hd).astype(q.dtype)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    qpos: jax.Array,
    kpos: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    kv_chunk: int = 1024,
    dense_threshold: int = 2048 * 2048,
) -> jax.Array:
    """Dispatch between materialized and chunked attention by S*T size.

    The threshold is deliberately small: a materialized (B, H, S, T) f32
    logits tensor at S=T=4096 and production batch is a TB-scale transient
    (~100 GB/device at mistral-123b train_4k, observed); the 2D-tiled path
    keeps the tile at O(q_chunk * kv_chunk)."""
    s, t = q.shape[1], k.shape[1]
    if s * t <= dense_threshold or s == 1:
        return dense_attention(q, k, v, qpos, kpos, causal=causal, window=window)
    return chunked_attention(
        q, k, v, qpos, kpos, causal=causal, window=window, kv_chunk=kv_chunk
    )


# ---------------------------------------------------------------------------
# GQA attention layer (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    causal: bool = True
    window: int | None = None
    use_rope: bool = True
    # Route full-sequence (prefill) attention through the Pallas
    # flash-attention kernel instead of the pure-JAX reference path.
    flash: bool = False


def init_attention(key, d_model: int, spec: AttnSpec, dtype) -> PyTree:
    h, hkv, hd = spec.num_heads, spec.num_kv_heads, spec.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = d_model**-0.5
    s_out = (h * hd) ** -0.5
    return {
        "wq": (jax.random.normal(k1, (d_model, h * hd)) * s_in).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, hkv * hd)) * s_in).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, hkv * hd)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k4, (h * hd, d_model)) * s_out).astype(dtype),
    }


def attention_layer(
    p: PyTree,
    x: jax.Array,
    spec: AttnSpec,
    *,
    positions: jax.Array | None = None,
    cache: PyTree | None = None,
    cross_kv: tuple[jax.Array, jax.Array] | None = None,
) -> tuple[jax.Array, PyTree | None]:
    """GQA attention over (B, S, d).

    Modes:
    - full-sequence (cache=None): self-attention over x.
    - decode (cache={'k','v','index'}): S==1 query against the cache; the
      cache is a ring buffer of length T (sliding-window archs size it to
      the window), updated functionally and returned.
    - cross (cross_kv=(k, v)): encoder-decoder cross-attention; no rope on
      k/v (they carry encoder positions already), cache unused.
    """
    b, s, d = x.shape
    h, hkv, hd = spec.num_heads, spec.num_kv_heads, spec.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)

    if cross_kv is not None:
        k, v = cross_kv
        t = k.shape[1]
        qpos = jnp.arange(s)
        kpos = jnp.arange(t)
        out = attention(q, k, v, qpos, kpos, causal=False, window=None)
        return (out.reshape(b, s, h * hd) @ p["wo"]).astype(x.dtype), None

    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)

    if cache is None:
        pos = jnp.arange(s) if positions is None else positions
        if spec.use_rope:
            q = apply_rope(q, pos, spec.rope_theta)
            k = apply_rope(k, pos, spec.rope_theta)
        out = attention(
            q, k, v, jnp.asarray(pos), jnp.asarray(pos),
            causal=spec.causal, window=spec.window,
        )
        return (out.reshape(b, s, h * hd) @ p["wo"]).astype(x.dtype), None

    if s > 1:
        # --- prefill: the whole prompt in one pass, KV written in one shot
        # Contract: the cache is fresh (positions start at 0); the ring
        # buffer keeps the last min(S, T) prompt tokens. The caller owns the
        # true-length bookkeeping for right-padded prompts (padded positions
        # land at ring slots >= the written index, which the decode-side
        # kpos reconstruction marks unwritten / future — never attended).
        # That only holds for S <= T: with S > T padded slots wrap below the
        # written index and WOULD be attended, so right-padded rows must
        # never reach this branch with S > T (prefill_forward rejects the
        # combination; full-length rows with S > T are fine — ring/window).
        t = cache["k"].shape[1]
        pos = jnp.arange(s)
        if spec.use_rope:
            q = apply_rope(q, pos, spec.rope_theta)
            k = apply_rope(k, pos, spec.rope_theta)
        if spec.flash:
            from repro.kernels import ops as _ops

            out = _ops.flash_attention(
                q, k, v, causal=spec.causal, window=spec.window
            )
        else:
            out = attention(
                q, k, v, pos, pos, causal=spec.causal, window=spec.window
            )
        m = min(s, t)
        # Static ring slots of the surviving (last m) prompt positions.
        slots = np.arange(s - m, s) % t
        kw, vw = k[:, s - m :], v[:, s - m :]
        quantized = cache["k"].dtype == jnp.int8
        new_cache = {"index": cache["index"] + s}
        if quantized:
            kq, ks = _quant_kv(kw)
            vq, vs = _quant_kv(vw)
            new_cache.update(
                k=cache["k"].at[:, slots].set(kq),
                v=cache["v"].at[:, slots].set(vq),
                k_scale=cache["k_scale"].at[:, slots].set(ks),
                v_scale=cache["v_scale"].at[:, slots].set(vs),
            )
        else:
            new_cache.update(
                k=cache["k"].at[:, slots].set(kw.astype(cache["k"].dtype)),
                v=cache["v"].at[:, slots].set(vw.astype(cache["v"].dtype)),
            )
        return (out.reshape(b, s, h * hd) @ p["wo"]).astype(x.dtype), new_cache

    # --- decode: single new token against a (possibly ring) cache ---------
    index = cache["index"]  # int32 absolute position of the new token:
    # scalar = position shared by the whole batch (classic batched decode);
    # (B,) = per-slot positions (the continuous-batching engine, where every
    # slot is at a different point in its own sequence).
    t = cache["k"].shape[1]
    per_slot = index.ndim == 1
    qpos = index[:, None] if per_slot else index[None]
    if spec.use_rope:
        q = apply_rope(q, qpos, spec.rope_theta)
        k = apply_rope(k, qpos, spec.rope_theta)
    slot = jnp.mod(index, t)  # ring-buffer slot (t == window for SWA archs)
    if per_slot:
        sel = (jnp.arange(t)[None, :] == slot[:, None])[:, :, None, None]

        def put(buf, val):  # masked per-row scatter at each slot's position
            return jnp.where(sel, val.astype(buf.dtype), buf)

    else:

        def put(buf, val):
            return jax.lax.dynamic_update_slice_in_dim(
                buf, val.astype(buf.dtype), slot, axis=1
            )

    quantized = cache["k"].dtype == jnp.int8
    new_cache = {"index": index + 1}
    if quantized:
        # int8 KV cache: per-(token, head) absmax scales — halves decode HBM
        # and keeps 32k-cache serving under the v5e budget (EXPERIMENTS §Perf
        # H3). Error is bounded by 1/127 of the per-head absmax.
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        ck, cv = put(cache["k"], kq), put(cache["v"], vq)
        cks = put(cache["k_scale"], ks)
        cvs = put(cache["v_scale"], vs)
        new_cache.update(k=ck, v=cv, k_scale=cks, v_scale=cvs)
        ck_f = ck.astype(jnp.float32) * cks
        cv_f = cv.astype(jnp.float32) * cvs
    else:
        ck, cv = put(cache["k"], k), put(cache["v"], v)
        new_cache.update(k=ck, v=cv)
        ck_f, cv_f = ck, cv
    # Absolute positions of each ring slot, given `index` was just written.
    slots = jnp.arange(t)
    if per_slot:
        kpos = (
            index[:, None] + slots[None, :] - slot[:, None]
            - jnp.where(slots[None, :] > slot[:, None], t, 0)
        )
    else:
        kpos = index + slots - slot - jnp.where(slots > slot, t, 0)
    kpos = jnp.where(kpos < 0, jnp.iinfo(jnp.int32).max, kpos)  # unwritten slots
    out = attention(q, ck_f, cv_f, qpos, kpos, causal=True, window=spec.window)
    y = (out.reshape(b, 1, h * hd) @ p["wo"]).astype(x.dtype)
    return y, new_cache


def _quant_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization over the head_dim axis.
    x: (B, S, Hkv, hd) -> (int8 values, f32 scales (B, S, Hkv, 1))."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------


def init_ffn(key, d_model: int, d_ff: int, dtype) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model**-0.5
    s_out = d_ff**-0.5
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_in": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    }


def swiglu_ffn(p: PyTree, x: jax.Array) -> jax.Array:
    return ((jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])) @ p["w_out"]).astype(x.dtype)


def gelu_ffn(p: PyTree, x: jax.Array) -> jax.Array:
    """2-matrix GELU FFN (whisper-style); reuses w_in/w_out."""
    return (jax.nn.gelu(x @ p["w_in"]) @ p["w_out"]).astype(x.dtype)
