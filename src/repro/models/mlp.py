"""The paper's local model: a 3-hidden-layer MLP (512, 256, 128) with ReLU.

Used by the faithful reproduction (100-node MNIST-scale experiments) and as
the `paper-mlp` architecture config.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

PyTree = Any

HIDDEN = (512, 256, 128)


def init_mlp(
    key,
    in_dim: int = 784,
    hidden: Sequence[int] = HIDDEN,
    num_classes: int = 10,
    dtype=jnp.float32,
) -> PyTree:
    dims = [in_dim, *hidden, num_classes]
    params = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        k = jax.random.fold_in(key, i)
        # He init for ReLU nets.
        w = jax.random.normal(k, (a, b)) * (2.0 / a) ** 0.5
        params.append({"w": w.astype(dtype), "b": jnp.zeros((b,), dtype)})
    return {"layers": tuple(params)}


def mlp_forward(params: PyTree, x: jax.Array) -> jax.Array:
    """x: (..., in_dim) -> logits (..., num_classes)."""
    h = x
    layers = params["layers"]
    for i, p in enumerate(layers):
        h = h @ p["w"] + p["b"]
        if i < len(layers) - 1:
            h = jax.nn.relu(h)
    return h
