"""STUB modality frontends (the one sanctioned carve-out, see DESIGN.md §4).

We do not implement a mel-spectrogram conv codec or a ViT: the assigned
[audio]/[vlm] entries specify the *transformer backbone* only. These helpers
produce (a) deterministic synthetic embeddings for smoke tests / examples and
(b) ShapeDtypeStruct stand-ins for the dry-run, with the right shapes:

- audio (whisper): (B, T_frames, d_model) frame embeddings, the output the
  conv1d×2 + GELU frontend would produce.
- vlm (internvl2): (B, P, d_model) projected patch embeddings, the output of
  InternViT + MLP projector; the LM consumes them as a prefix to the token
  embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def audio_frames(key, cfg: ArchConfig, batch: int, num_frames: int) -> jax.Array:
    """Synthetic encoder-input frame embeddings (stub for mel+conv)."""
    return (
        jax.random.normal(key, (batch, num_frames, cfg.d_model)) * cfg.d_model**-0.5
    ).astype(cfg.dtype())


def patch_embeddings(key, cfg: ArchConfig, batch: int, num_patches: int) -> jax.Array:
    """Synthetic projected vision-patch embeddings (stub for ViT+projector)."""
    return (
        jax.random.normal(key, (batch, num_patches, cfg.d_model)) * cfg.d_model**-0.5
    ).astype(cfg.dtype())


def audio_frames_spec(cfg: ArchConfig, batch: int, num_frames: int):
    return jax.ShapeDtypeStruct((batch, num_frames, cfg.d_model), cfg.dtype())


def patch_embeddings_spec(cfg: ArchConfig, batch: int, num_patches: int):
    return jax.ShapeDtypeStruct((batch, num_patches, cfg.d_model), cfg.dtype())
