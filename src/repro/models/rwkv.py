"""RWKV-6 ("Finch") block: token-shift mixing + data-dependent-decay WKV.

Attention-free: per head h of size D, the time-mixing state is a (D, D)
matrix S updated per token with a *data-dependent* diagonal decay w_t
(the Finch contribution vs RWKV-5's static decay):

    S_t = diag(w_t) @ S_{t-1} + k_t^T v_t
    o_t = r_t @ (diag(u) k_t^T v_t + S_{t-1})

TPU adaptation: like mamba.py, the recurrence runs as a chunked scan —
within a chunk we materialize per-step decays and use the classic
"chunked linear attention" decomposition (intra-chunk pairwise term with a
decay-ratio mask + inter-chunk state term), so the bulk of the compute is
MXU matmuls; a short lax.scan carries S across chunks. Decode is the exact
single-step update.

The decay LoRA (w = base + tanh(x A) B) and the token-shift interpolation
factors follow the RWKV-6 paper's structure; channel-mixing is the standard
RWKV squared-relu FFN (d_ff from the config).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class RWKVSpec:
    head_dim: int = 64
    decay_lora: int = 64
    # chunk * |log w|_max must stay < ~80 so the intra-chunk exp(-cum) factor
    # cannot overflow f32 (see the clamp in rwkv_block): 32 * 2 = 64 < 80.
    chunk: int = 32

    def heads(self, d_model: int) -> int:
        assert d_model % self.head_dim == 0
        return d_model // self.head_dim


def init_rwkv(key, d_model: int, spec: RWKVSpec, dtype) -> PyTree:
    h = spec.heads(d_model)
    hd = spec.head_dim
    ks = jax.random.split(key, 10)
    s = d_model**-0.5
    lin = lambda k, i, o, sc: (jax.random.normal(k, (i, o)) * sc).astype(dtype)
    return {
        # token-shift interpolation factors per channel, one per projection
        "mu": (0.5 * jnp.ones((5, d_model))).astype(dtype),  # r,k,v,g,w
        "wr": lin(ks[0], d_model, d_model, s),
        "wk": lin(ks[1], d_model, d_model, s),
        "wv": lin(ks[2], d_model, d_model, s),
        "wg": lin(ks[3], d_model, d_model, s),
        "w_base": jnp.full((d_model,), -6.0, jnp.float32),
        "w_lora_a": lin(ks[4], d_model, spec.decay_lora, s),
        "w_lora_b": lin(ks[5], spec.decay_lora, d_model, spec.decay_lora**-0.5),
        "u_bonus": (jax.random.normal(ks[6], (h, hd)) * 0.1).astype(jnp.float32),
        "wo": lin(ks[7], d_model, d_model, s),
        "ln_w": jnp.ones((d_model,), dtype),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """Return x_{t-1} (zero / cache for the first position)."""
    if x.shape[1] == 1 and prev is not None:
        return prev[:, None, :]
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev)
    return shifted


def _wkv_chunked(r, k, v, w, u, s0, chunk):
    """Chunked WKV recurrence.

    r,k,v,w: (B, S, H, D) with w the per-step decay in (0,1); u: (H, D).
    s0: (B, H, D, D) initial state. Returns (out (B,S,H,D), s_last).
    """
    b, s, h, d = r.shape
    pad = (-s) % chunk
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, zp), jnp.pad(k, zp), jnp.pad(v, zp)
        w = jnp.pad(w, zp, constant_values=1.0)
    nc = (s + pad) // chunk

    def resh(x):
        return x.reshape(b, nc, chunk, h, d).swapaxes(0, 1)

    rc, kc, vc, wc = map(resh, (r, k, v, w))
    logw = jnp.log(jnp.maximum(wc, 1e-12))
    cum = jnp.cumsum(logw, axis=2)  # (nc, B, C, H, D) cumulative log-decay incl. t

    def outer2(state, inputs):
        rb, kb, vb, cumb, logwb = inputs  # (B,C,H,D)
        cum_prev = cumb - logwb  # prod_{i<t} within chunk (log)
        # inter-chunk term: o_inter[t] = (r_t * exp(cum_prev_t)) @ S
        r_in = (rb * jnp.exp(cum_prev)).astype(jnp.float32)
        o_inter = jnp.einsum("bchd,bhde->bche", r_in, state)
        # intra-chunk pairwise: A[t,s] = sum_d r_t[d] exp(cum_prev_t - cum_s)[d] k_s[d] for s < t
        # plus the bonus diagonal term u for s == t.
        q_dec = rb * jnp.exp(cum_prev)
        k_dec = kb * jnp.exp(-cumb)
        att = jnp.einsum("bchd,bghd->bhcg", q_dec, k_dec)  # (B,H,C,C) over positions c>g
        c_idx = jnp.arange(rb.shape[1])
        mask = (c_idx[:, None] > c_idx[None, :]).astype(att.dtype)
        att = att * mask[None, None]
        diag = jnp.einsum("bchd,hd,bchd->bch", rb, u, kb)  # bonus at s == t
        o_intra = jnp.einsum("bhcg,bghe->bche", att, vb) + diag[..., None] * vb
        # state update: S' = diag(prod_chunk w) S + sum_s exp(cum_last - cum_s) k_s v_s
        total = cumb[:, -1:]  # (B,1,H,D)
        k_tail = kb * jnp.exp(total - cumb)
        s_new = jnp.exp(total[:, 0])[..., None] * state + jnp.einsum(
            "bchd,bche->bhde", k_tail, vb
        )
        return s_new, o_inter + o_intra

    s_last, outs = jax.lax.scan(
        outer2,
        s0.astype(jnp.float32),
        (
            rc.astype(jnp.float32),
            kc.astype(jnp.float32),
            vc.astype(jnp.float32),
            cum.astype(jnp.float32),
            logw.astype(jnp.float32),
        ),
    )
    out = outs.swapaxes(0, 1).reshape(b, nc * chunk, h, d)[:, :s]
    return out, s_last


def rwkv_block(
    p: PyTree,
    x: jax.Array,
    spec: RWKVSpec,
    *,
    cache: PyTree | None = None,
) -> tuple[jax.Array, PyTree | None]:
    """Time-mixing RWKV-6 block. cache = {"shift": (B,d), "wkv": (B,H,D,D)}."""
    b, s, d = x.shape
    h, hd = spec.heads(d), spec.head_dim
    prev = cache["shift"] if cache is not None else None
    xp = _token_shift(x, prev)

    def mix(i):
        mu = p["mu"][i][None, None]
        return x * mu + xp * (1.0 - mu)

    r = (mix(0) @ p["wr"]).reshape(b, s, h, hd)
    k = (mix(1) @ p["wk"]).reshape(b, s, h, hd)
    v = (mix(2) @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(mix(3) @ p["wg"])
    wx = mix(4).astype(jnp.float32)
    dec = p["w_base"] + jnp.tanh(wx @ p["w_lora_a"].astype(jnp.float32)) @ p[
        "w_lora_b"
    ].astype(jnp.float32)
    # Clamp the per-step log-decay to [-2, 0) so the chunked formulation's
    # exp(-cumsum) factor stays within f32 range (chunk=32 -> exp(64) max).
    dec = jnp.clip(dec, -20.0, jnp.log(2.0))
    w = jnp.exp(-jnp.exp(dec)).reshape(b, s, h, hd)  # data-dependent decay in (0,1)

    s0 = (
        cache["wkv"].astype(jnp.float32)
        if cache is not None
        else jnp.zeros((b, h, hd, hd), jnp.float32)
    )
    if s == 1 and cache is not None:
        rf, kf, vf, wf = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
        o = jnp.einsum("bhd,bhde->bhe", rf, s0) + jnp.einsum(
            "bhd,hd,bhd,bhe->bhe", rf, p["u_bonus"], kf, vf
        )
        s_new = wf[..., None] * s0 + jnp.einsum("bhd,bhe->bhde", kf, vf)
        out = o[:, None]
    else:
        out, s_new = _wkv_chunked(r, k, v, w, p["u_bonus"], s0, spec.chunk)

    from repro.models.layers import rms_norm

    out = rms_norm(out.reshape(b, s, d).astype(x.dtype), p["ln_w"])
    y = (out * g) @ p["wo"]
    new_cache = None
    if cache is not None:
        new_cache = {"shift": x[:, -1], "wkv": s_new}
    return y.astype(x.dtype), new_cache


def init_rwkv_cache(batch: int, d_model: int, spec: RWKVSpec, dtype) -> PyTree:
    h, hd = spec.heads(d_model), spec.head_dim
    return {
        "shift": jnp.zeros((batch, d_model), dtype),
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
    }


# --- RWKV channel mixing (squared-relu FFN with token shift) ---------------


def init_rwkv_ffn(key, d_model: int, d_ff: int, dtype) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    s = d_model**-0.5
    return {
        "mu": (0.5 * jnp.ones((2, d_model))).astype(dtype),
        "wk": (jax.random.normal(k1, (d_model, d_ff)) * s).astype(dtype),
        "wv": (jax.random.normal(k2, (d_ff, d_model)) * d_ff**-0.5).astype(dtype),
        "wr": (jax.random.normal(k3, (d_model, d_model)) * s).astype(dtype),
    }


def rwkv_ffn(
    p: PyTree, x: jax.Array, *, cache: PyTree | None = None
) -> tuple[jax.Array, PyTree | None]:
    """cache = {"shift": (B, d)}."""
    prev = cache["shift"] if cache is not None else None
    xp = _token_shift(x, prev)
    mu_k, mu_r = p["mu"][0][None, None], p["mu"][1][None, None]
    xk = x * mu_k + xp * (1 - mu_k)
    xr = x * mu_r + xp * (1 - mu_r)
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    y = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    new_cache = {"shift": x[:, -1]} if cache is not None else None
    return y.astype(x.dtype), new_cache
