"""rwkv6-3b ("Finch") — attention-free, data-dependent decay. [arXiv:2404.05892]

§Arch-applicability: DecAvg applies unchanged (gossip averages the full
param pytree); the WKV recurrent *state* is per-sequence and never gossiped.
long_500k runs natively (O(1) state per layer).
"""

from repro.configs.base import ArchConfig, LayerSpec
from repro.models.rwkv import RWKVSpec

CONFIG = ArchConfig(
    arch_id="rwkv6-3b",
    family="ssm",
    source="[arXiv:2404.05892]",
    num_layers=32,
    d_model=2560,
    num_heads=40,  # d_model / head_dim(64)
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    pattern=(LayerSpec("rwkv", "rwkv"),),
    rwkv=RWKVSpec(head_dim=64),
    num_nodes_single_pod=16,
    num_nodes_multi_pod=32,
)
