"""internvl2-76b — VLM backbone: InternLM2-style 80L GQA decoder.
[arXiv:2404.16821]

The InternViT-6B vision tower + MLP projector is a STUB (models/frontends.py):
the LM consumes projected patch embeddings as a continuous prefix
(``vlm_prefix_frac`` of the sequence) ahead of the text tokens.
"""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    arch_id="internvl2-76b",
    family="vlm",
    source="[arXiv:2404.16821]",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=1_000_000.0,
    pattern=(LayerSpec("attn", "dense"),),
    vlm_prefix_frac=0.25,
    optimizer="sgd",
    opt_dtype="bfloat16",
    num_nodes_single_pod=2,
    num_nodes_multi_pod=4,
)
