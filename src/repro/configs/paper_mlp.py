"""paper-mlp — the paper's own model: MLP(512, 256, 128) + ReLU on 784-dim
inputs, 10 classes, trained with SGD(lr=1e-3, momentum=0.5) under DecAvg
over 100-node ER/BA/SBM graphs. [the reproduced paper, §5.1]
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperMLPConfig:
    arch_id: str = "paper-mlp"
    family: str = "mlp"
    source: str = "[reproduced paper §5.1]"
    in_dim: int = 784
    hidden: tuple = (512, 256, 128)
    num_classes: int = 10
    num_nodes: int = 100
    lr: float = 1e-3
    momentum: float = 0.5
    local_epochs: int = 1
    batch_size: int = 32


CONFIG = PaperMLPConfig()
