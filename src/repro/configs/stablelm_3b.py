"""stablelm-3b — dense, MHA (kv == heads). [hf:stabilityai/stablelm-2-1_6b]"""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    arch_id="stablelm-3b",
    family="dense",
    source="[hf:stabilityai/stablelm-2-1_6b]",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    pattern=(LayerSpec("attn", "dense"),),
    num_nodes_single_pod=16,
    num_nodes_multi_pod=32,
)
