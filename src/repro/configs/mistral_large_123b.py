"""mistral-large-123b — dense GQA. [hf:mistralai/Mistral-Large-Instruct-2407]"""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    arch_id="mistral-large-123b",
    family="dense",
    source="[hf:mistralai/Mistral-Large-Instruct-2407]",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1_000_000.0,
    pattern=(LayerSpec("attn", "dense"),),
    # 123 B params: two full replicas (nodes) per 256-chip pod max — DESIGN §4.
    optimizer="sgd",
    opt_dtype="bfloat16",
    num_nodes_single_pod=2,
    num_nodes_multi_pod=4,
)
