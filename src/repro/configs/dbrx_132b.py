"""dbrx-132b — fine-grained MoE, 16 experts top-4. [hf:databricks/dbrx-base]"""

from repro.configs.base import ArchConfig, LayerSpec
from repro.models.moe import MoESpec

CONFIG = ArchConfig(
    arch_id="dbrx-132b",
    family="moe",
    source="[hf:databricks/dbrx-base]",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    pattern=(LayerSpec("attn", "moe"),),
    moe=MoESpec(num_experts=16, top_k=4, d_ff=10752),
    optimizer="sgd",
    opt_dtype="bfloat16",
    num_nodes_single_pod=2,
    num_nodes_multi_pod=4,
)
