"""Architecture / run configuration system.

``ArchConfig`` is the single source of truth consumed by model init/forward,
the launcher, the dry-run, and the roofline tool. One file per assigned
architecture lives next to this module; ``repro.configs.get(arch_id)``
resolves them, and every config cites its source in ``source``.

Layer stacking: ``pattern`` describes one *period* of layers (e.g. jamba's
7×mamba + 1×attn); the full stack is the pattern tiled ``num_layers /
len(pattern)`` times and executed as a ``lax.scan`` over the tiled groups —
so HLO size is O(period), not O(depth), which keeps 88-layer × 512-device
dry-run compiles tractable.

Node counts: how many DecAvg nodes (model replicas) a mesh hosts — bounded
by HBM, see DESIGN.md §4 for the math per architecture.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Literal

import jax.numpy as jnp

from repro.models.mamba import MambaSpec
from repro.models.moe import MoESpec
from repro.models.rwkv import RWKVSpec

Mixer = Literal["attn", "mamba", "rwkv"]
Ffn = Literal["dense", "moe", "rwkv", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: Mixer = "attn"
    ffn: Ffn = "dense"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
    source: str

    num_layers: int = 12
    d_model: int = 512
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 2048
    vocab_size: int = 32000
    rope_theta: float = 10000.0
    norm: Literal["rms", "ln"] = "rms"
    ffn_act: Literal["swiglu", "gelu"] = "swiglu"

    # Layer pattern (one period; tiled). Default: uniform attn+dense.
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)

    moe: MoESpec | None = None
    mamba: MambaSpec | None = None
    rwkv: RWKVSpec | None = None

    # Sliding-window width used by the long-context (long_500k) variant; the
    # dense 32k shapes use full attention unless ``always_window`` is set.
    sliding_window: int = 4096
    always_window: bool = False

    # Encoder-decoder (whisper): encoder layers share d_model/heads/d_ff.
    enc_dec: bool = False
    enc_layers: int = 0
    max_target_len: int = 448  # whisper decoder context

    # Modality frontends (stubs): number of continuous prefix embeddings the
    # LM consumes in place of that many tokens (vlm), or "all inputs are
    # frames" (audio encoder).
    vlm_prefix_frac: float = 0.0

    # Distribution / dtype policy.
    num_nodes_single_pod: int = 16
    num_nodes_multi_pod: int = 32
    param_dtype: str = "bfloat16"
    opt_dtype: str = "float32"
    # Cohort optimizer: "adamw" (2 f32 moments) or "sgd" (1 f32 momentum —
    # the paper's optimizer; used by the ≥50 B archs where AdamW state alone
    # would blow the per-device HBM budget, DESIGN §4).
    optimizer: str = "adamw"

    # Per-node batch used by smoke tests / examples (full shapes come from
    # repro.launch.shapes).
    smoke_batch: int = 2
    smoke_seq: int = 32

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.period == 0, (
            f"{self.arch_id}: num_layers {self.num_layers} not divisible by "
            f"pattern period {self.period}"
        )
        return self.num_layers // self.period

    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 periods, d_model<=256, <=4 experts."""
        d_model = min(self.d_model, 256)
        hd = 32
        heads = max(2, min(self.num_heads, d_model // hd))
        kv = heads if self.num_kv_heads == self.num_heads else max(1, heads // 2)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff=min(self.moe.d_ff, 448),
                dense_d_ff=min(self.moe.dense_d_ff, 448) if self.moe.dense_d_ff else 0,
            )
        rwkv = None
        if self.rwkv is not None:
            rwkv = dataclasses.replace(self.rwkv, head_dim=hd, decay_lora=16, chunk=8)
        mamba = None
        if self.mamba is not None:
            mamba = dataclasses.replace(self.mamba, d_state=8, chunk=8)
        return dataclasses.replace(
            self,
            arch_id=self.arch_id + "-reduced",
            num_layers=min(2 * self.period, self.num_layers),
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            enc_layers=min(self.enc_layers, 2),
            moe=moe,
            rwkv=rwkv,
            mamba=mamba,
            sliding_window=16,
            param_dtype="float32",
            num_nodes_single_pod=4,
            num_nodes_multi_pod=4,
        )


ASSIGNED_ARCHS = (
    "stablelm_3b",
    "mistral_large_123b",
    "jamba_v01_52b",
    "dbrx_132b",
    "arctic_480b",
    "llama32_1b",
    "minicpm_2b",
    "rwkv6_3b",
    "whisper_base",
    "internvl2_76b",
)

_ALIASES = {name.replace("_", "-"): name for name in ASSIGNED_ARCHS} | {
    "stablelm-3b": "stablelm_3b",
    "mistral-large-123b": "mistral_large_123b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "dbrx-132b": "dbrx_132b",
    "arctic-480b": "arctic_480b",
    "llama3.2-1b": "llama32_1b",
    "minicpm-2b": "minicpm_2b",
    "rwkv6-3b": "rwkv6_3b",
    "whisper-base": "whisper_base",
    "internvl2-76b": "internvl2_76b",
    "paper-mlp": "paper_mlp",
}


def get(arch_id: str) -> ArchConfig:
    mod_name = _ALIASES.get(arch_id, arch_id.replace("-", "_").replace(".", ""))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_arch_ids() -> tuple[str, ...]:
    return ASSIGNED_ARCHS
