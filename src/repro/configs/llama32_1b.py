"""llama3.2-1b — small llama3 dense GQA. [hf:meta-llama/Llama-3.2-1B]"""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    arch_id="llama3.2-1b",
    family="dense",
    source="[hf:meta-llama/Llama-3.2-1B]",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
    pattern=(LayerSpec("attn", "dense"),),
    num_nodes_single_pod=16,
    num_nodes_multi_pod=32,
)
