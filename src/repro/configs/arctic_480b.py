"""arctic-480b — 128-expert top-2 MoE with a parallel dense residual FFN.
[hf:Snowflake/snowflake-arctic-base]

At 482 B params a single replica needs bf16 optimizer state to fit a pod
(DESIGN §4): single-pod hosts 1 node (gossip degenerates to local training),
multi-pod hosts 2 (one per pod — the cross-silo configuration).
"""

from repro.configs.base import ArchConfig, LayerSpec
from repro.models.moe import MoESpec

CONFIG = ArchConfig(
    arch_id="arctic-480b",
    family="moe",
    source="[hf:Snowflake/snowflake-arctic-base]",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    pattern=(LayerSpec("attn", "moe"),),
    moe=MoESpec(
        num_experts=128, top_k=2, d_ff=4864, dense_residual=True, dense_d_ff=4864
    ),
    optimizer="sgd",
    num_nodes_single_pod=1,
    num_nodes_multi_pod=2,
    opt_dtype="bfloat16",
)
