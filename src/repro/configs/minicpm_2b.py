"""minicpm-2b — llama-like dense MHA, trained with the WSD schedule.
[arXiv:2404.06395]

The WSD (warmup-stable-decay) schedule is wired in optim/schedules.py and
selected by this config's ``lr_schedule`` hint (used by launch/train.py).
vocab 122753 is not divisible by the model axis (16); the sharding rules
fall back to replicating the vocab dim and sharding d_model for the
embedding/head of this arch.
"""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    arch_id="minicpm-2b",
    family="dense",
    source="[arXiv:2404.06395]",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    pattern=(LayerSpec("attn", "dense"),),
    num_nodes_single_pod=16,
    num_nodes_multi_pod=32,
)

LR_SCHEDULE = "wsd"
