"""jamba-v0.1-52b — hybrid Mamba+attention 7:1 with MoE every other layer.
[arXiv:2403.19887]

Pattern period = 8 layers (the Jamba block): one attention layer per period
(position 4, mirroring the paper's placement), Mamba elsewhere; MoE replaces
the dense FFN on every odd layer (16 experts, top-2).
"""

from repro.configs.base import ArchConfig, LayerSpec
from repro.models.mamba import MambaSpec
from repro.models.moe import MoESpec

_P = tuple(
    LayerSpec(
        mixer="attn" if i == 4 else "mamba",
        ffn="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)

CONFIG = ArchConfig(
    arch_id="jamba-v0.1-52b",
    family="hybrid",
    source="[arXiv:2403.19887]",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    pattern=_P,
    moe=MoESpec(num_experts=16, top_k=2, d_ff=14336),
    mamba=MambaSpec(d_state=16, d_conv=4, expand=2),
    optimizer="sgd",
    num_nodes_single_pod=2,
    num_nodes_multi_pod=4,
)
