"""whisper-base — encoder-decoder audio backbone. [arXiv:2212.04356]

The mel+conv frontend is a STUB (models/frontends.py): the encoder consumes
precomputed frame embeddings. LayerNorm + GELU FFN per the Whisper paper.
Decoder context is 448 tokens; the decode_32k / long_500k shapes are
architecturally synthetic for this model (see DESIGN.md §4) but are lowered
with a ring-buffer cache for completeness.
"""

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    arch_id="whisper-base",
    family="audio",
    source="[arXiv:2212.04356]",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    norm="ln",
    ffn_act="gelu",
    pattern=(LayerSpec("attn", "dense"),),
    enc_dec=True,
    enc_layers=6,
    max_target_len=448,
    num_nodes_single_pod=16,
    num_nodes_multi_pod=32,
)
