"""Batched serving: prefill + autoregressive decode loops over the model
zoo's ``decode_step``, plus greedy/temperature sampling.

``serve_step`` (one token for the whole batch) is what the decode_32k /
long_500k dry-run shapes lower; ``generate`` is the runnable CPU-scale loop
used by examples and tests.

Prefill has two implementations:

- ``prefill`` — the fast path: ONE full-sequence forward
  (``transformer.prefill_forward``) that writes the whole KV cache in a
  single shot, optionally through the Pallas flash-attention kernel.
- ``prefill_sequential`` — the reference path: token-at-a-time ``lax.scan``
  over ``decode_step`` (L kernel dispatches per prompt). Kept as the
  bit-for-bit definition of "what incremental decoding would have produced";
  ``bench_serve.py`` guards the chunked path at >=5x this one at seq>=128.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as TF

PyTree = Any


def cache_len_for(cfg: ArchConfig, seq_len: int, *, long_context: bool) -> int:
    """Ring-buffer length: full seq for exact attention, window for SWA."""
    if long_context or cfg.always_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def flash_ok(cfg: ArchConfig) -> bool:
    """True when every mixer in the pattern can route prefill attention
    through the flash kernel (attention-only; enc/dec cross-attn excluded)."""
    return not cfg.enc_dec and all(s.mixer == "attn" for s in cfg.pattern)


def prefill(
    params: PyTree,
    cfg: ArchConfig,
    prompt: jax.Array,
    cache: PyTree,
    *,
    length: jax.Array | None = None,
    memory: jax.Array | None = None,
    window: int | None = None,
    flash: bool | str = "auto",
) -> tuple[jax.Array, PyTree]:
    """Chunked prefill: the whole prompt in one forward, cache in one shot.

    ``flash="auto"`` uses the Pallas kernel on TPU (interpret mode is far
    slower than the reference path on CPU) when the pattern supports it.
    """
    if flash == "auto":
        from repro.kernels import ops as _ops

        flash = bool(_ops.on_tpu()) and flash_ok(cfg)
    return TF.prefill_forward(
        params, cfg, prompt, cache,
        length=length, memory=memory, window=window, flash=bool(flash),
    )


def prefill_sequential(
    params: PyTree,
    cfg: ArchConfig,
    prompt: jax.Array,
    cache: PyTree,
    *,
    memory: jax.Array | None = None,
    window: int | None = None,
) -> tuple[jax.Array, PyTree]:
    """Feed the prompt token-by-token through decode_step (exactly matches
    incremental decoding; the chunked ``prefill`` is benchmarked against
    this)."""

    def body(cache, tok):
        logits, cache = TF.decode_step(
            params, cfg, tok, cache, memory=memory, window=window
        )
        return cache, logits

    cache, logits = jax.lax.scan(body, cache, prompt.T)  # scan over seq
    return logits[-1], cache


@functools.partial(jax.jit, static_argnames=("cfg", "steps", "temperature"))
def generate(
    params: PyTree,
    cfg: ArchConfig,
    prompt: jax.Array,
    cache: PyTree,
    *,
    steps: int,
    key: jax.Array,
    temperature: float = 0.0,
    memory: jax.Array | None = None,
) -> jax.Array:
    """Greedy (temperature=0) or sampled generation. prompt: (B, S0)."""
    logits, cache = prefill(params, cfg, prompt, cache, memory=memory)

    def sample(logits, k):
        if temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, logits / temperature).astype(jnp.int32)

    def body(carry, k):
        logits, cache = carry
        tok = sample(logits, k)
        logits, cache = TF.decode_step(params, cfg, tok, cache, memory=memory)
        return (logits, cache), tok

    keys = jax.random.split(key, steps)
    (_, _), toks = jax.lax.scan(body, (logits, cache), keys)
    return toks.T  # (B, steps)
