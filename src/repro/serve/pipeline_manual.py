"""Fully-manual pipeline-parallel decode (EXPERIMENTS §Perf H3).

Like serve/pipeline.py but with hand-written tensor parallelism inside a
fully-manual ``shard_map`` over BOTH mesh axes — XLA's partial-manual GSPMD
mode CHECK-crashes at 256 devices (spmd_partitioner_util.cc:504), so nothing
is left to the auto-partitioner:

- `data` axis  = pipeline stages. Stage s owns layer groups
  [s*G/S, (s+1)*G/S); weights and KV cache never move; activation
  microgroups rotate via ``ppermute`` (GPipe rotation, all stages busy).
- `model` axis = megatron TP, manually: each rank owns H/16 query heads +
  its ffn column shard, contributes partial outputs, ``psum("model")`` after
  the attention out-projection and the FFN down-projection.
- KV cache: per-rank layout (G/S, B, T, 1, hd) — each TP rank stores exactly
  the one GQA KV head its query heads attend to (ranks_per_kv = 16/hkv
  duplicates; with int8 values + f32 scales this is what fits a 32k cache on
  v5e). Requires H % 16 == 0 and hkv <= 16.

Supported: decoder-only, uniform attention+dense pattern, num_groups %
stages == 0 (llama3.2-1b: 16/16, internvl2-76b: 80/16, stablelm-3b: 32/16,
minicpm-2b: 40/... 40 % 16 != 0 -> excluded, mistral 88 % 16 != 0 ->
excluded; see EXPERIMENTS §Perf H3 notes).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as TF
from repro.serve import gpipe

PyTree = Any


def _check(cfg: ArchConfig, tp: int) -> None:
    if cfg.enc_dec or cfg.family in ("ssm", "hybrid", "moe"):
        raise ValueError(f"{cfg.arch_id}: manual pipeline supports dense decoder-only")
    if cfg.num_heads % tp:
        raise ValueError(f"{cfg.arch_id}: H={cfg.num_heads} % tp={tp} != 0")
    if tp % cfg.num_kv_heads and cfg.num_kv_heads % tp:
        raise ValueError(f"{cfg.arch_id}: kv heads {cfg.num_kv_heads} vs tp {tp}")


def init_kv_cache(cfg: ArchConfig, batch: int, cache_len: int, tp: int) -> PyTree:
    """Global-view cache: (G, B, T, tp, hd) int8 + f32 scales; dim 3 shards
    over `model` so each rank holds its own KV-head slice."""
    shape = (cfg.num_groups, batch, cache_len, tp, cfg.hd)
    return {
        "k": jnp.zeros(shape, jnp.int8),
        "v": jnp.zeros(shape, jnp.int8),
        "k_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
        "v_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
        "index": jnp.zeros((cfg.num_groups,), jnp.int32),
    }


def cache_shardings(mesh) -> PyTree:
    kv = NamedSharding(mesh, P("data", None, None, "model", None))
    return {
        "k": kv, "v": kv,
        "k_scale": NamedSharding(mesh, P("data", None, None, "model", None)),
        "v_scale": NamedSharding(mesh, P("data", None, None, "model", None)),
        "index": NamedSharding(mesh, P("data")),
    }


def param_shardings(cfg: ArchConfig, mesh, params_shapes: PyTree) -> PyTree:
    """Pipeline layout: blocks' group axis over `data`; wq/wo + ffn over
    `model`; wk/wv REPLICATED (each rank computes all kv heads for one new
    token, then keeps its head — cheaper than half-head sharding)."""

    def one(path, leaf):
        parts = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        pstr = "/".join(parts)
        name = parts[-1]
        if pstr.startswith("blocks/"):
            spec = [None] * leaf.ndim
            spec[0] = "data"
            if name in ("wq", "w_gate", "w_in"):
                spec[-1] = "model"
            elif name in ("wo", "w_out"):
                spec[-2] = "model"
            # wk, wv, norms: replicated within the stage
            return NamedSharding(mesh, P(*spec))
        if name in ("embed", "lm_head"):
            spec = [None] * leaf.ndim
            spec[-1] = "model"  # d (embed) / V (head)
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def build_manual_pipeline_step(
    cfg: ArchConfig,
    mesh,
    *,
    window: int | None = None,
):
    """serve_step(params, token (B,), cache) -> (next_token (B,), cache)."""
    stages = mesh.shape["data"]
    tp = mesh.shape["model"]
    pods = mesh.shape.get("pod", 1)
    _check(cfg, tp)
    if cfg.num_groups % stages:
        raise ValueError(f"{cfg.arch_id}: {cfg.num_groups} groups % {stages} stages")
    qh = cfg.num_heads // tp  # query heads per rank
    hd = cfg.hd
    theta = cfg.rope_theta
    group = cfg.num_heads // cfg.num_kv_heads

    def layer_local(lp, x, kv, pos):
        """One manually-TP'd decoder layer on (mb, 1, d) for one group.
        kv: dict of local (B_sub, T, 1, hd)-squeezed slices for this rank."""
        r = jax.lax.axis_index("model")
        h = L.norm(x, lp["norm1"], cfg.norm)
        mb = x.shape[0]
        q = (h @ lp["attn"]["wq"]).reshape(mb, 1, qh, hd)  # local q heads
        k_full = (h @ lp["attn"]["wk"]).reshape(mb, 1, cfg.num_kv_heads, hd)
        v_full = (h @ lp["attn"]["wv"]).reshape(mb, 1, cfg.num_kv_heads, hd)
        my_kv = (r * qh) // group  # the kv head this rank's q heads use
        k_new = jax.lax.dynamic_index_in_dim(k_full, my_kv, axis=2, keepdims=True)
        v_new = jax.lax.dynamic_index_in_dim(v_full, my_kv, axis=2, keepdims=True)
        q = L.apply_rope(q, pos[None], theta)
        k_new = L.apply_rope(k_new, pos[None], theta)

        t = kv["k"].shape[1]
        slot = jnp.mod(pos, t)
        kq, ks = L._quant_kv(k_new)
        vq, vs = L._quant_kv(v_new)
        ck = jax.lax.dynamic_update_slice_in_dim(kv["k"], kq[:, :, 0], slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(kv["v"], vq[:, :, 0], slot, axis=1)
        cks = jax.lax.dynamic_update_slice_in_dim(kv["k_scale"], ks[:, :, 0], slot, axis=1)
        cvs = jax.lax.dynamic_update_slice_in_dim(kv["v_scale"], vs[:, :, 0], slot, axis=1)
        new_kv = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}

        keys = ck.astype(jnp.float32) * cks  # (mb, T, hd)
        vals = cv.astype(jnp.float32) * cvs
        slots = jnp.arange(t)
        kpos = pos + slots - slot - jnp.where(slots > slot, t, 0)
        kpos = jnp.where(kpos < 0, jnp.iinfo(jnp.int32).max, kpos)
        logits = jnp.einsum("mqhd,mtd->mhqt", q.astype(jnp.float32), keys) * hd**-0.5
        ok = kpos[None, None, None, :] <= pos
        if window is not None:
            ok &= kpos[None, None, None, :] > pos - window
        logits = jnp.where(ok, logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("mhqt,mtd->mqhd", probs, vals)  # (mb,1,qh,hd)
        partial = attn.reshape(mb, 1, qh * hd).astype(x.dtype) @ lp["attn"]["wo"]
        y = jax.lax.psum(partial, "model")
        x = x + y

        h = L.norm(x, lp["norm2"], cfg.norm)
        if cfg.ffn_act == "swiglu":
            f = (jax.nn.silu(h @ lp["ffn"]["w_gate"]) * (h @ lp["ffn"]["w_in"])) @ lp["ffn"]["w_out"]
        else:
            f = jax.nn.gelu(h @ lp["ffn"]["w_in"]) @ lp["ffn"]["w_out"]
        x = x + jax.lax.psum(f, "model")
        return x, new_kv

    def stage_fn(blocks, cache, embed_local, token):
        """Fully manual: blocks/cache local shards, embed_local (V, d/tp),
        token full (B_pod,)."""
        b = token.shape[0]
        mb = b // stages
        pos = cache["index"][0]  # shared absolute position

        # embed: d sharded over model -> all-gather the feature dim
        x_local = embed_local[token]  # (B, d/tp)
        x_all = jax.lax.all_gather(x_local, "model", axis=1, tiled=True)  # (B, d)
        x_groups = x_all.reshape(stages, mb, 1, -1).astype(cfg.dtype())

        def apply_stage(x, kv_stage):
            """Scan this stage's local groups. kv_stage: (G/S, mb, T, hd)..."""

            def body(x, scanned):
                lp = scanned["lp"]
                kv = scanned["kv"]
                x, new_kv = layer_local(lp["layer0"], x, kv, pos)
                return x, new_kv

            return jax.lax.scan(body, x, {"lp": blocks, "kv": kv_stage})

        kv_local = {
            k: cache[k][:, :, :, 0] for k in ("k", "v", "k_scale", "v_scale")
        }
        # kv_local has no index leaf (shared position bumps below), so the
        # microbatch slice/write run on every leaf — no skip predicate.
        xs, kv_local = gpipe.rotate(
            x_groups, kv_local, stages=stages,
            apply_fn=apply_stage,
            slice_fn=lambda c, m: gpipe.microbatch_slice(c, m, mb),
            write_fn=lambda c, new, m, act: gpipe.microbatch_write(
                c, new, m, mb, act
            ),
        )
        new_cache = {k: kv_local[k][:, :, :, None] for k in kv_local}
        new_cache["index"] = cache["index"] + 1
        return xs, new_cache

    def serve_step(params, token, cache):
        token_spec = P("pod") if pods > 1 else P()

        # per-leaf specs for the manual region
        def blk_spec(path, leaf):
            parts = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
            name = parts[-1]
            spec = [None] * leaf.ndim
            spec[0] = "data"
            if name in ("wq", "w_gate", "w_in"):
                spec[-1] = "model"
            elif name in ("wo", "w_out"):
                spec[-2] = "model"
            return P(*spec)

        blocks_specs = jax.tree_util.tree_map_with_path(blk_spec, params["blocks"])
        cache_specs = {
            "k": P("data", None, None, "model", None),
            "v": P("data", None, None, "model", None),
            "k_scale": P("data", None, None, "model", None),
            "v_scale": P("data", None, None, "model", None),
            "index": P("data"),
        }
        fn = jax.shard_map(
            stage_fn,
            mesh=mesh,
            in_specs=(blocks_specs, cache_specs, P(None, "model"), token_spec),
            out_specs=(token_spec if pods > 1 else P(), cache_specs),
            axis_names=frozenset(mesh.axis_names),
            # xs IS model-invariant (it follows two psum("model")s per layer)
            # but the conservative VMA inference cannot prove it.
            check_vma=False,
        )
        xs, new_cache = fn(params["blocks"], cache, params["embed"], token)
        h = L.norm(xs, params["final_norm"], cfg.norm)
        logits = (h @ params["lm_head"]).astype(jnp.float32)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

    return serve_step
