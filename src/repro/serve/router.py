"""Topology-aware routing: send each query to the cohort node that knows.

The paper's core result is that topology shapes WHERE knowledge ends up —
hubs absorb G2 (foreign-domain) patterns that leaves never see. At serving
time that asymmetry is actionable: a query about domain d should go to the
node whose model best covers d, which after gossip on a star/scale-free
graph is typically a hub, not the node that owns d's training stream.

``CohortRouter`` loads a trained cohort from the LM trainer's checkpoint
format (params-only — AdamW moments stay on disk, see
``ckpt.restore_subtree``), builds a (nodes × domains) coverage table by
scoring every node's model on every domain's held-out query stream (the
trainer's ``domain_acc`` quantity: mean true-next-token probability), and
routes each query to ``argmax_node coverage[node, domain(query)]``. The
query's domain is classified by token overlap with the per-node domain sets
(``data/tokens.node_domain`` — pure functions of the data seed, no side
channel from training).

Routing policies (the ``route=`` knob): ``"best"`` (coverage-table argmax),
``"round_robin"`` (topology-blind baseline), or an int node id (pinned).
The serve-eval smoke guards that "best" measurably beats round-robin on
foreign-domain queries.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data import tokens as tok
from repro.models import transformer as TF

PyTree = Any


def stacked_params_like(cfg: ArchConfig, nodes: int) -> PyTree:
    """ShapeDtypeStruct tree of a node-stacked ((N, ...) leaves) param tree —
    the ``like`` for a params-only checkpoint restore, built without running
    a single init FLOP."""
    per = jax.eval_shape(lambda k: TF.init_params(k, cfg), jax.random.PRNGKey(0))
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((nodes,) + l.shape, l.dtype), per
    )


def load_cohort(path: str, cfg: ArchConfig, *, nodes: int) -> tuple[PyTree, int | None]:
    """Load node-stacked params from an ``LMCohortTrainer.save`` checkpoint
    without materializing the optimizer moments. Returns (params, step)."""
    from repro.checkpoint import ckpt

    return ckpt.restore_subtree(path, stacked_params_like(cfg, nodes), prefix="params")


@functools.partial(jax.jit, static_argnames=("cfg",))
def _coverage(params: PyTree, cfg: ArchConfig, toks: jax.Array, labels: jax.Array):
    """(N-stacked params) × (D, B, S) queries -> (N, D) mean true-token
    probability of node i's model on domain j's query stream."""

    def one(p, tk, lb):
        logits, _ = TF.forward(p, cfg, tk)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, lb[..., None], axis=-1)[..., 0]
        return jnp.exp(ll).mean()

    per_node = jax.vmap(lambda p: jax.vmap(functools.partial(one, p))(toks, labels))
    return per_node(params)


class CohortRouter:
    """Routes queries over a trained cohort's node-stacked params.

    >>> router = CohortRouter.from_checkpoint(path, cfg, nodes=8, seed=0)
    >>> node = router.route(query_tokens)            # coverage argmax
    >>> node = router.route(query_tokens, route="round_robin")
    >>> params_i = router.node_params(node)          # feed Engine / generate
    """

    def __init__(
        self,
        params: PyTree,
        cfg: ArchConfig,
        *,
        seed: int = 0,
        domain_size: int = 64,
        coverage_batch: int = 4,
        coverage_seq: int = 16,
    ):
        self.params = params
        self.cfg = cfg
        self.nodes = int(jax.tree.leaves(params)[0].shape[0])
        self.seed = seed
        self.domains = np.stack(
            [
                tok.node_domain(i, cfg.vocab_size, seed=seed, domain_size=domain_size)
                for i in range(self.nodes)
            ]
        )  # (N, domain_size) — domain j IS node j's boosted token set
        qt, ql = zip(
            *(
                tok.domain_query_batch(
                    j, coverage_batch, coverage_seq, cfg.vocab_size,
                    seed=seed, domain_size=domain_size,
                )
                for j in range(self.nodes)
            )
        )
        self.coverage = np.asarray(
            _coverage(params, cfg, jnp.asarray(np.stack(qt)), jnp.asarray(np.stack(ql)))
        )  # (N nodes, D domains)
        self._rr = 0

    @classmethod
    def from_checkpoint(
        cls, path: str, cfg: ArchConfig, *, nodes: int, seed: int = 0, **kw
    ) -> "CohortRouter":
        params, _ = load_cohort(path, cfg, nodes=nodes)
        return cls(params, cfg, seed=seed, **kw)

    # -- routing -----------------------------------------------------------

    def classify(self, query) -> int:
        """Domain id of a query: the node-domain set with the largest token
        overlap (ties break toward the lower id, deterministically)."""
        q = np.asarray(query).reshape(-1)
        hits = (self.domains[:, :, None] == q[None, None, :]).any(axis=1)
        return int(hits.sum(axis=1).argmax())

    def route(self, query, *, route: str | int = "best", exclude=()) -> int:
        """Pick the serving node for one query under the given policy.

        ``exclude``: node ids unavailable for this query (offline / busy) —
        the case where topology-awareness earns its keep: with the domain's
        owner excluded, "best" falls through to whichever node gossip pushed
        that domain's knowledge to (on a star, the hub).
        """
        excluded = set(int(e) for e in exclude)
        if len(excluded) >= self.nodes:
            raise ValueError("every node excluded")
        if isinstance(route, (int, np.integer)):
            if not 0 <= route < self.nodes:
                raise ValueError(f"node id {route} out of range [0, {self.nodes})")
            return int(route)
        if route == "round_robin":
            while True:
                n, self._rr = self._rr, (self._rr + 1) % self.nodes
                if n not in excluded:
                    return n
        if route == "best":
            cov = self.coverage[:, self.classify(query)].copy()
            if excluded:
                cov[list(excluded)] = -np.inf
            return int(cov.argmax())
        raise ValueError(f"route must be 'best', 'round_robin' or a node id, got {route!r}")

    def node_params(self, node: int) -> PyTree:
        """Single-node param tree (leading N axis sliced off) — what
        ``Engine`` / ``decode.generate`` consume."""
        return jax.tree.map(lambda l: l[node], self.params)
