"""Continuous batching: a slot-based scheduler over a fixed-capacity KV cache.

The engine holds a batched per-slot cache (``init_cache(per_slot=True)``) of
``slots`` rows. Requests are admitted into free slots as they arrive (chunked
prefill into a single-row cache, scattered into the slot), every active slot
decodes one token per ``Engine.step`` through ONE jitted ``serve_step``, and
finished sequences retire by simply freeing the slot — no recompilation at
any point: the slot count is static, inactive slots decode garbage that the
host-side scheduler ignores, and a retired slot's cache rows are fully
overwritten on the next admission.

Compiled programs, total: one ``serve_step`` (per (slots, cache_len)), one
``_scatter_slot``, and one prefill per power-of-two prompt bucket — constant
regardless of arrival order, prompt mix, or completion order.

Restrictions: attention-only patterns (``engine_ok``). Recurrent mixers
(mamba/rwkv) carry prompt state through their scan paths, where right-padded
admission would corrupt the recurrent state; the ring-buffer attention cache
is padding-safe as long as the padded width never exceeds the ring length
(padded ring slots then sit at positions >= the written ``index`` and are
never attended) — ``submit`` rejects prompts longer than ``cache_len`` and
admission caps the pad bucket at ``cache_len``, so the safe regime is the
only one the engine can enter.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as TF
from repro.serve import decode as SD

PyTree = Any


def engine_ok(cfg: ArchConfig) -> bool:
    """True when cfg can serve through the continuous-batching engine:
    attention-only mixers (padding-safe ring cache), no encoder."""
    return not cfg.enc_dec and all(s.mixer == "attn" for s in cfg.pattern)


@functools.partial(jax.jit, static_argnames=("cfg", "temperature"))
def serve_step(
    params: PyTree,
    cfg: ArchConfig,
    tok: jax.Array,
    cache: PyTree,
    key: jax.Array,
    *,
    temperature: float = 0.0,
) -> tuple[jax.Array, PyTree]:
    """Decode ONE token for every slot at once. tok: (slots,) int32 last
    tokens; cache: per-slot batched cache. Returns (next_tok (slots,), cache).

    Inactive slots run through the same program (static shapes — this is what
    makes continuous batching recompile-free); the scheduler discards their
    output and overwrites their cache rows at the next admission.
    """
    logits, cache = TF.decode_step(params, cfg, tok, cache)
    if temperature == 0.0:
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        keys = jax.random.split(key, logits.shape[0])
        nxt = jax.vmap(
            lambda k, l: jax.random.categorical(k, l / temperature)
        )(keys, logits).astype(jnp.int32)
    return nxt, cache


@jax.jit
def _scatter_slot(cache: PyTree, row: PyTree, slot: jax.Array) -> PyTree:
    """Write a single-row cache (batch=1) into batch position ``slot`` of the
    batched cache. Leaves are (G, B, ...) / (G, B); row leaves (G, 1, ...)."""
    return jax.tree.map(lambda b, r: b.at[:, slot].set(r[:, 0]), cache, row)


def _bucket(n: int, lo: int = 8) -> int:
    """Round a prompt length up to a power of two (caps prefill recompiles
    at log2(max_prompt) programs)."""
    return max(lo, 1 << (n - 1).bit_length())


@dataclasses.dataclass
class _Slot:
    rid: int = -1
    remaining: int = 0
    tokens: list = dataclasses.field(default_factory=list)


class Engine:
    """Continuous-batching serving engine over one model.

    >>> eng = Engine(params, cfg, slots=4, cache_len=64)
    >>> rid = eng.submit([1, 2, 3], max_new=16)
    >>> for ev in iter(eng.step, []):  # or: out = eng.run()
    ...     ...  # ev: {"rid", "token", "done"} per active slot, stream order

    temperature=0 is greedy and token-identical to ``decode.generate`` on the
    same prompt (CI-guarded); temperature>0 samples per-slot.
    """

    def __init__(
        self,
        params: PyTree,
        cfg: ArchConfig,
        *,
        slots: int = 4,
        cache_len: int = 64,
        temperature: float = 0.0,
        flash: bool | str = "auto",
        seed: int = 0,
    ):
        if not engine_ok(cfg):
            raise ValueError(
                "continuous batching needs an attention-only pattern "
                f"(got {[s.mixer for s in cfg.pattern]}, enc_dec={cfg.enc_dec}): "
                "recurrent mixers cannot admit right-padded prompts"
            )
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.cache_len = cache_len
        self.temperature = temperature
        self.flash = flash
        self.cache = TF.init_cache(cfg, slots, cache_len, per_slot=True)
        self.last_tok = np.zeros(slots, np.int32)
        self._slots = [_Slot() for _ in range(slots)]
        self._free = deque(range(slots))
        self._pending: deque = deque()
        self._finished: dict[int, np.ndarray] = {}
        self._next_rid = 0
        self._key = jax.random.PRNGKey(seed)

    # -- scheduling --------------------------------------------------------

    def submit(self, prompt, *, max_new: int) -> int:
        """Queue a prompt; returns the request id. Non-blocking — the request
        is admitted into a slot by the next ``step`` with capacity.

        The prompt must fit the cache: admission pads it (never past
        ``cache_len``) and prefills the padded row into the ring, which is
        only padding-safe while padded width <= ring length — overflow would
        wrap padded K/V below the written index, where decode attends it as
        real context (silent corruption). Longer prompts need a bigger
        ``cache_len``. Generation PAST ``cache_len`` (prompt + max_new >
        cache_len) is safe but degrades to ring/window semantics: the oldest
        tokens are overwritten and fall out of the attention span.
        """
        rid = self._next_rid
        self._next_rid += 1
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size > self.cache_len:
            raise ValueError(
                f"prompt of {prompt.size} tokens does not fit "
                f"cache_len={self.cache_len}: padded prefill into the ring "
                "would silently drop prompt tokens and attend padding as "
                "real context — raise cache_len to at least the longest "
                "prompt"
            )
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        self._pending.append((rid, prompt, max_new))
        return rid

    def _admit(self) -> list[dict]:
        events = []
        while self._pending and self._free:
            rid, prompt, max_new = self._pending.popleft()
            slot = self._free.popleft()
            n = int(prompt.size)
            # Cap the pow2 bucket at cache_len: submit() guarantees
            # n <= cache_len, but the bucket above n can overshoot a
            # non-power-of-two cache_len, and padded width must never
            # exceed the ring (prefill_forward rejects that combination).
            padded = np.zeros((1, min(_bucket(n), self.cache_len)), np.int32)
            padded[0, :n] = prompt
            row = TF.init_cache(self.cfg, 1, self.cache_len, per_slot=True)
            logits, row = SD.prefill(
                self.params, self.cfg, jnp.asarray(padded), row,
                length=jnp.array([n], jnp.int32), flash=self.flash,
            )
            tok = self._sample(logits)[0]
            self.cache = _scatter_slot(self.cache, row, slot)
            self.last_tok[slot] = tok
            st = self._slots[slot]
            st.rid, st.remaining, st.tokens = rid, max_new - 1, [int(tok)]
            events.append({"rid": rid, "token": int(tok), "done": max_new == 1})
            if max_new == 1:
                self._retire(slot)
        return events

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.temperature == 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self._key, k = jax.random.split(self._key)
        return np.asarray(
            jax.random.categorical(k, logits / self.temperature), np.int32
        )

    def _retire(self, slot: int) -> None:
        st = self._slots[slot]
        self._finished[st.rid] = np.asarray(st.tokens, np.int32)
        self._slots[slot] = _Slot()
        self._free.append(slot)

    # -- decoding ----------------------------------------------------------

    def step(self) -> list[dict]:
        """Admit pending requests, decode one token on every active slot.
        Returns the streamed events ({"rid", "token", "done"}); [] when idle
        (nothing pending, nothing active) — so ``iter(eng.step, [])`` drains.
        """
        events = self._admit()
        active = [i for i, s in enumerate(self._slots) if s.rid >= 0]
        if not active:
            return events
        self._key, k = jax.random.split(self._key)
        nxt, self.cache = serve_step(
            self.params, self.cfg, jnp.asarray(self.last_tok), self.cache, k,
            temperature=self.temperature,
        )
        self.last_tok = np.array(nxt, np.int32)  # copy: jax views are read-only
        for i in active:
            st = self._slots[i]
            tok = int(self.last_tok[i])
            st.tokens.append(tok)
            st.remaining -= 1
            done = st.remaining <= 0
            events.append({"rid": st.rid, "token": tok, "done": done})
            if done:
                self._retire(i)
        return events

    def run(self) -> dict[int, np.ndarray]:
        """Drive until every submitted request has finished; returns
        {rid: generated tokens (max_new,)}."""
        while self._pending or any(s.rid >= 0 for s in self._slots):
            self.step()
        out, self._finished = self._finished, {}
        return out
