"""GPipe stage rotation shared by the pipeline-parallel decode variants.

Both pipeline serving layouts (auto-partitioned ``serve/pipeline.py`` and
fully-manual ``serve/pipeline_manual.py``) drive the same schedule: the batch
splits into S microgroups, stage 0 injects microgroup t at tick t, finished
microgroups leave from the last stage, activations hop stage->stage+1 via
``ppermute``, and 2S-1 ticks drain the whole batch — at steady state every
stage computes every tick. The two variants differ only in what ONE stage
does to its activations and cache shard; this module owns everything else.

Runs inside a ``shard_map``-manual region over the stage axis. Caller
supplies three callbacks:

- ``apply_fn(x, sub) -> (y, sub_new)``: this stage's layer groups on one
  microgroup's activations + its cache slice.
- ``slice_fn(cache, m) -> sub``: microgroup m's rows of the stage cache.
- ``write_fn(cache, sub_new, m, active) -> cache``: write them back (no-op
  rows when ``active`` is false — the warm-up/drain bubble).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


def microbatch_slice(
    tree: PyTree, m, mb: int, *, axis: int = 1, skip: Callable | None = None
) -> PyTree:
    """Rows [m*mb, (m+1)*mb) along ``axis`` of every leaf; leaves matching
    ``skip(path)`` pass through whole (e.g. shared ``index`` counters)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: l
        if (skip is not None and skip(p))
        else jax.lax.dynamic_slice_in_dim(l, m * mb, mb, axis=axis),
        tree,
    )


def microbatch_write(
    tree: PyTree,
    new: PyTree,
    m,
    mb: int,
    active,
    *,
    axis: int = 1,
    skip: Callable | None = None,
) -> PyTree:
    """Write a microgroup's updated rows back where ``active``; skipped
    leaves (and the bubble's inactive ticks) keep their old values."""

    def upd(p, full, sub_new):
        if skip is not None and skip(p):
            return full
        old = jax.lax.dynamic_slice_in_dim(full, m * mb, mb, axis=axis)
        val = jnp.where(active, sub_new, old)
        return jax.lax.dynamic_update_slice_in_dim(full, val, m * mb, axis=axis)

    return jax.tree_util.tree_map_with_path(upd, tree, new)


def rotate(
    x_groups: jax.Array,
    cache: PyTree,
    *,
    stages: int,
    apply_fn: Callable[[jax.Array, PyTree], tuple[jax.Array, PyTree]],
    slice_fn: Callable[[PyTree, jax.Array], PyTree],
    write_fn: Callable[[PyTree, PyTree, jax.Array, jax.Array], PyTree],
    axis: str = "data",
) -> tuple[jax.Array, PyTree]:
    """Run the full 2S-1-tick GPipe rotation on one stage.

    x_groups: (S, mb, 1, d) — stage 0's embedded microgroups (other stages
    receive the same array but never inject from it). Returns
    (xs (S*mb, d) — every microgroup's output, replicated over the stage
    axis via psum — and the updated stage cache).
    """
    s_idx = jax.lax.axis_index(axis)

    def tick(carry, t):
        x_cur, cache = carry
        # microgroup handled by this stage at tick t (GPipe rotation)
        m = t - s_idx
        active = jnp.logical_and(m >= 0, m < stages)
        m_c = jnp.clip(m, 0, stages - 1)
        # stage 0 injects microgroup t from the embedding at tick t
        inject = jnp.logical_and(s_idx == 0, jnp.logical_and(t >= 0, t < stages))
        x_in = jax.lax.dynamic_index_in_dim(
            x_groups, jnp.clip(t, 0, stages - 1), axis=0, keepdims=False
        )
        x_cur = jnp.where(inject, x_in, x_cur)
        sub = slice_fn(cache, m_c)
        y, sub_new = apply_fn(x_cur, sub)
        keep = active.astype(x_cur.dtype)
        x_out = y * keep + x_cur * (1 - keep)
        cache = write_fn(cache, sub_new, m_c, active)
        # collect finished microgroups at the last stage BEFORE permuting
        done = jnp.logical_and(s_idx == stages - 1, active)
        emit = jnp.where(done, x_out, jnp.zeros_like(x_out))
        x_next = jax.lax.ppermute(
            x_out, axis, [(i, (i + 1) % stages) for i in range(stages)]
        )
        return (x_next, cache), emit

    # carry becomes stage-varying after the first ppermute: mark it so
    x0 = jax.lax.pcast(jnp.zeros_like(x_groups[0]), (axis,), to="varying")
    (_, cache), emits = jax.lax.scan(tick, (x0, cache), jnp.arange(2 * stages - 1))
    # emits: (2S-1, mb, 1, d); microgroup m finished at tick m + (S-1) on
    # the last stage. Gather them into (S, mb, d) order.
    idx = jnp.arange(stages) + stages - 1
    xs = emits[idx, :, 0, :]  # (S, mb, d)
    # only the last stage emitted nonzero values: psum replicates them.
    xs = jax.lax.psum(xs, axis)
    return xs.reshape(stages * x_groups.shape[1], -1), cache
