"""Pipeline-parallel decode (EXPERIMENTS §Perf H3) — THE entry point.

The baseline serving layout shards weights over BOTH mesh axes (they must
coexist with the 32k KV cache), so every decoded token re-gathers the full
model over `data` — 8-14 GB of wire per step, 30-60x the compute term.

This module removes that traffic entirely: the `data` axis becomes a
PIPELINE axis. Stage s owns layer groups [s*G/S, (s+1)*G/S) — weights and
cache shards STAY PUT — and activations rotate through stages via
``jax.lax.ppermute`` (a few hundred KB per hop). The batch is split into S
microgroups rotated GPipe-style (the schedule lives in ``serve/gpipe.py``),
so at steady state every stage computes every tick; one call advances every
sequence in the batch by one token.

Two variants share that rotation; ``build_pipeline_step(cfg, mesh,
manual=...)`` is the one documented entry point:

- ``manual=False`` (this module's ``build_pipeline_serve_step``): stage axis
  manual, tensor parallelism inside a stage left to the auto-partitioner.
  Simplest, works for any uniform pattern the model zoo lowers.
- ``manual=True`` (``pipeline_manual.build_manual_pipeline_step``):
  hand-written megatron TP + per-rank int8 KV-head cache inside a fully
  manual shard_map — required at 256 devices, where partial-manual GSPMD
  CHECK-crashes (see pipeline_manual.py).

Constraints: uniform layer pattern (period tiles the stack), num_groups %
stages == 0, decoder-only (no cross-attention), batch % stages == 0.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import transformer as TF
from repro.serve import gpipe

PyTree = Any


def build_pipeline_step(cfg: ArchConfig, mesh, *, manual: bool = False, **kw):
    """One documented entry point for both pipeline-decode variants.

    Returns serve_step(params, token (B,), cache) -> (next_token, cache).
    ``manual=False`` needs the shardings from ``stage_shardings``;
    ``manual=True`` needs ``pipeline_manual.init_kv_cache`` /
    ``param_shardings`` (int8 per-rank KV layout). See module docstring for
    when each applies.
    """
    if manual:
        from repro.serve import pipeline_manual as PM

        return PM.build_manual_pipeline_step(cfg, mesh, **kw)
    return build_pipeline_serve_step(cfg, mesh, **kw)


def stage_shardings(cfg: ArchConfig, mesh, *, batch: int, kv_quant: bool):
    """NamedShardings: blocks' group axis over `data` (pipeline stages), TP
    dims over `model`; cache group axis over `data`, seq over `model`."""
    from repro.launch import sharding as SR

    params = jax.eval_shape(lambda: TF.init_params(jax.random.PRNGKey(0), cfg))

    def param_sh(path, leaf):
        pstr = SR._path_str(path)
        base = SR.leaf_spec(pstr, tuple(leaf.shape), cfg, mesh, has_node_axis=False)
        spec = list(base)
        if pstr.startswith("blocks/"):
            # kill any `data` FSDP the generic rule chose; stage axis owns it
            spec = [s if s not in ("data", ("data",)) else None for s in spec]
            spec[0] = "data"
        return NamedSharding(mesh, P(*spec))

    p_sh = jax.tree_util.tree_map_with_path(param_sh, params)

    cache = jax.eval_shape(
        lambda: TF.init_cache(cfg, batch, 0 or 1, kv_quant=kv_quant)
    )

    def cache_sh(path, leaf):
        pstr = SR._path_str(path)
        spec = [None] * leaf.ndim
        if leaf.ndim >= 1 and not pstr.endswith("index"):
            spec[0] = "data"  # group-stack axis = pipeline stage
        if (pstr.endswith("/k") or pstr.endswith("/v")) and leaf.ndim >= 3:
            if leaf.shape[2] % mesh.shape.get("model", 1) == 0:
                spec[2] = "model"  # cache seq dim
        return NamedSharding(mesh, P(*spec))

    c_sh = jax.tree_util.tree_map_with_path(cache_sh, cache)
    return params, p_sh, c_sh


def _is_index(path) -> bool:
    last = path[-1]
    return str(getattr(last, "key", last)) == "index"


def build_pipeline_serve_step(
    cfg: ArchConfig,
    mesh,
    *,
    stages: int | None = None,
    window: int | None = None,
):
    """Auto-partitioned-TP variant; prefer ``build_pipeline_step``.

    Must be jit'ed with the shardings from ``stage_shardings`` so the
    shard_map receives stage-local blocks.
    """
    stages = stages or mesh.shape["data"]
    if cfg.num_groups % stages:
        raise ValueError(f"{cfg.arch_id}: {cfg.num_groups} groups % {stages} stages != 0")
    if cfg.enc_dec:
        raise ValueError("pipeline decode supports decoder-only models")

    def stage_fn(blocks, cache, embed, token):
        """Runs on one stage. blocks/cache: stage-local (G/S, ...) shards;
        embed/final_norm/lm_head replicated over `data` (TP over model
        handled automatically); token: full (B,)."""
        b = token.shape[0]
        mb = b // stages

        # Stage 0 embeds its rotation of microgroups; others start with zeros.
        x_groups = embed[token].reshape(stages, mb, 1, -1)  # (S, mb, 1, d)

        def apply_local(x, sub):
            def body(x, scanned):
                x, new_c, _ = TF._apply_group(
                    scanned["gp"], x, cfg, window=window, cache=scanned["cache"],
                    cross=None, memory=None, positions=None,
                )
                return x, new_c

            return jax.lax.scan(body, x, {"gp": blocks, "cache": sub})

        # index leaves are shared across microgroups: sliced/written whole-
        # batch is wrong, so they pass through and bump once per serve_step.
        xs, cache = gpipe.rotate(
            x_groups, cache, stages=stages,
            apply_fn=apply_local,
            slice_fn=lambda c, m: gpipe.microbatch_slice(c, m, mb, skip=_is_index),
            write_fn=lambda c, new, m, act: gpipe.microbatch_write(
                c, new, m, mb, act, skip=_is_index
            ),
        )
        cache = jax.tree_util.tree_map_with_path(
            lambda p, l: l + 1 if _is_index(p) else l, cache
        )
        # (final norm + head run OUTSIDE the manual region: a model-sharded
        # matmul inside a partially-manual shard_map trips an XLA partitioner
        # CHECK at 256 devices.)
        return xs, cache

    def serve_step(params, token, cache):
        in_specs = (
            P("data"),  # blocks: group axis
            P("data"),  # cache: group axis
            P(),        # embed
            P(),        # token
        )
        # shard_map with per-leaf prefix specs: group axis manual over data,
        # everything else (model axis) stays automatic.
        fn = jax.shard_map(
            functools.partial(stage_fn),
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(), P("data")),
            axis_names=frozenset({"data"}),
        )
        xs, cache = fn(params["blocks"], cache, params["embed"], token)
        from repro.models import layers as L

        h = L.norm(xs, params["final_norm"], cfg.norm)
        logits = (h @ params["lm_head"]).astype(jnp.float32)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return serve_step
