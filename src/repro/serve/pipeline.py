"""Pipeline-parallel decode (EXPERIMENTS §Perf H3).

The baseline serving layout shards weights over BOTH mesh axes (they must
coexist with the 32k KV cache), so every decoded token re-gathers the full
model over `data` — 8-14 GB of wire per step, 30-60x the compute term.

This module removes that traffic entirely: the `data` axis becomes a
PIPELINE axis. Stage s owns layer groups [s*G/S, (s+1)*G/S) — weights and
cache shards STAY PUT — and activations rotate through stages via
``jax.lax.ppermute`` (a few hundred KB per hop). The batch is split into S
microgroups rotated GPipe-style, so at steady state every stage computes
every tick; one call advances every sequence in the batch by one token.

Constraints: uniform layer pattern (period tiles the stack), num_groups %
stages == 0, decoder-only (no cross-attention), batch % stages == 0.
Weights within a stage stay tensor-parallel over `model`.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import transformer as TF

PyTree = Any


def stage_shardings(cfg: ArchConfig, mesh, *, batch: int, kv_quant: bool):
    """NamedShardings: blocks' group axis over `data` (pipeline stages), TP
    dims over `model`; cache group axis over `data`, seq over `model`."""
    from repro.launch import sharding as SR

    params = jax.eval_shape(lambda: TF.init_params(jax.random.PRNGKey(0), cfg))

    def param_sh(path, leaf):
        pstr = SR._path_str(path)
        base = SR.leaf_spec(pstr, tuple(leaf.shape), cfg, mesh, has_node_axis=False)
        spec = list(base)
        if pstr.startswith("blocks/"):
            # kill any `data` FSDP the generic rule chose; stage axis owns it
            spec = [s if s not in ("data", ("data",)) else None for s in spec]
            spec[0] = "data"
        return NamedSharding(mesh, P(*spec))

    p_sh = jax.tree_util.tree_map_with_path(param_sh, params)

    cache = jax.eval_shape(
        lambda: TF.init_cache(cfg, batch, 0 or 1, kv_quant=kv_quant)
    )

    def cache_sh(path, leaf):
        pstr = SR._path_str(path)
        spec = [None] * leaf.ndim
        if leaf.ndim >= 1 and not pstr.endswith("index"):
            spec[0] = "data"  # group-stack axis = pipeline stage
        if (pstr.endswith("/k") or pstr.endswith("/v")) and leaf.ndim >= 3:
            if leaf.shape[2] % mesh.shape.get("model", 1) == 0:
                spec[2] = "model"  # cache seq dim
        return NamedSharding(mesh, P(*spec))

    c_sh = jax.tree_util.tree_map_with_path(cache_sh, cache)
    return params, p_sh, c_sh


def build_pipeline_serve_step(
    cfg: ArchConfig,
    mesh,
    *,
    stages: int | None = None,
    window: int | None = None,
):
    """Returns serve_step(params, token (B,), cache) -> (next_token, cache).

    Must be jit'ed with the shardings from ``stage_shardings`` so the
    shard_map receives stage-local blocks.
    """
    stages = stages or mesh.shape["data"]
    if cfg.num_groups % stages:
        raise ValueError(f"{cfg.arch_id}: {cfg.num_groups} groups % {stages} stages != 0")
    if cfg.enc_dec:
        raise ValueError("pipeline decode supports decoder-only models")
    local_groups = cfg.num_groups // stages
    other_axes = tuple(a for a in mesh.axis_names if a != "data")

    def _is_index(path) -> bool:
        last = path[-1]
        return str(getattr(last, "key", last)) == "index"

    def stage_fn(blocks, cache, embed, token):
        """Runs on one stage. blocks/cache: stage-local (G/S, ...) shards;
        embed/final_norm/lm_head replicated over `data` (TP over model
        handled automatically); token: full (B,)."""
        s_idx = jax.lax.axis_index("data")
        b = token.shape[0]
        mb = b // stages

        # Stage 0 embeds its rotation of microgroups; others start with zeros.
        x_groups = embed[token].reshape(stages, mb, 1, -1)  # (S, mb, 1, d)

        tmap = jax.tree_util.tree_map_with_path

        def slice_mb(cache, m):
            """Batch rows [m*mb, (m+1)*mb) of every (G/S, B, ...) leaf;
            index leaves pass through (shared across microgroups)."""
            return tmap(
                lambda p, l: l
                if _is_index(p)
                else jax.lax.dynamic_slice_in_dim(l, m * mb, mb, axis=1),
                cache,
            )

        def write_mb(cache, sub_new, m, active):
            """Write the microgroup's updated KV rows back (only if active);
            index leaves are NOT advanced here — every microgroup decodes the
            same position, so the shared index bumps once after all ticks."""

            def upd(p, full, new):
                if _is_index(p):
                    return full
                old = jax.lax.dynamic_slice_in_dim(full, m * mb, mb, axis=1)
                val = jnp.where(active, new, old)
                return jax.lax.dynamic_update_slice_in_dim(full, val, m * mb, axis=1)

            return tmap(upd, cache, sub_new)

        def apply_local(x, sub):
            def body(x, scanned):
                x, new_c, _ = TF._apply_group(
                    scanned["gp"], x, cfg, window=window, cache=scanned["cache"],
                    cross=None, memory=None, positions=None,
                )
                return x, new_c

            return jax.lax.scan(body, x, {"gp": blocks, "cache": sub})

        def tick(carry, t):
            x_cur, cache = carry
            # microgroup handled by this stage at tick t (GPipe rotation)
            m = t - s_idx
            active = jnp.logical_and(m >= 0, m < stages)
            m_c = jnp.clip(m, 0, stages - 1)
            # stage 0 injects microgroup t from the embedding at tick t
            inject = jnp.logical_and(s_idx == 0, jnp.logical_and(t >= 0, t < stages))
            x_in = jax.lax.dynamic_index_in_dim(
                x_groups, jnp.clip(t, 0, stages - 1), axis=0, keepdims=False
            )
            x_cur = jnp.where(inject, x_in, x_cur)
            sub = slice_mb(cache, m_c)
            y, sub_new = apply_local(x_cur, sub)
            keep = active.astype(x_cur.dtype)
            x_out = y * keep + x_cur * (1 - keep)
            cache = write_mb(cache, sub_new, m_c, active)
            # collect finished microgroups at the last stage BEFORE permuting
            done = jnp.logical_and(s_idx == stages - 1, active)
            emit = jnp.where(done, x_out, jnp.zeros_like(x_out))
            x_next = jax.lax.ppermute(
                x_out, "data", [(i, (i + 1) % stages) for i in range(stages)]
            )
            return (x_next, cache), emit

        # carry becomes stage-varying after the first ppermute: mark it so
        x0 = jax.lax.pcast(jnp.zeros_like(x_groups[0]), ("data",), to="varying")
        (_, cache), emits = jax.lax.scan(
            tick, (x0, cache), jnp.arange(2 * stages - 1)
        )
        # shared position advances once per serve_step
        cache = tmap(lambda p, l: l + 1 if _is_index(p) else l, cache)
        # emits: (2S-1, mb, 1, d); microgroup m finished at tick m + (S-1) on
        # the last stage. Gather them into (S, mb, d) order.
        idx = jnp.arange(stages) + stages - 1
        xs = emits[idx, :, 0, :]  # (S, mb, d)
        # only the last stage emitted nonzero values: psum replicates them.
        # (final norm + head run OUTSIDE the manual region: a model-sharded
        # matmul inside a partially-manual shard_map trips an XLA partitioner
        # CHECK at 256 devices.)
        xs = jax.lax.psum(xs, "data")
        return xs.reshape(b, -1), cache

    def serve_step(params, token, cache):
        in_specs = (
            P("data"),  # blocks: group axis
            P("data"),  # cache: group axis
            P(),        # embed
            P(),        # token
        )
        # shard_map with per-leaf prefix specs: group axis manual over data,
        # everything else (model axis) stays automatic.
        fn = jax.shard_map(
            functools.partial(stage_fn),
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(), P("data")),
            axis_names=frozenset({"data"}),
        )
        xs, cache = fn(params["blocks"], cache, params["embed"], token)
        from repro.models import layers as L

        h = L.norm(xs, params["final_norm"], cfg.norm)
        logits = (h @ params["lm_head"]).astype(jnp.float32)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return serve_step
