"""Sharding-aware per-node batching.

Builds node-stacked arrays from per-node index sets (core/partition.py) so
the vmapped/sharded local-training step sees a uniform (N, B, ...) batch
every step. Nodes with differently-sized datasets sample with replacement
per round from their own pool — matching the paper's "equal share per
assigned class" setup where nodes holding extra classes simply have more
local data (their epoch covers more batches; we keep steps uniform and let
alpha_ij in the mixing matrix carry the |D_j| weighting, as Eq. 1 does).
"""

from __future__ import annotations

import numpy as np

__all__ = ["NodeLoader"]


class NodeLoader:
    def __init__(
        self,
        x: np.ndarray,
        y: np.ndarray,
        parts: list[np.ndarray],
        *,
        batch_size: int,
        seed: int = 0,
    ):
        self.x, self.y = x, y
        self.parts = parts
        self.batch = batch_size
        self.rng = np.random.default_rng(seed)
        self.num_nodes = len(parts)
        self.sizes = np.array([len(p) for p in parts], dtype=np.int64)

    def steps_per_epoch(self) -> int:
        """Uniform local steps per round: one pass of the *median* node."""
        return max(1, int(np.median(self.sizes)) // self.batch)

    def sample_round(self, steps: int):
        """(steps, N, B, ...) batches, sampled per node with replacement."""
        xs = np.empty((steps, self.num_nodes, self.batch) + self.x.shape[1:], self.x.dtype)
        ys = np.empty((steps, self.num_nodes, self.batch), self.y.dtype)
        for n, p in enumerate(self.parts):
            if len(p) == 0:
                raise ValueError(f"node {n} has an empty dataset")
            idx = self.rng.choice(p, size=(steps, self.batch), replace=True)
            xs[:, n] = self.x[idx]
            ys[:, n] = self.y[idx]
        return xs, ys
