"""Sharding-aware per-node batching.

Builds node-stacked arrays from per-node index sets (core/partition.py) so
the vmapped/sharded local-training step sees a uniform (N, B, ...) batch
every step. Nodes with differently-sized datasets sample with replacement
per round from their own pool — matching the paper's "equal share per
assigned class" setup where nodes holding extra classes simply have more
local data (their epoch covers more batches; we keep steps uniform and let
alpha_ij in the mixing matrix carry the |D_j| weighting, as Eq. 1 does).

Two sampling modes share one index-generation rule:

- host (``sample_round(steps)``): the legacy stateful numpy-RNG path.
- round-keyed (``sample_round(steps, round=r)`` and the fused trainer's
  in-scan sampler): batch indices are a *pure function* of
  ``(seed, round)`` via ``round_batch_indices`` (jax.random, so the exact
  same bits come out on host and inside a jitted ``lax.scan``). This is
  what makes the fused single-scan run and the Python loop draw identical
  batches — the fused-vs-loop equivalence tests rest on it.

``device_data()`` stages the dataset once as device arrays (the full
(T, D) image bank plus padded per-node index pools, O(T·D + N·M) memory —
not the O(N·M·D) a per-node copy would cost), so the fused path never
transfers batches from the host.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["NodeLoader", "DeviceData", "round_batch_indices"]


class DeviceData(NamedTuple):
    """The loader's dataset staged on device for in-scan sampling.

    x/y are the *shared* banks; ``parts`` maps (node, pool position) to a
    bank row (zero-padded past ``sizes[n]`` — round_batch_indices never
    produces an index beyond the node's true pool). ``key`` seeds the
    round-keyed batch sampler.
    """

    x: jax.Array  # (T, ...) full image bank
    y: jax.Array  # (T,) int32 labels
    parts: jax.Array  # (N, M) int32 rows of x/y per node, zero-padded
    sizes: jax.Array  # (N,) int32 true pool sizes
    key: jax.Array  # PRNG key (pure function of the loader seed)


def round_batch_indices(
    key: jax.Array, round: int | jax.Array, steps: int, batch: int, sizes: jax.Array
) -> jax.Array:
    """(steps, N, B) with-replacement pool positions for one round.

    Pure function of ``(key, round)`` — jax.random is deterministic across
    the jit boundary, so the Python loop (eager, host) and the fused scan
    body (traced, ``round`` a tracer) draw bit-identical indices. Positions
    are uniform over ``[0, sizes[n])`` per node via a modulo draw from the
    full int32 range (bias O(size / 2^31), far below sampling noise).
    """
    k = jax.random.fold_in(key, round)
    raw = jax.random.randint(
        k, (steps, sizes.shape[0], batch), 0, jnp.iinfo(jnp.int32).max, dtype=jnp.int32
    )
    return raw % sizes[None, :, None]


class NodeLoader:
    def __init__(
        self,
        x: np.ndarray,
        y: np.ndarray,
        parts: list[np.ndarray],
        *,
        batch_size: int,
        seed: int = 0,
    ):
        self.x, self.y = x, y
        self.parts = parts
        self.batch = batch_size
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.num_nodes = len(parts)
        self.sizes = np.array([len(p) for p in parts], dtype=np.int64)
        self._device_data: DeviceData | None = None
        self._sampler_state: tuple[jax.Array, jax.Array] | None = None

    def steps_per_epoch(self) -> int:
        """Uniform local steps per round: one pass of the *median* node."""
        return max(1, int(np.median(self.sizes)) // self.batch)

    def _check_nonempty(self) -> None:
        empty = np.flatnonzero(self.sizes == 0)
        if empty.size:
            raise ValueError(f"node {int(empty[0])} has an empty dataset")

    def device_data(self) -> DeviceData:
        """Stage the dataset as device arrays once (cached); see DeviceData."""
        if self._device_data is None:
            self._check_nonempty()
            m = int(self.sizes.max())
            pools = np.zeros((self.num_nodes, m), dtype=np.int32)
            for n, p in enumerate(self.parts):
                pools[n, : len(p)] = p
            self._device_data = DeviceData(
                x=jnp.asarray(self.x),
                y=jnp.asarray(self.y.astype(np.int32)),
                parts=jnp.asarray(pools),
                sizes=jnp.asarray(self.sizes.astype(np.int32)),
                key=jax.random.PRNGKey(self.seed),
            )
        return self._device_data

    def sample_round(self, steps: int, *, round: int | None = None):
        """(steps, N, B, ...) batches, sampled per node with replacement.

        With ``round`` given, indices come from the round-keyed pure sampler
        (identical draws to the fused in-scan path — and re-calling with the
        same round re-yields the same batches). Without it, the legacy
        stateful numpy RNG path is used.
        """
        self._check_nonempty()
        xs = np.empty((steps, self.num_nodes, self.batch) + self.x.shape[1:], self.x.dtype)
        ys = np.empty((steps, self.num_nodes, self.batch), self.y.dtype)
        if round is not None:
            if self._sampler_state is None:  # key/sizes staged once, not per round
                self._sampler_state = (
                    jax.random.PRNGKey(self.seed),
                    jnp.asarray(self.sizes.astype(np.int32)),
                )
            key, sizes = self._sampler_state
            idx = np.asarray(
                round_batch_indices(key, round, steps, self.batch, sizes)
            )
            for n, p in enumerate(self.parts):
                rows = p[idx[:, n]]
                xs[:, n] = self.x[rows]
                ys[:, n] = self.y[rows]
            return xs, ys
        for n, p in enumerate(self.parts):
            i = self.rng.choice(p, size=(steps, self.batch), replace=True)
            xs[:, n] = self.x[i]
            ys[:, n] = self.y[i]
        return xs, ys
