"""Synthetic LM token pipeline for the LLM-cohort runner and smoke tests.

Zipf-distributed unigrams with a per-node "domain" bias: node i's stream
mixes a shared zipf background with a node-specific set of boosted tokens —
the LLM analogue of the paper's non-IID label skew (different nodes see
different data modes; gossip must spread the knowledge).

The zipf background is truncated to the vocab by rejection resampling: a
``zipf % vocab`` fold would alias the unbounded tail onto arbitrary token
ids and flatten the intended head-heavy shape (at ``a=1.2`` and a 512-token
vocab ~30% of the mass lands in the tail).

Every batch is a pure function of ``(seed, node, round)`` — the Python loop
and the fused ``lax.scan`` path draw bit-identical tokens, a resumed run
re-derives exactly the batches the interrupted run would have seen, and the
fused path can stage one chunk of rounds at a time (``round_token_slab``)
instead of materializing the whole O(rounds·N·B·S) stream up front.

Labels are next-token (shifted) — standard causal LM objective.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "token_batches",
    "node_token_stream",
    "node_domain",
    "round_token_batch",
    "round_token_slab",
    "domain_eval_batch",
    "domain_query_batch",
]

# Seed-sequence stream tags: np.random.default_rng hashes the full tuple, so
# the per-round training draws, the fixed domain sets, and the held-out
# domain-eval draws are independent streams of one (seed, node) lineage.
_STREAM_TRAIN = 0
_STREAM_DOMAIN = 1
_STREAM_EVAL = 2
_STREAM_QUERY = 3


def _zipf_tokens(
    rng: np.random.Generator, a: float, size: int, vocab: int, *, max_tries: int = 32
) -> np.ndarray:
    """Truncated-zipf token ids in ``[0, vocab)``.

    Rejection-resamples draws past the vocab instead of folding them back
    with ``%``, so the head-heavy ordering (P(0) > P(1) > ...) survives
    truncation exactly. The residual tail after ``max_tries`` redraw passes
    (~0.3^32 of the mass at a=1.2, vocab=512) is clamped to the last token.
    """
    draw = rng.zipf(a, size=size).astype(np.int64)
    for _ in range(max_tries):
        bad = draw > vocab
        n_bad = int(bad.sum())
        if not n_bad:
            break
        draw[bad] = rng.zipf(a, size=n_bad).astype(np.int64)
    np.minimum(draw, vocab, out=draw)
    return draw - 1  # zipf support starts at 1


def node_domain(
    node: int, vocab: int, *, seed: int, domain_size: int = 64
) -> np.ndarray:
    """Node ``node``'s boosted "domain" token set — fixed for the whole run.

    Drawn from a dedicated stream so training batches, however many rounds
    are generated, never perturb which tokens a node's domain holds.
    """
    rng = np.random.default_rng((seed, node, _STREAM_DOMAIN))
    return rng.integers(0, vocab, size=domain_size)


def node_token_stream(
    node: int,
    length: int,
    vocab: int,
    *,
    seed: int,
    zipf_a: float = 1.2,
    domain_frac: float = 0.3,
    domain_size: int = 64,
) -> np.ndarray:
    """Token stream for one node: zipf background + node-domain boosts."""
    rng = np.random.default_rng((seed, node, _STREAM_TRAIN))
    bg = _zipf_tokens(rng, zipf_a, length, vocab)
    domain = node_domain(node, vocab, seed=seed, domain_size=domain_size)
    mask = rng.random(length) < domain_frac
    bg[mask] = domain[rng.integers(0, domain_size, size=int(mask.sum()))]
    return bg


def round_token_batch(
    num_nodes: int,
    round: int,
    batch: int,
    seq: int,
    vocab: int,
    *,
    seed: int = 0,
    zipf_a: float = 1.2,
    domain_frac: float = 0.3,
    domain_size: int = 64,
) -> tuple[np.ndarray, np.ndarray]:
    """One round's (tokens, labels), each (N, B, S) int32.

    A pure function of ``(seed, node, round)``: the per-round generator both
    run paths (and checkpoint resume) key their draws from.
    """
    chunk = batch * (seq + 1)
    toks = np.empty((num_nodes, batch, seq + 1), np.int32)
    for node in range(num_nodes):
        rng = np.random.default_rng((seed, node, _STREAM_TRAIN, round))
        bg = _zipf_tokens(rng, zipf_a, chunk, vocab)
        domain = node_domain(node, vocab, seed=seed, domain_size=domain_size)
        mask = rng.random(chunk) < domain_frac
        bg[mask] = domain[rng.integers(0, domain_size, size=int(mask.sum()))]
        toks[node] = bg.reshape(batch, seq + 1)
    return toks[:, :, :-1], toks[:, :, 1:]


def round_token_slab(
    num_nodes: int,
    rounds,
    batch: int,
    seq: int,
    vocab: int,
    *,
    seed: int = 0,
    **kw,
) -> tuple[np.ndarray, np.ndarray]:
    """Stack ``round_token_batch`` over a chunk of rounds: (L, N, B, S) x2.

    The fused lm path's DeviceData-style staging unit: one slab per scan
    chunk rides in as the scan's xs, so device memory holds O(chunk) rounds
    of tokens instead of the whole run.
    """
    ts, ls = zip(
        *(
            round_token_batch(
                num_nodes, int(r), batch, seq, vocab, seed=seed, **kw
            )
            for r in rounds
        )
    )
    return np.stack(ts), np.stack(ls)


def domain_eval_batch(
    num_nodes: int,
    batch: int,
    seq: int,
    vocab: int,
    *,
    seed: int = 0,
    domain_size: int = 64,
) -> tuple[np.ndarray, np.ndarray]:
    """Held-out per-node eval set of *other* nodes' domain tokens.

    Row i holds (B, S) sequences drawn uniformly from the concatenation of
    every domain set except node i's own — the token-task analogue of the
    mlp path's G2-spread eval (how well does node i model the data modes it
    never trained on?). Drawn from a dedicated stream, so it is disjoint
    from every training draw at any seed.
    """
    if num_nodes < 2:
        raise ValueError("domain_eval_batch needs >= 2 nodes (foreign domains)")
    domains = np.stack(
        [
            node_domain(i, vocab, seed=seed, domain_size=domain_size)
            for i in range(num_nodes)
        ]
    )
    toks = np.empty((num_nodes, batch, seq + 1), np.int32)
    for i in range(num_nodes):
        rng = np.random.default_rng((seed, i, _STREAM_EVAL))
        foreign = np.delete(domains, i, axis=0).reshape(-1)
        draw = foreign[rng.integers(0, foreign.size, size=batch * (seq + 1))]
        toks[i] = draw.reshape(batch, seq + 1)
    return toks[:, :, :-1], toks[:, :, 1:]


def domain_query_batch(
    domain_node: int,
    batch: int,
    seq: int,
    vocab: int,
    *,
    seed: int = 0,
    domain_size: int = 64,
    query_round: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Serve-time queries "about" one node's domain: (B, S) (tokens, labels)
    drawn uniformly from node ``domain_node``'s domain set.

    The router-eval analogue of ``domain_eval_batch``: a query stream whose
    token domain is known by construction, so serve accuracy can be compared
    across routing policies (does routing to the hub that *covers* this
    domain beat round-robin?). Dedicated stream tag + ``query_round`` keep
    the draws disjoint from training/eval and from each other.
    """
    dom = node_domain(domain_node, vocab, seed=seed, domain_size=domain_size)
    rng = np.random.default_rng((seed, domain_node, _STREAM_QUERY, query_round))
    draw = dom[rng.integers(0, dom.size, size=batch * (seq + 1))]
    toks = draw.reshape(batch, seq + 1).astype(np.int32)
    return toks[:, :-1], toks[:, 1:]


def token_batches(
    num_nodes: int,
    batch: int,
    seq: int,
    vocab: int,
    *,
    steps: int,
    seed: int = 0,
):
    """Yield ``steps`` batches of (tokens, labels), each (N, B, S) int32.

    Thin generator over ``round_token_batch`` — O(N·B·S) live memory
    regardless of ``steps`` (the pre-PR-8 version materialized every node's
    full stream up front).
    """
    for s in range(steps):
        yield round_token_batch(num_nodes, s, batch, seq, vocab, seed=seed)
