"""Synthetic LM token pipeline for the LLM-cohort examples and smoke tests.

Zipf-distributed unigrams with a per-node "domain" bias: node i's stream
mixes a shared zipf background with a node-specific set of boosted tokens —
the LLM analogue of the paper's non-IID label skew (different nodes see
different data modes; gossip must spread the knowledge).

Labels are next-token (shifted) — standard causal LM objective.
"""

from __future__ import annotations

import numpy as np

__all__ = ["token_batches", "node_token_stream"]


def node_token_stream(
    node: int,
    length: int,
    vocab: int,
    *,
    seed: int,
    zipf_a: float = 1.2,
    domain_frac: float = 0.3,
    domain_size: int = 64,
) -> np.ndarray:
    """Token stream for one node: zipf background + node-domain boosts."""
    rng = np.random.default_rng(seed * 100003 + node)
    bg = rng.zipf(zipf_a, size=length).astype(np.int64) % vocab
    domain = rng.integers(0, vocab, size=domain_size)
    mask = rng.random(length) < domain_frac
    bg[mask] = domain[rng.integers(0, domain_size, size=int(mask.sum()))]
    return bg


def token_batches(
    num_nodes: int,
    batch: int,
    seq: int,
    vocab: int,
    *,
    steps: int,
    seed: int = 0,
):
    """Yield ``steps`` batches of (tokens, labels), each (N, B, S) int32."""
    streams = [
        node_token_stream(n, steps * batch * (seq + 1), vocab, seed=seed)
        for n in range(num_nodes)
    ]
    for s in range(steps):
        toks = np.stack(
            [
                st[s * batch * (seq + 1) : (s + 1) * batch * (seq + 1)].reshape(
                    batch, seq + 1
                )
                for st in streams
            ]
        ).astype(np.int32)
        yield toks[:, :, :-1], toks[:, :, 1:]
