"""Deterministic MNIST-like synthetic dataset.

The container is offline (no MNIST download — the repro=2 data gate, see
DESIGN.md §2), so the reproduction uses a *structured* stand-in with the same
interface: 10 classes, 784-dim inputs in [0, 1], train/test splits.

Construction: each class c gets a fixed random prototype p_c (seeded
independently of the sampling seed) plus a class-specific low-rank "style"
subspace B_c; a sample is  clip(p_c + B_c z + eps)  with z ~ N(0, I_r),
eps ~ N(0, sigma^2).  Within-class variation is real (an MLP must learn more
than a nearest-prototype rule, and test accuracy saturates below 100%), and
classes a node never sees are unpredictable without gossip — which is the
property the paper's knowledge-spread experiments need.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Dataset", "make_mnist_like"]

_PROTO_SEED = 1234567


@dataclasses.dataclass(frozen=True)
class Dataset:
    x_train: np.ndarray  # (Ntr, 784) float32 in [0, 1]
    y_train: np.ndarray  # (Ntr,) int64
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def num_classes(self) -> int:
        return int(self.y_train.max()) + 1


def _prototypes(num_classes: int, dim: int, rank: int, contrast: float, style: float):
    rng = np.random.default_rng(_PROTO_SEED)
    # Smooth-ish prototypes: random low-frequency mixtures, scaled into [0,1]
    # and contrast-compressed so classes overlap (a ridge probe lands at
    # ~0.82 test accuracy — learnable but not linearly trivial, like MNIST).
    base = rng.normal(size=(num_classes, dim))
    kernel = np.exp(-0.5 * (np.arange(-10, 11) / 4.0) ** 2)
    kernel /= kernel.sum()
    smooth = np.stack([np.convolve(b, kernel, mode="same") for b in base])
    protos = (smooth - smooth.min()) / (smooth.max() - smooth.min())
    protos = 0.5 + contrast * (protos - 0.5)
    styles = rng.normal(size=(num_classes, dim, rank)) * style
    return protos.astype(np.float32), styles.astype(np.float32)


def make_mnist_like(
    *,
    train_per_class: int = 500,
    test_per_class: int = 100,
    dim: int = 784,
    num_classes: int = 10,
    rank: int = 8,
    noise: float = 0.25,
    contrast: float = 0.4,
    style: float = 0.25,
    seed: int = 0,
) -> Dataset:
    protos, styles = _prototypes(num_classes, dim, rank, contrast, style)
    rng = np.random.default_rng(seed)

    def sample(per_class: int):
        xs, ys = [], []
        for c in range(num_classes):
            z = rng.normal(size=(per_class, rank)).astype(np.float32)
            eps = rng.normal(scale=noise, size=(per_class, dim)).astype(np.float32)
            x = protos[c][None] + z @ styles[c].T + eps
            xs.append(np.clip(x, 0.0, 1.0))
            ys.append(np.full(per_class, c, dtype=np.int64))
        x = np.concatenate(xs)
        y = np.concatenate(ys)
        perm = rng.permutation(len(y))
        return x[perm], y[perm]

    x_tr, y_tr = sample(train_per_class)
    x_te, y_te = sample(test_per_class)
    return Dataset(x_tr, y_tr, x_te, y_te)
