"""Seeded, fully traceable fault injection: churn, stragglers, edge drops.

The paper's DecAvg rounds are perfectly synchronous over a fixed node set;
real networks of user devices are not.  This module turns three fault
families into first-class, *deterministic* experiment axes:

- ``churn`` — nodes leave and rejoin mid-run.  Dead nodes freeze their
  parameters (mask-based ``where``, no shape changes) and drop out of every
  neighbor's mixing row.
- ``straggler`` — a static subset of nodes publishes *stale* parameter
  snapshots: each straggler gossips the params it held ``delay`` rounds ago
  (a bounded ring buffer of past params — an asynchronous-gossip
  approximation with per-node logical lag).
- ``drop`` — each undirected edge independently fails for one round with
  probability ``p_edge`` (message loss); both directions drop together.

Spec grammar mirrors :mod:`repro.core.topology`'s schedule strings —
clauses joined by ``";"``, each ``kind[:k=v,...][@targeted=...]``::

    "churn:p_leave=0.05,p_join=0.5@targeted=hubs"
    "straggler:frac=0.2,delay=3"
    "drop:p_edge=0.1"
    "churn:p_leave=1.0,p_join=0.0,frac=0.25,start=8@targeted=hubs;drop:p_edge=0.05"

``targeted`` restricts churn/straggler candidacy to the top (``hubs``) or
bottom (``leaves``) ``frac`` of nodes by degree; ``uniform`` (default)
draws from everyone.  ``churn`` extras: ``frac`` bounds the candidate pool
and ``start`` delays the first departure (so a run can train cleanly, take
a churn hit, and expose a measurable recovery).  ``drop`` takes no target.

Everything expands deterministically from ``(seed, spec, topology)`` via a
dedicated ``SeedSequence`` stream on the host (:class:`FaultTrace`); the
resulting per-round masks are plain arrays, so the fused trainer stages
them as one more stacked axis on ``MixingProgram`` and a faulty multi-host
run stays a single SPMD ``lax.scan``.

Renormalization semantics (shared by every backend, loop and fused): given
the round's entry-keep mask, each W row is rescaled over its surviving
entries so row-stochasticity holds; a row left with *no* surviving mass
falls back to identity (the node keeps its own params), and dead nodes'
params pass through bit-unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import TopologySchedule, _parse_value

__all__ = [
    "FaultClause",
    "FaultSchedule",
    "FaultTrace",
    "parse_faults",
    "renorm_dense",
    "renorm_values",
    "mix_faulted_dense",
    "mix_faulted_csr",
    "faulted_dense_w",
    "init_history",
    "push_and_publish",
    "where_alive",
    "where_alive_stacked",
    "churn_rounds",
    "recovery_rounds",
]

_KINDS = ("churn", "straggler", "drop")
_TARGETS = ("uniform", "hubs", "leaves")
_DEFAULTS: dict[str, dict[str, Any]] = {
    "churn": {"p_leave": 0.1, "p_join": 0.5, "frac": 0.25, "start": 0},
    "straggler": {"frac": 0.2, "delay": 2},
    "drop": {"p_edge": 0.1},
}

# Domain tag mixed into the SeedSequence so fault draws never collide with
# topology/init/batch streams derived from the same run seed.
_FAULT_STREAM = 0xFA017


@dataclasses.dataclass(frozen=True)
class FaultClause:
    """One parsed clause: ``kind`` + resolved params + targeting mode."""

    kind: str
    params: Mapping[str, Any]
    target: str = "uniform"


def _parse_clause(text: str) -> FaultClause:
    text = text.strip()
    target = "uniform"
    if "@" in text:
        text, _, mod = text.partition("@")
        key, _, val = mod.partition("=")
        if key.strip() != "targeted":
            raise ValueError(f"unknown fault modifier {mod!r} (only @targeted=...)")
        target = val.strip()
        if target not in _TARGETS:
            raise ValueError(f"unknown fault target {target!r}; one of {_TARGETS}")
    kind, _, rest = text.partition(":")
    kind = kind.strip()
    if kind not in _KINDS:
        raise ValueError(f"unknown fault kind {kind!r}; one of {_KINDS}")
    if kind == "drop" and target != "uniform":
        raise ValueError("drop faults hit edges, not nodes: @targeted is invalid")
    params = dict(_DEFAULTS[kind])
    if rest.strip():
        for item in rest.split(","):
            key, eq, val = item.partition("=")
            key = key.strip()
            if not eq or key not in params:
                raise ValueError(
                    f"bad {kind} param {item.strip()!r}; known: {sorted(params)}"
                )
            params[key] = type(_DEFAULTS[kind][key])(_parse_value(val.strip()))
    for key in ("p_leave", "p_join", "frac", "p_edge"):
        if key in params and not 0.0 <= float(params[key]) <= 1.0:
            raise ValueError(f"{kind}:{key}={params[key]} outside [0, 1]")
    if kind == "straggler" and int(params["delay"]) < 1:
        raise ValueError(f"straggler delay must be >= 1, got {params['delay']}")
    return FaultClause(kind, params, target)


def parse_faults(spec: str) -> tuple[FaultClause, ...]:
    """Parse a fault spec string into clauses (see module docstring)."""
    clauses = tuple(
        _parse_clause(part) for part in spec.split(";") if part.strip()
    )
    if not clauses:
        raise ValueError(f"empty fault spec {spec!r}")
    return clauses


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A parsed fault spec — the static half of the subsystem.

    Hashable/comparable on the raw spec string, so it can ride in jit
    static args and experiment configs alike.
    """

    spec: str
    clauses: tuple[FaultClause, ...]

    @classmethod
    def parse(cls, spec: "str | FaultSchedule") -> "FaultSchedule":
        if isinstance(spec, FaultSchedule):
            return spec
        return cls(spec=spec, clauses=parse_faults(spec))

    @property
    def has_churn(self) -> bool:
        return any(c.kind == "churn" for c in self.clauses)

    @property
    def has_drop(self) -> bool:
        return any(c.kind == "drop" for c in self.clauses)

    @property
    def has_stragglers(self) -> bool:
        return any(c.kind == "straggler" for c in self.clauses)

    @property
    def max_delay(self) -> int:
        return max(
            (int(c.params["delay"]) for c in self.clauses if c.kind == "straggler"),
            default=0,
        )


def _target_pool(clause: FaultClause, degrees: np.ndarray) -> np.ndarray:
    """Boolean candidate mask for a targeted churn/straggler clause."""
    n = degrees.shape[0]
    if clause.target == "uniform" and clause.kind == "churn":
        # churn's frac only narrows *targeted* pools; uniform churn may
        # touch anyone (p_leave already rate-limits departures).
        return np.ones(n, bool)
    k = max(1, int(np.ceil(float(clause.params["frac"]) * n)))
    # lexsort tie-break on node id keeps hub/leaf pools deterministic on
    # regular graphs where many degrees tie.
    if clause.target == "hubs":
        order = np.lexsort((np.arange(n), -degrees))
    elif clause.target == "leaves":
        order = np.lexsort((np.arange(n), degrees))
    else:  # uniform straggler: handled by the caller's rng.choice
        return np.ones(n, bool)
    pool = np.zeros(n, bool)
    pool[order[:k]] = True
    return pool


class FaultTrace:
    """Deterministic host-side expansion of a :class:`FaultSchedule`.

    Sequentially materializes per-round aliveness and edge-drop masks from
    ``np.random.SeedSequence([seed, _FAULT_STREAM])``; every consumer (loop
    trainer, fused program staging, runner analytics) sees byte-identical
    masks for the same ``(seed, spec, topology)``.
    """

    def __init__(
        self,
        schedule: FaultSchedule | str,
        topo: TopologySchedule,
        *,
        seed: int = 0,
    ):
        self.schedule = FaultSchedule.parse(schedule)
        self.topo = topo
        self.seed = int(seed)
        self._rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, _FAULT_STREAM])
        )
        g0 = topo.graph_at(0)
        self.n = g0.num_nodes
        deg0 = g0.degrees().astype(np.int64)
        # Straggler delays are static for the run, drawn from the period-0
        # graph (a straggler is a slow *device*, not a slow round).
        delay = np.zeros(self.n, np.int32)
        for clause in self.schedule.clauses:
            if clause.kind != "straggler":
                continue
            d = int(clause.params["delay"])
            if clause.target == "uniform":
                k = max(1, int(np.ceil(float(clause.params["frac"]) * self.n)))
                picks = self._rng.choice(self.n, size=k, replace=False)
                mask = np.zeros(self.n, bool)
                mask[picks] = True
            else:
                mask = _target_pool(clause, deg0)
            delay = np.maximum(delay, np.where(mask, d, 0).astype(np.int32))
        self.delay = delay
        self.delay_max = int(delay.max()) if self.n else 0
        self._alive = np.ones(self.n, bool)
        self._alive_rows: list[np.ndarray] = []
        self._drop_rows: list[np.ndarray] = []
        self._edge_cache: dict[int, np.ndarray] = {}

    def _edges(self, period: int) -> np.ndarray:
        """Sorted encoded (i*n+j, i<j) undirected edge keys for a period."""
        if period not in self._edge_cache:
            g = self.topo.graph_at(period * self.topo.every)
            i, j = np.nonzero(np.triu(np.asarray(g.adj, bool), 1))
            self._edge_cache[period] = (i.astype(np.int64) * self.n + j)
        return self._edge_cache[period]

    def _step(self, r: int) -> None:
        period = self.topo.period_of(r)
        g = self.topo.graph_at(r)
        degrees = g.degrees().astype(np.int64)
        alive = self._alive
        for clause in self.schedule.clauses:
            if clause.kind != "churn":
                continue
            # Draw both uniforms every round regardless of `start` so the
            # stream (and thus every later round's masks) doesn't depend on
            # when churn activates.
            u_leave = self._rng.random(self.n)
            u_join = self._rng.random(self.n)
            if r < int(clause.params["start"]):
                continue
            pool = _target_pool(clause, degrees)
            leave = alive & pool & (u_leave < float(clause.params["p_leave"]))
            join = ~alive & (u_join < float(clause.params["p_join"]))
            alive = (alive & ~leave) | join
        self._alive = alive
        self._alive_rows.append(alive.copy())

        edges = self._edges(period)
        dropped = np.zeros(edges.shape[0], bool)
        for clause in self.schedule.clauses:
            if clause.kind != "drop":
                continue
            dropped |= self._rng.random(edges.shape[0]) < float(
                clause.params["p_edge"]
            )
        self._drop_rows.append(edges[dropped])

    def ensure(self, rounds: int) -> None:
        """Extend the trace through round ``rounds - 1`` (incremental)."""
        while len(self._alive_rows) < rounds:
            self._step(len(self._alive_rows))

    def alive(self, r: int) -> np.ndarray:
        """(N,) bool aliveness after round ``r``'s churn transitions."""
        self.ensure(r + 1)
        return self._alive_rows[r]

    def alive_matrix(self, rounds: int) -> np.ndarray:
        """(rounds, N) bool alive masks, one row per round."""
        self.ensure(rounds)
        return np.stack(self._alive_rows[:rounds]) if rounds else np.zeros(
            (0, self.n), bool
        )

    def _dropped_keys(self, r: int) -> np.ndarray:
        self.ensure(r + 1)
        return self._drop_rows[r]

    def edge_kept(self, r: int, i: int, j: int) -> bool:
        """Did the undirected edge (i, j) survive round ``r``'s drops?"""
        lo, hi = (i, j) if i < j else (j, i)
        if lo == hi:
            return True
        key = lo * self.n + hi
        dropped = self._dropped_keys(r)
        pos = np.searchsorted(dropped, key)
        return not (pos < dropped.shape[0] and dropped[pos] == key)

    def dense_keep(self, r: int) -> np.ndarray:
        """(N, N) bool entry-keep mask for round ``r`` (dense W layout).

        Entry (i, j) survives iff both endpoints are alive and the edge was
        not dropped; the diagonal follows aliveness alone.
        """
        alive = self.alive(r)
        keep = alive[:, None] & alive[None, :]
        dropped = self._dropped_keys(r)
        if dropped.size:
            lo, hi = dropped // self.n, dropped % self.n
            keep[lo, hi] = False
            keep[hi, lo] = False
        return keep

    def entry_keep(
        self,
        r: int,
        rows_g: np.ndarray,
        cols_g: np.ndarray,
        values: np.ndarray | None = None,
    ) -> np.ndarray:
        """Entry-keep mask for arbitrary-shaped global-id (row, col) arrays.

        Covers every sparse layout in one helper: loop CSR, the fused
        stacked CSR, and the stacked ShardedCSR (where padded slots carry
        value 0.0 — pass ``values`` to force those slots kept, i.e. inert:
        0-valued entries contribute nothing either way, and keeping them
        avoids renormalizing over a phantom loss).
        """
        alive = self.alive(r)
        rows_g = np.asarray(rows_g)
        cols_g = np.asarray(cols_g)
        keep = alive[rows_g] & alive[cols_g]
        dropped = self._dropped_keys(r)
        offdiag = rows_g != cols_g
        if dropped.size and offdiag.any():
            lo = np.minimum(rows_g, cols_g).astype(np.int64)
            hi = np.maximum(rows_g, cols_g).astype(np.int64)
            key = lo * self.n + hi
            pos = np.searchsorted(dropped, key)
            pos = np.minimum(pos, dropped.shape[0] - 1)
            hit = (dropped[pos] == key) & offdiag
            keep = keep & ~hit
        if values is not None:
            keep = keep | (np.asarray(values) == 0.0)
        return keep


# ---------------------------------------------------------------------------
# Device-side (jnp) fault mixing — shared by loop and fused paths
# ---------------------------------------------------------------------------


def renorm_dense(w: jax.Array, keep: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Zero masked entries and rescale each row to sum 1.

    Returns ``(w_renorm, row_ok)`` where ``row_ok[i]`` is False iff row i
    lost *all* its mass (the caller must fall back to identity there).
    """
    wk = w * keep
    rowsum = wk.sum(axis=1)
    ok = rowsum > 0
    return wk / jnp.where(ok, rowsum, 1.0)[:, None], ok


def renorm_values(
    values: jax.Array, keep: jax.Array, rows: jax.Array, n: int
) -> tuple[jax.Array, jax.Array]:
    """CSR-layout row renormalization (``rows`` sorted ascending)."""
    vk = values * keep
    rowsum = jax.ops.segment_sum(vk, rows, num_segments=n, indices_are_sorted=True)
    ok = rowsum > 0
    inv = jnp.where(ok, 1.0, 0.0) / jnp.where(ok, rowsum, 1.0)
    return vk * inv[rows], ok


def mix_faulted_dense(
    w: jax.Array,
    keep: jax.Array,
    alive: jax.Array,
    params: Any,
    pub: Any = None,
) -> Any:
    """One faulted dense DecAvg round on a node-stacked pytree.

    Mixes the *published* snapshots ``pub`` (stale for stragglers; defaults
    to ``params``) under the renormalized surviving W, while each node's own
    contribution stays fresh: ``out = Wf @ pub + diag(Wf) * (cur - pub)``.
    Rows with no surviving mass, and dead destination nodes, pass their
    current params through bit-unchanged.
    """
    wn, ok = renorm_dense(w, keep)
    okr = ok & alive

    if pub is None:
        # Every publish is fresh (no stragglers): the diagonal correction is
        # identically zero, so mix per leaf with no pytree flatten copies.
        def leaf(p: jax.Array) -> jax.Array:
            pf = p.reshape(p.shape[0], -1).astype(jnp.float32)
            out = jnp.where(okr[:, None], wn @ pf, pf)
            return out.reshape(p.shape).astype(p.dtype)

        return jax.tree_util.tree_map(leaf, params)

    # Stale publishes: mix them through the OFF-diagonal weights only and
    # add each node's fresh self-contribution directly —
    # ``(Wf - diag(Wf)) @ pub + diag(Wf) * cur`` is algebraically
    # ``Wf @ pub + diag(Wf) * (cur - pub)`` with one fewer params-sized
    # elementwise pass through the scan body.
    diag = jnp.diagonal(wn)
    wn_od = wn - jnp.diag(diag)

    def leaf2(p: jax.Array, q: jax.Array) -> jax.Array:
        pf = p.reshape(p.shape[0], -1).astype(jnp.float32)
        qf = q.reshape(q.shape[0], -1).astype(jnp.float32)
        out = wn_od @ qf + diag[:, None] * pf
        out = jnp.where(okr[:, None], out, pf)
        return out.reshape(p.shape).astype(p.dtype)

    return jax.tree_util.tree_map(leaf2, params, pub)


def mix_faulted_csr(
    rows: jax.Array,
    cols: jax.Array,
    values: jax.Array,
    keep: jax.Array,
    alive: jax.Array,
    n: int,
    params: Any,
    pub: Any = None,
) -> Any:
    """CSR twin of :func:`mix_faulted_dense` (entries sorted by row)."""
    vn, ok = renorm_values(values, keep, rows, n)
    okr = ok & alive

    if pub is None:
        def leaf(p: jax.Array) -> jax.Array:
            pf = p.reshape(p.shape[0], -1).astype(jnp.float32)
            out = jax.ops.segment_sum(
                pf[cols] * vn[:, None], rows, num_segments=n,
                indices_are_sorted=True,
            )
            out = jnp.where(okr[:, None], out, pf)
            return out.reshape(p.shape).astype(p.dtype)

        return jax.tree_util.tree_map(leaf, params)

    # Same off-diagonal rewrite as the dense path: gather stale publishes
    # through the non-self entries, add the fresh self term directly.
    is_diag = rows == cols
    dcoef = jax.ops.segment_sum(
        jnp.where(is_diag, vn, 0.0),
        rows,
        num_segments=n,
        indices_are_sorted=True,
    )
    vn_od = jnp.where(is_diag, 0.0, vn)

    def leaf2(p: jax.Array, q: jax.Array) -> jax.Array:
        pf = p.reshape(p.shape[0], -1).astype(jnp.float32)
        qf = q.reshape(q.shape[0], -1).astype(jnp.float32)
        out = jax.ops.segment_sum(
            qf[cols] * vn_od[:, None], rows, num_segments=n,
            indices_are_sorted=True,
        )
        out = out + dcoef[:, None] * pf
        out = jnp.where(okr[:, None], out, pf)
        return out.reshape(p.shape).astype(p.dtype)

    return jax.tree_util.tree_map(leaf2, params, pub)


def faulted_dense_w(
    w: np.ndarray | jax.Array, keep: np.ndarray | jax.Array, alive: np.ndarray
) -> np.ndarray:
    """The effective mixing matrix a faulted round applies (test/analysis
    helper): renormalized surviving rows, identity rows for dead nodes and
    for rows that lost all mass."""
    wn, ok = renorm_dense(jnp.asarray(w, jnp.float32), jnp.asarray(keep, bool))
    wn = np.array(wn)
    identity = ~(np.asarray(ok) & np.asarray(alive, bool))
    wn[identity] = 0.0
    wn[identity, np.flatnonzero(identity)] = 1.0
    return wn


def init_history(params: Any, depth: int) -> Any:
    """Zeroed ring buffer of past params: each leaf (N, ...) -> (N, depth, ...).

    Node-first layout so the trainer's per-node sharding specs cover
    history leaves unchanged.  Zero-init is safe: reads clamp the effective
    delay to ``min(delay, round)``, so unwritten slots are never consumed.
    """
    return jax.tree_util.tree_map(
        lambda l: jnp.zeros((l.shape[0], depth) + l.shape[1:], l.dtype), params
    )


def push_and_publish(
    params: Any, hist: Any, r: jax.Array, delay: jax.Array
) -> tuple[Any, Any]:
    """Write this round's params into the ring buffer, read stale snapshots.

    ``hist`` leaves are (N, D, ...) with ``D = delay_max + 1`` — enough
    depth that a slot is never overwritten before its last reader: node i
    reads slot ``(r - min(delay_i, r)) % D``, and ``(r - d) % D == r % D``
    only at ``d = 0`` (whose slot was *just* written, so delay-0 nodes
    publish bit-fresh params).
    """
    slot_w = jnp.mod(r, jax.tree_util.tree_leaves(hist)[0].shape[1])
    hist = jax.tree_util.tree_map(
        lambda h, p: jax.lax.dynamic_update_index_in_dim(h, p, slot_w, 1),
        hist,
        params,
    )
    depth = jax.tree_util.tree_leaves(hist)[0].shape[1]
    eff = jnp.minimum(delay, r)
    slot_r = jnp.mod(r - eff, depth)
    pub = jax.tree_util.tree_map(
        lambda h: h[jnp.arange(h.shape[0]), slot_r], hist
    )
    return pub, hist


def where_alive(alive: jax.Array, new: Any, old: Any) -> Any:
    """Per-node select over node-stacked pytrees: dead nodes keep ``old``."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(
            alive.reshape((-1,) + (1,) * (a.ndim - 1)), a, b
        ),
        new,
        old,
    )


def where_alive_stacked(alive: jax.Array, new: Any, old: Any) -> Any:
    """``where_alive`` for pytrees mixing node-stacked leaves with shared
    state: leaves without a leading node axis (e.g. AdamW's global step
    ``count``, shared by every cohort member) pass through unfrozen — a
    per-node select over a scalar would silently reshape it to (N,)."""
    n = alive.shape[0]
    return jax.tree_util.tree_map(
        lambda a, b: a
        if a.ndim == 0 or a.shape[0] != n
        else jnp.where(alive.reshape((-1,) + (1,) * (a.ndim - 1)), a, b),
        new,
        old,
    )


# ---------------------------------------------------------------------------
# Analytics helpers (host side)
# ---------------------------------------------------------------------------


def churn_rounds(alive_counts: np.ndarray | list[int], n: int) -> list[int]:
    """Rounds where the alive count strictly dropped (churn events)."""
    counts = np.asarray(alive_counts, np.int64)
    prev = np.concatenate([[n], counts[:-1]])
    return np.flatnonzero(counts < prev).tolist()


def recovery_rounds(
    eval_rounds: list[int],
    accs: list[float | None],
    event_round: int,
) -> int | None:
    """Rounds until accuracy recovers to its best pre-event level.

    Over a (round, acc) eval curve: take the max acc strictly before
    ``event_round``; return ``first eval round >= event_round with
    acc >= that max (minus epsilon)`` minus ``event_round``.  ``None`` if
    there is no pre-event eval or the run never recovers.
    """
    pre = [a for r, a in zip(eval_rounds, accs) if r < event_round and a is not None]
    if not pre:
        return None
    target = max(pre) - 1e-9
    for r, a in zip(eval_rounds, accs):
        if r >= event_round and a is not None and a >= target:
            return r - event_round
    return None
