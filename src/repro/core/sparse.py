"""Sparse (CSR) gossip mixing — the large-N DecAvg path.

A gossip matrix W over a sparse collaboration graph has nnz = 2E + N entries
(neighbors + self loops), while the dense representation is N^2 floats: at
N=4096 on BA(m=2) that is 64 MB of dense W vs ~230 KB of CSR, and a per-round
cost of O(E*P) instead of O(N^2*P). This module stores W as (indptr, indices,
values) plus the precomputed COO row ids, and applies one DecAvg round as a
row-gather + segment-sum:

    out[i] = sum_{e : rows[e] == i} values[e] * P[indices[e]]

Two execution paths, numerically allclose to ``decavg.mix_dense``:

1. ``mix_sparse``         — XLA gather + ``jax.ops.segment_sum`` (sorted
                            segments), f32 accumulation. Default everywhere.
2. ``mix_sparse_pallas``  — ELL-padded Pallas row-gather kernel
                            (kernels/sparse_gossip.py) driven by scalar
                            prefetch; validated in interpret mode on CPU.

The transient gather buffer is O(nnz * P_leaf); for sparse graphs nnz ~ c*N,
so memory stays linear in N (dense mixing materializes the same O(N * P_leaf)
output anyway).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CSR",
    "csr_from_dense",
    "csr_to_dense",
    "ell_from_csr",
    "mix_sparse",
    "mix_sparse_pallas",
    "auto_p_chunk",
]

PyTree = Any


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("indptr", "indices", "rows", "values"),
    meta_fields=("shape",),
)
@dataclasses.dataclass(frozen=True)
class CSR:
    """Row-compressed sparse matrix with precomputed COO row ids.

    Attributes:
      indptr:  (N+1,) int32 — row e spans entries indptr[i]:indptr[i+1].
      indices: (nnz,) int32 — column (source node) of each entry.
      rows:    (nnz,) int32 — row (destination node) of each entry, sorted
               ascending (derivable from indptr; kept so segment_sum needs no
               host round-trip inside jit).
      values:  (nnz,) float32 — W entries.
      shape:   (N, N) static.
    """

    indptr: jax.Array
    indices: jax.Array
    rows: jax.Array
    values: jax.Array
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def nbytes(self) -> int:
        """Bytes of the W representation (the O(E) vs O(N^2) claim)."""
        return sum(
            int(np.prod(a.shape)) * a.dtype.itemsize
            for a in (self.indptr, self.indices, self.rows, self.values)
        )

    @property
    def max_row_nnz(self) -> int:
        ptr = np.asarray(self.indptr)
        return int((ptr[1:] - ptr[:-1]).max()) if self.shape[0] else 0


def csr_from_dense(w: np.ndarray | jax.Array, *, tol: float = 0.0) -> CSR:
    """Compress a dense (N, N) mixing matrix; entries with |w| <= tol drop."""
    wd = np.asarray(w, dtype=np.float32)
    if wd.ndim != 2 or wd.shape[0] != wd.shape[1]:
        raise ValueError(f"mixing matrix must be square, got {wd.shape}")
    mask = np.abs(wd) > tol
    rows, cols = np.nonzero(mask)  # row-major order -> rows sorted ascending
    counts = mask.sum(axis=1)
    indptr = np.zeros(wd.shape[0] + 1, dtype=np.int32)
    np.cumsum(counts, out=indptr[1:])
    return CSR(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(cols.astype(np.int32)),
        rows=jnp.asarray(rows.astype(np.int32)),
        values=jnp.asarray(wd[rows, cols]),
        shape=wd.shape,
    )


def csr_to_dense(csr: CSR) -> np.ndarray:
    out = np.zeros(csr.shape, dtype=np.float32)
    out[np.asarray(csr.rows), np.asarray(csr.indices)] = np.asarray(csr.values)
    return out


def ell_from_csr(csr: CSR) -> tuple[np.ndarray, np.ndarray]:
    """ELL padding for the Pallas kernel: (N, K) column indices + values,
    K = max row nnz. Padding entries point at column 0 with weight 0."""
    n = csr.shape[0]
    k = max(csr.max_row_nnz, 1)
    idx = np.zeros((n, k), dtype=np.int32)
    val = np.zeros((n, k), dtype=np.float32)
    ptr = np.asarray(csr.indptr)
    cols = np.asarray(csr.indices)
    vals = np.asarray(csr.values)
    for i in range(n):
        lo, hi = int(ptr[i]), int(ptr[i + 1])
        idx[i, : hi - lo] = cols[lo:hi]
        val[i, : hi - lo] = vals[lo:hi]
    return idx, val


def _gather_segment_sum(csr: CSR, flat: jax.Array) -> jax.Array:
    gathered = flat[csr.indices] * csr.values[:, None]  # (nnz, p)
    return jax.ops.segment_sum(
        gathered, csr.rows, num_segments=csr.shape[0], indices_are_sorted=True
    )


def _mix_sparse_leaf(csr: CSR, leaf: jax.Array, p_chunk: int | None = None) -> jax.Array:
    n = csr.shape[0]
    if leaf.shape[0] != n:
        raise ValueError(f"leaf leading axis {leaf.shape[0]} != num_nodes {n}")
    flat = leaf.reshape(n, -1).astype(jnp.float32)
    p = flat.shape[1]
    if p_chunk is not None and p_chunk < p:
        # Chunk the feature axis so the transient gather buffer is
        # O(nnz * p_chunk) instead of O(nnz * P) — at N=4096 / BA(m=2) a
        # P=2^20 leaf would otherwise materialize a ~65 GB intermediate.
        # lax.map serializes the chunks, bounding peak memory.
        pad = (-p) % p_chunk
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        chunks = flat.reshape(n, -1, p_chunk).transpose(1, 0, 2)  # (k, n, pc)
        out = jax.lax.map(functools.partial(_gather_segment_sum, csr), chunks)
        out = out.transpose(1, 0, 2).reshape(n, -1)[:, :p]
    else:
        out = _gather_segment_sum(csr, flat)
    return out.reshape(leaf.shape).astype(leaf.dtype)


@functools.partial(jax.jit, static_argnames=("p_chunk",))
def mix_sparse(csr: CSR, params: PyTree, *, p_chunk: int | None = None) -> PyTree:
    """One DecAvg round ``P <- W @ P`` with W in CSR, O(E*P) work.

    ``p_chunk`` bounds the transient gather buffer to O(nnz * p_chunk) per
    leaf (serialized chunks over the feature axis) — use for very large
    per-leaf P at large N. Default None preserves the single-gather path.
    """
    return jax.tree.map(functools.partial(_mix_sparse_leaf, csr, p_chunk=p_chunk), params)


def auto_p_chunk(nnz: int, budget_elems: int = 1 << 22) -> int:
    """Feature-axis chunk size keeping the gather buffer under ``budget_elems``
    f32 elements (default 4M ~= 16 MiB)."""
    return max(64, budget_elems // max(nnz, 1))


def mix_sparse_pallas(
    csr: CSR,
    params: PyTree,
    *,
    ell: tuple[np.ndarray, np.ndarray] | None = None,
    interpret: bool | None = None,
) -> PyTree:
    """Sparse DecAvg round via the Pallas ELL row-gather kernel.

    ``ell`` lets callers that mix repeatedly with the same W (GossipEngine)
    pass a precomputed ``ell_from_csr`` result instead of paying the O(N*K)
    host-side padding loop per call.
    """
    from repro.kernels import ops  # local import: kernels are optional at import time

    idx, val = ell_from_csr(csr) if ell is None else ell
    idx_j, val_j = jnp.asarray(idx), jnp.asarray(val)

    def mix(leaf: jax.Array) -> jax.Array:
        n = csr.shape[0]
        flat = leaf.reshape(n, -1)
        out = ops.gossip_mix_sparse(idx_j, val_j, flat, interpret=interpret)
        return out.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree.map(mix, params)
