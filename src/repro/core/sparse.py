"""Sparse (CSR) gossip mixing — the large-N DecAvg path.

A gossip matrix W over a sparse collaboration graph has nnz = 2E + N entries
(neighbors + self loops), while the dense representation is N^2 floats: at
N=4096 on BA(m=2) that is 64 MB of dense W vs ~230 KB of CSR, and a per-round
cost of O(E*P) instead of O(N^2*P). This module stores W as (indptr, indices,
values) plus the precomputed COO row ids, and applies one DecAvg round as a
row-gather + segment-sum:

    out[i] = sum_{e : rows[e] == i} values[e] * P[indices[e]]

Two execution paths, numerically allclose to ``decavg.mix_dense``:

1. ``mix_sparse``         — XLA gather + ``jax.ops.segment_sum`` (sorted
                            segments), f32 accumulation. Default everywhere.
2. ``mix_sparse_pallas``  — ELL-padded Pallas row-gather kernel
                            (kernels/sparse_gossip.py) driven by scalar
                            prefetch; validated in interpret mode on CPU.

The transient gather buffer is O(nnz * P_leaf); for sparse graphs nnz ~ c*N,
so memory stays linear in N (dense mixing materializes the same O(N * P_leaf)
output anyway).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CSR",
    "ShardedCSR",
    "BlockELL",
    "csr_from_dense",
    "csr_from_graph",
    "csr_to_dense",
    "ell_from_csr",
    "block_ell_from_csr",
    "stack_block_ell",
    "shard_csr",
    "stack_shard_csr",
    "halo_wire_bytes",
    "mix_sparse",
    "mix_sparse_pallas",
    "auto_p_chunk",
]

PyTree = Any


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("indptr", "indices", "rows", "values"),
    meta_fields=("shape",),
)
@dataclasses.dataclass(frozen=True)
class CSR:
    """Row-compressed sparse matrix with precomputed COO row ids.

    Attributes:
      indptr:  (N+1,) int32 — row e spans entries indptr[i]:indptr[i+1].
      indices: (nnz,) int32 — column (source node) of each entry.
      rows:    (nnz,) int32 — row (destination node) of each entry, sorted
               ascending (derivable from indptr; kept so segment_sum needs no
               host round-trip inside jit).
      values:  (nnz,) float32 — W entries.
      shape:   (N, N) static.
    """

    indptr: jax.Array
    indices: jax.Array
    rows: jax.Array
    values: jax.Array
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def nbytes(self) -> int:
        """Bytes of the W representation (the O(E) vs O(N^2) claim)."""
        return sum(
            int(np.prod(a.shape)) * a.dtype.itemsize
            for a in (self.indptr, self.indices, self.rows, self.values)
        )

    @property
    def max_row_nnz(self) -> int:
        ptr = np.asarray(self.indptr)
        return int((ptr[1:] - ptr[:-1]).max()) if self.shape[0] else 0


def csr_from_dense(w: np.ndarray | jax.Array, *, tol: float = 0.0) -> CSR:
    """Compress a dense (N, N) mixing matrix; entries with |w| <= tol drop."""
    wd = np.asarray(w, dtype=np.float32)
    if wd.ndim != 2 or wd.shape[0] != wd.shape[1]:
        raise ValueError(f"mixing matrix must be square, got {wd.shape}")
    mask = np.abs(wd) > tol
    rows, cols = np.nonzero(mask)  # row-major order -> rows sorted ascending
    counts = mask.sum(axis=1)
    indptr = np.zeros(wd.shape[0] + 1, dtype=np.int32)
    np.cumsum(counts, out=indptr[1:])
    return CSR(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(cols.astype(np.int32)),
        rows=jnp.asarray(rows.astype(np.int32)),
        values=jnp.asarray(wd[rows, cols]),
        shape=wd.shape,
    )


def csr_from_graph(
    g,
    data_sizes: np.ndarray | None = None,
    *,
    matrix: str = "decavg",
    self_trust: float = 1.0,
) -> CSR:
    """Build the mixing-matrix CSR straight from a graph's edge list.

    Equivalent (same support, values allclose at f32) to
    ``csr_from_dense(mixing.decavg_matrix(g, sizes))`` et al., but never
    materializes the dense (N, N) float matrix: the only transient is the
    O(E) entry list plus a boolean adjacency view. This is what lets
    ``GossipEngine.program`` stage every ``@rewire`` period of an N=4096 run
    without O(T * N^2) host memory.

    ``matrix``: "decavg" (paper Eq. 1 — weights omega * |D_j|, row-
    normalized; isolated zero-data rows keep their own model), "uniform"
    (closed-neighborhood mean) or "mh" (Metropolis-Hastings). Exact zeros
    (zero-size sources, zero MH diagonals) are dropped, matching
    ``csr_from_dense``'s support. Entries come out row-major sorted.
    """
    n = g.num_nodes
    if matrix == "mh":
        deg = g.adj.sum(axis=1).astype(np.float64)
        rr, cc = np.nonzero(g.adj)  # off-diagonal edges, no self loops
        off = 1.0 / (1.0 + np.maximum(deg[rr], deg[cc]))
        diag = 1.0 - np.bincount(rr, weights=off, minlength=n)
        rows = np.concatenate([rr, np.arange(n)])
        cols = np.concatenate([cc, np.arange(n)])
        vals = np.concatenate([off, diag])
    else:
        closed = g.adj.copy()
        np.fill_diagonal(closed, True)
        rows, cols = np.nonzero(closed)  # row-major: rows sorted ascending
        if matrix == "uniform":
            inv = 1.0 / np.bincount(rows, minlength=n).astype(np.float64)
            vals = inv[rows]
        elif matrix == "decavg":
            sizes = (
                np.ones(n) if data_sizes is None
                else np.asarray(data_sizes, dtype=np.float64)
            )
            if sizes.shape != (n,):
                raise ValueError(f"data_sizes must be ({n},), got {sizes.shape}")
            omega = np.where(rows == cols, float(self_trust), 1.0)
            vals = omega * sizes[cols]
            rowsum = np.bincount(rows, weights=vals, minlength=n)
            bad = rowsum == 0
            if bad.any():
                # Isolated node with zero data: keep its own model unchanged.
                vals = np.where(
                    bad[rows], np.where(rows == cols, 1.0, 0.0), vals
                )
                rowsum = np.where(bad, 1.0, rowsum)
            vals = vals / rowsum[rows]
        else:
            raise ValueError(
                f"matrix must be 'decavg', 'uniform' or 'mh', got {matrix!r}"
            )
    keep = vals != 0.0  # match csr_from_dense's |w| > 0 support
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    order = np.lexsort((cols, rows))  # mh appends the diagonal out of order
    rows = rows[order].astype(np.int32)
    cols = cols[order].astype(np.int32)
    vals = vals[order].astype(np.float32)
    indptr = np.zeros(n + 1, dtype=np.int32)
    np.cumsum(np.bincount(rows, minlength=n), out=indptr[1:])
    return CSR(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(cols),
        rows=jnp.asarray(rows),
        values=jnp.asarray(vals),
        shape=(n, n),
    )


def csr_to_dense(csr: CSR) -> np.ndarray:
    out = np.zeros(csr.shape, dtype=np.float32)
    out[np.asarray(csr.rows), np.asarray(csr.indices)] = np.asarray(csr.values)
    return out


def ell_from_csr(csr: CSR) -> tuple[np.ndarray, np.ndarray]:
    """ELL padding for the Pallas kernel: (N, K) column indices + values,
    K = max row nnz. Padding entries point at column 0 with weight 0."""
    n = csr.shape[0]
    k = max(csr.max_row_nnz, 1)
    idx = np.zeros((n, k), dtype=np.int32)
    val = np.zeros((n, k), dtype=np.float32)
    ptr = np.asarray(csr.indptr)
    cols = np.asarray(csr.indices)
    vals = np.asarray(csr.values)
    for i in range(n):
        lo, hi = int(ptr[i]), int(ptr[i + 1])
        idx[i, : hi - lo] = cols[lo:hi]
        val[i, : hi - lo] = vals[lo:hi]
    return idx, val


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=(
        "halo", "rows", "cols", "values",
        "local_src", "local_dst", "ring_send", "ring_recv",
    ),
    meta_fields=("shape", "shards", "rows_per_shard"),
)
@dataclasses.dataclass(frozen=True)
class ShardedCSR:
    """CSR with the node (row) axis split into ``shards`` contiguous ranges.

    Shard ``s`` owns destination rows ``[s*rows_per_shard, (s+1)*rows_per_shard)``
    and stores its W entries with *halo-local* column ids: ``halo[s]`` lists
    the global source nodes shard ``s`` needs (its own rows plus cross-shard
    neighbors), and ``cols`` indexes into that halo list. One sharded DecAvg
    round (decavg.mix_sharded_sparse) assembles the shard's halo rows of P
    into an (H, p) buffer and runs an O(nnz_s * P) segment-sum per shard.

    Two halo assembly schedules are supported by the same layout:

    - allgather: gather the full node axis once, slice ``halo[s]`` rows.
    - ring: S-1 ``ppermute`` steps; at step d every shard sends exactly the
      rows shard ``(s+d) % S`` needs from it (``ring_send[d-1]``) and places
      what it receives from shard ``(s-d) % S`` at the matching halo slots
      (``ring_recv[d-1]``); its own rows are copied locally via
      ``local_src``/``local_dst``. Per-device wire drops from O(N*P) to
      O(H*P). Steps in which no shard pair exchanges anything have zero-width
      index arrays and are skipped entirely at trace time.

    All per-shard arrays are stacked on a leading shard axis and zero-padded
    to the max shard size so the same SPMD program runs on every device:
    padded entries carry weight 0 and point at halo slot 0 / the shard's last
    local row, so they contribute nothing while keeping segment ids sorted.
    Padded ring/local *destination* slots point at the scratch slot H (one
    past the halo), which the mixing kernel discards.

    Attributes:
      halo:   (S, H) int32 — global source node ids needed by shard s
              (sorted ascending per shard; padded by repeating id 0).
      rows:   (S, E) int32 — destination row LOCAL to the shard, sorted
              ascending (padded with rows_per_shard - 1).
      cols:   (S, E) int32 — index into ``halo[s]`` (padded with 0).
      values: (S, E) float32 — W entries (padded with 0).
      local_src: (S, L) int32 — shard-local rows copied into the halo buffer
              without communication (padded with 0).
      local_dst: (S, L) int32 — halo slots for ``local_src`` (padded with H).
      ring_send: tuple of (S, K_d) int32, one per ring step d=1..S-1 — rows
              LOCAL to the sending shard, packed in the receiver's halo
              order (padded with 0; sent but discarded by the receiver).
      ring_recv: tuple of (S, K_d) int32 — halo slots where the rows received
              at step d land (padded with the scratch slot H).
      shape:  (N, N) static; shards, rows_per_shard: static ints.
    """

    halo: jax.Array
    rows: jax.Array
    cols: jax.Array
    values: jax.Array
    local_src: jax.Array
    local_dst: jax.Array
    ring_send: tuple[jax.Array, ...]
    ring_recv: tuple[jax.Array, ...]
    shape: tuple[int, int]
    shards: int
    rows_per_shard: int

    @property
    def halo_width(self) -> int:
        """Max rows of P any shard gathers (the halo buffer height)."""
        return int(self.halo.shape[1])

    @property
    def ring_width(self) -> int:
        """Rows of P one device receives per round under the ring schedule
        (sum of padded per-step widths — the O(H) wire bound)."""
        return sum(int(a.shape[1]) for a in self.ring_send)

    @property
    def nbytes(self) -> int:
        return sum(
            int(np.prod(a.shape)) * a.dtype.itemsize
            for a in (
                self.halo, self.rows, self.cols, self.values,
                self.local_src, self.local_dst, *self.ring_send, *self.ring_recv,
            )
        )


def shard_csr(csr: CSR, shards: int) -> ShardedCSR:
    """Split a CSR mixing matrix into per-shard row ranges with halo columns.

    Requires N divisible by ``shards`` (same contract as the dense sharded
    backend). Pure host-side preprocessing, done once per schedule period.
    Besides the per-shard CSR entries, this derives the peer metadata for the
    ring halo exchange: which shard owns each halo row, which local rows each
    shard must send at every ring step, and the halo slot each received row
    lands in (see ShardedCSR).
    """
    n = csr.shape[0]
    if shards < 1 or n % shards:
        raise ValueError(f"num_nodes {n} not divisible by shards {shards}")
    blk = n // shards
    ptr = np.asarray(csr.indptr)
    cols = np.asarray(csr.indices)
    vals = np.asarray(csr.values)
    coo_rows = np.asarray(csr.rows)

    halos: list[np.ndarray] = []
    loc_rows: list[np.ndarray] = []
    loc_cols: list[np.ndarray] = []
    loc_vals: list[np.ndarray] = []
    for s in range(shards):
        lo, hi = int(ptr[s * blk]), int(ptr[(s + 1) * blk])
        c = cols[lo:hi]
        need = np.unique(c)  # sorted global sources for this shard (the halo)
        if need.size == 0:
            need = np.zeros(1, dtype=np.int32)
        halos.append(need.astype(np.int32))
        loc_rows.append((coo_rows[lo:hi] - s * blk).astype(np.int32))
        loc_cols.append(np.searchsorted(need, c).astype(np.int32))
        loc_vals.append(vals[lo:hi].astype(np.float32))

    h_max = max(h.size for h in halos)
    e_max = max(max(r.size for r in loc_rows), 1)
    halo = np.zeros((shards, h_max), dtype=np.int32)
    rows = np.full((shards, e_max), blk - 1, dtype=np.int32)
    lcols = np.zeros((shards, e_max), dtype=np.int32)
    lvals = np.zeros((shards, e_max), dtype=np.float32)
    for s in range(shards):
        halo[s, : halos[s].size] = halos[s]
        k = loc_rows[s].size
        rows[s, :k] = loc_rows[s]
        lcols[s, :k] = loc_cols[s]
        lvals[s, :k] = loc_vals[s]

    # Ring peer metadata. Each halo row of shard s is owned by shard
    # owner = id // blk; at ring step d shard s receives exactly its halo
    # rows owned by (s - d) % shards, packed in halo order, while sending the
    # rows (s + d) % shards needs from it in *that* receiver's halo order —
    # sender packing and receiver slots line up by construction.
    scratch = h_max  # one-past-the-halo slot; padded writes land here
    loc_src = [np.flatnonzero(halos[s] // blk == s) for s in range(shards)]
    l_max = max(max((p.size for p in loc_src), default=0), 1)
    local_src = np.zeros((shards, l_max), dtype=np.int32)
    local_dst = np.full((shards, l_max), scratch, dtype=np.int32)
    for s in range(shards):
        p = loc_src[s]
        local_src[s, : p.size] = halos[s][p] - s * blk
        local_dst[s, : p.size] = p

    ring_send: list[jax.Array] = []
    ring_recv: list[jax.Array] = []
    for d in range(1, shards):
        # recv_pos[r]: positions in halos[r] owned by o = (r - d) % shards.
        recv_pos = [
            np.flatnonzero(halos[r] // blk == (r - d) % shards)
            for r in range(shards)
        ]
        k_d = max(p.size for p in recv_pos)
        send = np.zeros((shards, k_d), dtype=np.int32)
        recv = np.full((shards, k_d), scratch, dtype=np.int32)
        for r in range(shards):
            o = (r - d) % shards
            p = recv_pos[r]
            send[o, : p.size] = halos[r][p] - o * blk
            recv[r, : p.size] = p
        ring_send.append(jnp.asarray(send))
        ring_recv.append(jnp.asarray(recv))

    return ShardedCSR(
        halo=jnp.asarray(halo),
        rows=jnp.asarray(rows),
        cols=jnp.asarray(lcols),
        values=jnp.asarray(lvals),
        local_src=jnp.asarray(local_src),
        local_dst=jnp.asarray(local_dst),
        ring_send=tuple(ring_send),
        ring_recv=tuple(ring_recv),
        shape=csr.shape,
        shards=shards,
        rows_per_shard=blk,
    )


def stack_shard_csr(shcsrs: list[ShardedCSR]) -> dict[str, Any]:
    """Pad per-period ShardedCSRs to common widths and stack on a period axis.

    The fused sharded scan body selects the current period by index, so every
    period's layout must share one shape: halo padded to the max halo width
    (repeating id 0 — extra gathered rows are simply never referenced), CSR
    entries padded with zero-weight rows at the shard's last local row (after
    the sorted real entries, so segment ids stay sorted), and ring/local
    tables padded per step to the max step width. Ring steps keep their
    per-period zero-width collapse only when the width is zero across *all*
    periods (shapes are shared), which also keeps ``ring_width`` — and hence
    the ``halo_schedule="auto"`` decision — common to the whole program.

    Padded local/ring *destination* slots point at the scratch slot; because
    the halo widens to ``h_max``, each period's own scratch slot
    (``halo_width_t``) is remapped to the stacked scratch ``h_max`` so padded
    writes keep landing one past the halo.

    Returns a dict of stacked arrays: halo/rows/cols/values/local_src/
    local_dst with leading (T, S, ...) axes and ring_send/ring_recv as tuples
    of (T, S, K_d) arrays, mirroring the ShardedCSR fields.
    """
    s0 = shcsrs[0]
    if any(s.shards != s0.shards or s.shape != s0.shape for s in shcsrs):
        raise ValueError("all periods must share shape and shard count")
    h_max = max(s.halo_width for s in shcsrs)
    e_max = max(int(s.rows.shape[1]) for s in shcsrs)
    l_max = max(int(s.local_src.shape[1]) for s in shcsrs)
    steps = s0.shards - 1
    k_max = [max(int(s.ring_send[d].shape[1]) for s in shcsrs) for d in range(steps)]

    def pad(a: jax.Array, width: int, fill) -> np.ndarray:
        a = np.asarray(a)
        return np.pad(a, ((0, 0), (0, width - a.shape[1])), constant_values=fill)

    def remap_scratch(a: jax.Array, s: ShardedCSR) -> np.ndarray:
        # Destination slots: the period's own scratch (== halo_width_t) must
        # follow the halo as it widens to h_max; real slots are < halo_width_t
        # and stay put.
        a = np.asarray(a)
        return np.where(a == s.halo_width, h_max, a).astype(a.dtype)

    return {
        "halo": np.stack([pad(s.halo, h_max, 0) for s in shcsrs]),
        "rows": np.stack(
            [pad(s.rows, e_max, s0.rows_per_shard - 1) for s in shcsrs]
        ),
        "cols": np.stack([pad(s.cols, e_max, 0) for s in shcsrs]),
        "values": np.stack([pad(s.values, e_max, 0.0) for s in shcsrs]),
        "local_src": np.stack([pad(s.local_src, l_max, 0) for s in shcsrs]),
        "local_dst": np.stack(
            [pad(remap_scratch(s.local_dst, s), l_max, h_max) for s in shcsrs]
        ),
        "ring_send": tuple(
            np.stack([pad(s.ring_send[d], k_max[d], 0) for s in shcsrs])
            for d in range(steps)
        ),
        "ring_recv": tuple(
            np.stack(
                [pad(remap_scratch(s.ring_recv[d], s), k_max[d], h_max)
                 for s in shcsrs]
            )
            for d in range(steps)
        ),
    }


def halo_wire_bytes(shcsr: ShardedCSR, p: int, *, itemsize: int = 4) -> dict[str, int]:
    """Modeled per-device *receive* volume of one mixing round, per schedule.

    allgather moves the (S-1)/S complement of the full node axis onto every
    device; the ring moves only the padded per-step halo rows (``ring_width``,
    O(H)). Both count payload bytes of P rows at ``p`` features — layout
    metadata (a few KB of int32, round-constant) is excluded.
    """
    n = shcsr.shape[0]
    return {
        "allgather": (n - shcsr.rows_per_shard) * p * itemsize,
        "ring": shcsr.ring_width * p * itemsize,
    }


@dataclasses.dataclass(frozen=True)
class BlockELL:
    """8-row-blocked ELL layout for the TPU sparse gossip kernel.

    Rows are grouped into blocks of ``block`` (the f32 sublane count); for
    each destination block the distinct *source blocks* touched by any of its
    rows are enumerated, and the weights coupling the two blocks are stored
    as a dense (block, block) tile. One kernel grid step is then a single
    aligned DMA of the source block's P rows plus a (block, block) @
    (block, bd) mini-matmul — real sublane packing instead of the scalar
    kernel's (1, bd) row-at-a-time gathers.

    Attributes:
      idx: (NB, KB) int32 — source block ids per destination block, padded
           with 0 (their weight tiles are all-zero).
      val: (NB*block, KB*block) f32 — ``val[r, t*block + o]`` is the weight
           of global row r against row ``idx[r//block, t]*block + o``. KB is
           padded so the trailing dim is a multiple of ``block * lane_pad``
           (TPU lane alignment of the (block, block) tile stream).
      n:   unpadded row count; block: rows per block.
    """

    idx: np.ndarray
    val: np.ndarray
    n: int
    block: int = 8

    @property
    def num_blocks(self) -> int:
        return int(self.idx.shape[0])

    @property
    def max_blocks_per_row(self) -> int:
        return int(self.idx.shape[1])


def block_ell_from_csr(csr: CSR, *, block: int = 8, lane_pad: int = 16) -> BlockELL:
    """Build the 8-row-blocked ELL layout (see BlockELL) from a CSR matrix.

    ``lane_pad`` rounds the per-block source count up so the stacked weight
    tiles' trailing dim (KB * block) is a multiple of block * lane_pad = 128
    lanes for the default block=8.
    """
    n = csr.shape[0]
    nb = -(-n // block)
    ptr = np.asarray(csr.indptr)
    cols = np.asarray(csr.indices)
    vals = np.asarray(csr.values)

    slots: list[dict[int, int]] = []
    entries: list[list[tuple[int, int, float]]] = []  # (row, val-col, value)
    for b in range(nb):
        slot: dict[int, int] = {}
        ent: list[tuple[int, int, float]] = []
        for r in range(b * block, min((b + 1) * block, n)):
            for e in range(int(ptr[r]), int(ptr[r + 1])):
                sb, off = divmod(int(cols[e]), block)
                t = slot.setdefault(sb, len(slot))
                ent.append((r, t * block + off, float(vals[e])))
        slots.append(slot)
        entries.append(ent)

    kb = max(max((len(s) for s in slots), default=0), 1)
    kb = -(-kb // lane_pad) * lane_pad
    idx = np.zeros((nb, kb), dtype=np.int32)
    val = np.zeros((nb * block, kb * block), dtype=np.float32)
    for b, (slot, ent) in enumerate(zip(slots, entries)):
        for sb, t in slot.items():
            idx[b, t] = sb
        for r, c, v in ent:
            val[r, c] = v
    return BlockELL(idx=idx, val=val, n=n, block=block)


def stack_block_ell(
    csrs: list[CSR], *, block: int = 8, lane_pad: int = 16
) -> tuple[np.ndarray, np.ndarray]:
    """Blocked-ELL layouts for every schedule period, padded to a common
    block count and stacked on a leading period axis.

    Periods with fewer source blocks per destination block are padded with
    index-0 tiles whose weights are all zero (the kernel multiplies them in
    as exact zeros, same convention as ``block_ell_from_csr``'s own lane
    padding). Returns ``idx`` (T, NB, KB) int32 and ``val``
    (T, NB*block, KB*block) f32 for the fused scan body to index by period.
    """
    if not csrs:
        raise ValueError("need at least one period")
    if any(c.shape != csrs[0].shape for c in csrs):
        raise ValueError("all periods must share the matrix shape")
    bells = [block_ell_from_csr(c, block=block, lane_pad=lane_pad) for c in csrs]
    kb = max(b.max_blocks_per_row for b in bells)  # lane-aligned per period
    idx = np.stack(
        [np.pad(b.idx, ((0, 0), (0, kb - b.idx.shape[1]))) for b in bells]
    )
    val = np.stack(
        [np.pad(b.val, ((0, 0), (0, (kb - b.idx.shape[1]) * block))) for b in bells]
    )
    return idx, val


def _gather_segment_sum(csr: CSR, flat: jax.Array) -> jax.Array:
    gathered = flat[csr.indices] * csr.values[:, None]  # (nnz, p)
    return jax.ops.segment_sum(
        gathered, csr.rows, num_segments=csr.shape[0], indices_are_sorted=True
    )


def _mix_sparse_leaf(csr: CSR, leaf: jax.Array, p_chunk: int | None = None) -> jax.Array:
    n = csr.shape[0]
    if leaf.shape[0] != n:
        raise ValueError(f"leaf leading axis {leaf.shape[0]} != num_nodes {n}")
    flat = leaf.reshape(n, -1).astype(jnp.float32)
    p = flat.shape[1]
    if p_chunk is not None and p_chunk < p:
        # Chunk the feature axis so the transient gather buffer is
        # O(nnz * p_chunk) instead of O(nnz * P) — at N=4096 / BA(m=2) a
        # P=2^20 leaf would otherwise materialize a ~65 GB intermediate.
        # lax.map serializes the chunks, bounding peak memory.
        pad = (-p) % p_chunk
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        chunks = flat.reshape(n, -1, p_chunk).transpose(1, 0, 2)  # (k, n, pc)
        out = jax.lax.map(functools.partial(_gather_segment_sum, csr), chunks)
        out = out.transpose(1, 0, 2).reshape(n, -1)[:, :p]
    else:
        out = _gather_segment_sum(csr, flat)
    return out.reshape(leaf.shape).astype(leaf.dtype)


@functools.partial(jax.jit, static_argnames=("p_chunk",))
def mix_sparse(csr: CSR, params: PyTree, *, p_chunk: int | None = None) -> PyTree:
    """One DecAvg round ``P <- W @ P`` with W in CSR, O(E*P) work.

    ``p_chunk`` bounds the transient gather buffer to O(nnz * p_chunk) per
    leaf (serialized chunks over the feature axis) — use for very large
    per-leaf P at large N. Default None preserves the single-gather path.
    """
    return jax.tree.map(functools.partial(_mix_sparse_leaf, csr, p_chunk=p_chunk), params)


def auto_p_chunk(nnz: int, budget_elems: int = 1 << 22) -> int:
    """Feature-axis chunk size keeping the gather buffer under ``budget_elems``
    f32 elements (default 4M ~= 16 MiB)."""
    return max(64, budget_elems // max(nnz, 1))


def mix_sparse_pallas(
    csr: CSR,
    params: PyTree,
    *,
    ell: tuple[np.ndarray, np.ndarray] | None = None,
    bell: BlockELL | None = None,
    interpret: bool | None = None,
    blocked: bool | None = None,
) -> PyTree:
    """Sparse DecAvg round via the Pallas ELL kernels.

    Two kernels (kernels/sparse_gossip.py), selected by ``blocked``:

    - blocked (default on real TPU): 8-row-blocked ELL — sublane-packed
      (8, bd) source-block DMAs + (8, 8) weight-tile mini-matmuls.
    - scalar (default under interpret, i.e. off-TPU): the per-row (1, bd)
      gather kernel; far fewer grid steps through the slow interpreter.

    ``ell`` / ``bell`` let callers that mix repeatedly with the same W
    (GossipEngine) pass a precomputed layout instead of paying the host-side
    padding loop per call.
    """
    from repro.kernels import ops  # local import: kernels are optional at import time

    if interpret is None:
        interpret = not ops.on_tpu()
    if blocked is None:
        blocked = not interpret  # scalar fallback kernel under interpret

    n = csr.shape[0]
    if blocked:
        b = block_ell_from_csr(csr) if bell is None else bell
        idx_j, val_j = jnp.asarray(b.idx), jnp.asarray(b.val)

        def mix(leaf: jax.Array) -> jax.Array:
            flat = leaf.reshape(n, -1)
            out = ops.gossip_mix_sparse_blocked(idx_j, val_j, flat, interpret=interpret)
            return out.reshape(leaf.shape).astype(leaf.dtype)

    else:
        idx, val = ell_from_csr(csr) if ell is None else ell
        idx_j, val_j = jnp.asarray(idx), jnp.asarray(val)

        def mix(leaf: jax.Array) -> jax.Array:
            flat = leaf.reshape(n, -1)
            out = ops.gossip_mix_sparse(idx_j, val_j, flat, interpret=interpret)
            return out.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree.map(mix, params)
