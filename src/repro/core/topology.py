"""Network-topology generators, the topology registry, and graph metrics.

Implements the three families studied in the paper (§4): Erdős–Rényi (ER),
Barabási–Albert (BA) and the Stochastic Block Model (SBM), plus the wider
catalog the follow-up literature sweeps (ring, star, complete, k-regular,
grid/torus, Watts–Strogatz small-world, connected caveman) and the metrics
the paper's analysis relies on (degree distribution, connectivity threshold
p*, modularity, per-community external-edge counts).

Every family is registered in a single string-spec factory::

    make("ba:n=100,m=2")            # one call site for every layer
    make("ring", n=8)               # caller defaults fill missing params
    make_schedule("er:n=64@regen=5")  # time-varying graph, new ER every 5 rounds

Spec grammar (see README for the catalog table)::

    spec   := family [":" params] ["@" schedule]
    params := key "=" value ("," key "=" value)*
    value  := int | float | bool | int ("+" int)*        # "+"-joined int list
    schedule := ("regen" | "rewire") "=" every ["," "frac" "=" float]

Everything is pure numpy (seeded, deterministic); graphs are returned as a
small `Graph` dataclass holding a dense boolean adjacency matrix — at the
paper's scale (N=100) dense is both simpler and faster on accelerators, and
the sparse mixing path (core/sparse.py) compresses W downstream for large N.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import numpy as np

__all__ = [
    "Graph",
    "TopologyFamily",
    "TopologySchedule",
    "make",
    "make_schedule",
    "parse_spec",
    "available",
    "families",
    "register",
    "erdos_renyi",
    "barabasi_albert",
    "stochastic_block_model",
    "ring",
    "star",
    "complete",
    "k_regular",
    "grid_2d",
    "watts_strogatz",
    "connected_caveman",
    "er_critical_p",
    "degree",
    "connected_components",
    "modularity",
    "external_edge_counts",
    "clustering_coefficient",
    "graph_summary",
]


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected, unweighted graph as a dense symmetric adjacency matrix.

    Attributes:
      adj: (N, N) bool ndarray, symmetric, zero diagonal.
      blocks: optional (N,) int ndarray of community labels (SBM only).
      name: human-readable description of the generator + params.
    """

    adj: np.ndarray
    blocks: np.ndarray | None = None
    name: str = "graph"

    def __post_init__(self):
        a = self.adj
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"adjacency must be square, got {a.shape}")
        if a.dtype != np.bool_:
            raise ValueError("adjacency must be boolean")
        if not np.array_equal(a, a.T):
            raise ValueError("adjacency must be symmetric (undirected graph)")
        if np.any(np.diag(a)):
            raise ValueError("adjacency must have a zero diagonal")

    @property
    def num_nodes(self) -> int:
        return self.adj.shape[0]

    @property
    def num_edges(self) -> int:
        return int(self.adj.sum()) // 2

    def degrees(self) -> np.ndarray:
        return degree(self.adj)

    def neighbors(self, i: int) -> np.ndarray:
        return np.flatnonzero(self.adj[i])


def er_critical_p(n: int) -> float:
    """Sharp connectivity threshold p* = ln(N)/N for ER graphs [Erdős–Rényi 1960]."""
    return math.log(n) / n


def erdos_renyi(n: int, p: float, *, seed: int) -> Graph:
    """ER random graph: each of the C(n,2) edges exists i.i.d. w.p. ``p``."""
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"p must be in [0,1], got {p}")
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < p
    adj = np.triu(upper, k=1)
    adj = adj | adj.T
    return Graph(adj=adj, name=f"er(n={n},p={p})")


def barabasi_albert(n: int, m: int, *, seed: int) -> Graph:
    """BA preferential-attachment graph.

    Starts from a star over the first ``m + 1`` nodes, then each new node
    attaches to ``m`` distinct existing nodes sampled proportionally to their
    current degree (the classic repeated-nodes urn construction).
    """
    if m < 1 or m >= n:
        raise ValueError(f"need 1 <= m < n, got m={m}, n={n}")
    rng = np.random.default_rng(seed)
    adj = np.zeros((n, n), dtype=np.bool_)
    # Seed graph: star over nodes [0, m] — every node has degree >= 1 so the
    # preferential urn is well defined from the first attachment step.
    for i in range(1, m + 1):
        adj[0, i] = adj[i, 0] = True
    # Urn of endpoints: one entry per half-edge, so sampling uniformly from it
    # is sampling proportionally to degree.
    urn: list[int] = []
    for i in range(m + 1):
        urn.extend([i] * int(adj[i].sum()))
    for new in range(m + 1, n):
        targets: set[int] = set()
        while len(targets) < m:
            targets.add(int(urn[rng.integers(len(urn))]))
        for t in targets:
            adj[new, t] = adj[t, new] = True
            urn.extend([new, t])
    return Graph(adj=adj, name=f"ba(n={n},m={m})")


def stochastic_block_model(
    block_sizes: Sequence[int],
    p_in: float | Sequence[float],
    p_out: float,
    *,
    seed: int,
) -> Graph:
    """SBM with within-block prob ``p_in`` (scalar or per-block) and
    cross-block prob ``p_out``."""
    sizes = np.asarray(block_sizes, dtype=np.int64)
    n = int(sizes.sum())
    b = len(sizes)
    p_in_vec = np.full(b, p_in, dtype=np.float64) if np.isscalar(p_in) else np.asarray(p_in, dtype=np.float64)
    if p_in_vec.shape != (b,):
        raise ValueError("p_in must be scalar or one value per block")
    labels = np.repeat(np.arange(b), sizes)
    # Edge probability matrix P[i, j] by block membership.
    pmat = np.full((n, n), p_out, dtype=np.float64)
    same = labels[:, None] == labels[None, :]
    pmat[same] = p_in_vec[labels[np.nonzero(same)[0]]]
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < pmat
    adj = np.triu(upper, k=1)
    adj = adj | adj.T
    return Graph(
        adj=adj,
        blocks=labels,
        name=f"sbm(sizes={list(block_sizes)},p_in={p_in},p_out={p_out})",
    )


# ---------------------------------------------------------------------------
# Beyond-paper deterministic + small-world families (registry catalog)
# ---------------------------------------------------------------------------


def _empty(n: int) -> np.ndarray:
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    return np.zeros((n, n), dtype=np.bool_)


def ring(n: int) -> Graph:
    """Cycle graph: node i <-> i+1 mod n (the classic decentralized baseline)."""
    adj = _empty(n)
    if n > 1:
        for i in range(n):
            adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = True
    return Graph(adj=adj, name=f"ring:n={n}")


def star(n: int) -> Graph:
    """Hub-and-spokes: node 0 connected to all others (extreme hub topology)."""
    adj = _empty(n)
    adj[0, 1:] = adj[1:, 0] = True
    return Graph(adj=adj, name=f"star:n={n}")


def complete(n: int) -> Graph:
    """Fully connected graph — the FedAvg-like all-to-all upper baseline."""
    adj = ~np.eye(n, dtype=np.bool_)
    return Graph(adj=adj, name=f"complete:n={n}")


def k_regular(n: int, k: int) -> Graph:
    """Circulant k-regular graph: each node links to its k/2 nearest ring
    neighbors on each side (k even; odd k additionally links antipodes and
    needs even n)."""
    if not 0 < k < n:
        raise ValueError(f"need 0 < k < n, got k={k}, n={n}")
    if k % 2 and n % 2:
        raise ValueError(f"odd k={k} needs even n, got n={n}")
    adj = _empty(n)
    for off in range(1, k // 2 + 1):
        for i in range(n):
            j = (i + off) % n
            adj[i, j] = adj[j, i] = True
    if k % 2:
        for i in range(n // 2):
            adj[i, i + n // 2] = adj[i + n // 2, i] = True
    return Graph(adj=adj, name=f"kreg:n={n},k={k}")


def grid_2d(rows: int, cols: int, *, periodic: bool = False) -> Graph:
    """2-D lattice (``grid``) or its wrap-around version (``torus``)."""
    if rows < 1 or cols < 1:
        raise ValueError(f"need rows, cols >= 1, got {rows}x{cols}")
    n = rows * cols
    adj = _empty(n)

    def idx(r: int, c: int) -> int:
        return r * cols + c

    for r in range(rows):
        for c in range(cols):
            right = (r, c + 1)
            down = (r + 1, c)
            for rr, cc in (right, down):
                if periodic:
                    rr, cc = rr % rows, cc % cols
                elif rr >= rows or cc >= cols:
                    continue
                i, j = idx(r, c), idx(rr, cc)
                if i != j:
                    adj[i, j] = adj[j, i] = True
    kind = "torus" if periodic else "grid"
    return Graph(adj=adj, name=f"{kind}:rows={rows},cols={cols}")


def watts_strogatz(n: int, k: int, beta: float, *, seed: int) -> Graph:
    """Watts–Strogatz small world: circulant k-regular lattice with each
    edge rewired to a uniform random endpoint with probability ``beta``."""
    if not 0.0 <= beta <= 1.0:
        raise ValueError(f"beta must be in [0,1], got {beta}")
    if k % 2 or not 0 < k < n:
        raise ValueError(f"need even 0 < k < n, got k={k}, n={n}")
    rng = np.random.default_rng(seed)
    adj = k_regular(n, k).adj.copy()
    for off in range(1, k // 2 + 1):
        for i in range(n):
            j = (i + off) % n
            if rng.random() < beta and adj[i, j]:
                candidates = np.flatnonzero(~adj[i])
                candidates = candidates[candidates != i]
                if len(candidates):
                    new_j = int(rng.choice(candidates))
                    adj[i, j] = adj[j, i] = False
                    adj[i, new_j] = adj[new_j, i] = True
    return Graph(adj=adj, name=f"ws:n={n},k={k},beta={beta},seed={seed}")


def connected_caveman(cliques: int, size: int) -> Graph:
    """Connected caveman graph: ``cliques`` complete graphs of ``size`` nodes
    arranged in a ring; one edge per clique is rewired to bridge to the next
    clique — maximal clustering with a thin inter-community backbone (the
    deterministic extreme of the paper's SBM modularity axis)."""
    if cliques < 1 or size < 2:
        raise ValueError(f"need cliques >= 1 and size >= 2, got {cliques}, {size}")
    if cliques > 1 and size < 3:
        # Bridging rewires each clique's (lo, lo+1) edge; for 2-cliques that
        # is the clique's only edge and node lo+1 would be left isolated.
        raise ValueError(f"bridged caveman needs size >= 3, got size={size}")
    n = cliques * size
    adj = _empty(n)
    for c in range(cliques):
        lo = c * size
        adj[lo : lo + size, lo : lo + size] = True
    np.fill_diagonal(adj, False)
    if cliques > 1:
        for c in range(cliques):
            lo = c * size
            # Rewire the (lo, lo+1) in-clique edge to bridge to the next clique.
            adj[lo, lo + 1] = adj[lo + 1, lo] = False
            nxt = (lo + size) % n
            adj[lo, nxt] = adj[nxt, lo] = True
    blocks = np.repeat(np.arange(cliques), size)
    return Graph(adj=adj, blocks=blocks, name=f"caveman:cliques={cliques},size={size}")


# ---------------------------------------------------------------------------
# Topology registry: one string-spec factory for every layer of the system
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopologyFamily:
    """One registered graph family.

    ``builder(seed=..., **params) -> Graph`` must set ``Graph.name`` to the
    canonical spec string so specs round-trip: ``make(g.name)`` rebuilds g.
    """

    name: str
    builder: Callable[..., Graph]
    defaults: dict[str, Any]
    required: tuple[str, ...]
    stochastic: bool
    example: str
    doc: str


_REGISTRY: dict[str, TopologyFamily] = {}
_ALIASES: dict[str, str] = {}


def register(
    name: str,
    *,
    aliases: Sequence[str] = (),
    defaults: dict[str, Any] | None = None,
    required: Sequence[str] = ("n",),
    stochastic: bool = False,
    example: str = "",
    doc: str = "",
) -> Callable[[Callable[..., Graph]], Callable[..., Graph]]:
    """Register a ``builder(seed=..., **params) -> Graph`` under ``name``."""

    def deco(fn: Callable[..., Graph]) -> Callable[..., Graph]:
        fam = TopologyFamily(
            name=name,
            builder=fn,
            defaults=dict(defaults or {}),
            required=tuple(required),
            stochastic=stochastic,
            example=example or name,
            doc=doc or next(iter((fn.__doc__ or "").strip().splitlines()), ""),
        )
        _REGISTRY[name] = fam
        for a in aliases:
            _ALIASES[a] = name
        return fn

    return deco


def available() -> list[str]:
    """Canonical names of every registered family."""
    return sorted(_REGISTRY)


def families() -> dict[str, TopologyFamily]:
    """The registry itself (read-only view for docs/tests)."""
    return dict(_REGISTRY)


def _parse_value(v: str) -> Any:
    if "+" in v:
        parts = v.split("+")
        try:
            return [int(p) for p in parts]
        except ValueError:
            pass
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            continue
    low = v.lower()
    if low in ("true", "false"):
        return low == "true"
    return v


def parse_spec(spec: str) -> tuple[str, dict[str, Any], str | None]:
    """Split ``"family:key=val,...@sched"`` into (family, params, sched)."""
    spec = spec.strip()
    sched: str | None = None
    if "@" in spec:
        spec, sched = spec.split("@", 1)
    name, _, paramstr = spec.partition(":")
    name = name.strip().lower()
    if not name:
        raise ValueError(f"empty topology family in spec {spec!r}")
    params: dict[str, Any] = {}
    for kv in paramstr.split(","):
        kv = kv.strip()
        if not kv:
            continue
        k, eq, v = kv.partition("=")
        if not eq:
            raise ValueError(f"malformed param {kv!r} in spec {spec!r} (want key=value)")
        params[k.strip()] = _parse_value(v.strip())
    return name, params, sched


def _lookup(name: str) -> TopologyFamily:
    canon = _ALIASES.get(name, name)
    if canon not in _REGISTRY:
        raise ValueError(
            f"unknown topology family {name!r}; available: {', '.join(available())}"
        )
    return _REGISTRY[canon]


def _build(name: str, params: dict[str, Any], seed: int, defaults: dict[str, Any]) -> Graph:
    fam = _lookup(name)
    allowed = set(fam.defaults) | set(fam.required) | {"seed"}
    merged = dict(fam.defaults)
    for k, v in defaults.items():  # caller fallbacks (e.g. n from --nodes)
        if k in allowed and k != "seed":
            merged[k] = v
    merged.update(params)  # spec params win
    seed = int(merged.pop("seed", seed))
    unknown = set(merged) - (allowed - {"seed"})
    if unknown:
        raise ValueError(
            f"unknown params {sorted(unknown)} for family {fam.name!r}; "
            f"allowed: {sorted(allowed)}"
        )
    missing = [k for k in fam.required if merged.get(k) is None]
    if missing:
        raise ValueError(f"family {fam.name!r} needs params {missing} (spec or kwargs)")
    merged = {k: v for k, v in merged.items() if v is not None}
    return fam.builder(seed=seed, **merged)


def make(spec: str, *, seed: int = 0, **defaults: Any) -> Graph:
    """Build a Graph from a registry spec string.

    ``defaults`` fill params absent from the spec (spec always wins); ``seed``
    is the fallback when the spec carries no ``seed=`` param. The returned
    graph's ``.name`` is the canonical spec and round-trips through ``make``.
    """
    name, params, sched = parse_spec(spec)
    if sched is not None:
        raise ValueError(
            f"spec {spec!r} has a schedule suffix; build it with make_schedule()"
        )
    return _build(name, params, seed, defaults)


# -- registered builders (wrap the public generators, set canonical names) --


@register("er", aliases=("erdos_renyi",), defaults={"n": None, "p": None},
          stochastic=True, example="er:n=100,p=0.05",
          doc="Erdos-Renyi G(n,p); p defaults to 2*ln(n)/n (above p*)")
def _make_er(*, seed: int, n: int, p: float | None = None) -> Graph:
    p = 2.0 * er_critical_p(n) if p is None else p
    g = erdos_renyi(n, p, seed=seed)
    return dataclasses.replace(g, name=f"er:n={n},p={p},seed={seed}")


@register("ba", aliases=("barabasi_albert",), defaults={"n": None, "m": 2},
          stochastic=True, example="ba:n=100,m=2",
          doc="Barabasi-Albert preferential attachment, m edges per new node")
def _make_ba(*, seed: int, n: int, m: int = 2) -> Graph:
    g = barabasi_albert(n, m, seed=seed)
    return dataclasses.replace(g, name=f"ba:n={n},m={m},seed={seed}")


@register("sbm", aliases=("stochastic_block_model",),
          defaults={"n": None, "blocks": 4, "sizes": None, "p_in": 0.5, "p_out": 0.01},
          required=(), stochastic=True, example="sbm:n=100,blocks=4,p_in=0.5,p_out=0.01",
          doc="Stochastic block model; equal blocks from n or explicit sizes=a+b+...")
def _make_sbm(
    *,
    seed: int,
    n: int | None = None,
    blocks: int = 4,
    sizes: Sequence[int] | None = None,
    p_in: float = 0.5,
    p_out: float = 0.01,
) -> Graph:
    if sizes is None:
        if n is None:
            raise ValueError("sbm needs n (equal blocks) or sizes=a+b+...")
        if n % blocks:
            raise ValueError(f"sbm: n={n} not divisible by blocks={blocks}")
        sizes = [n // blocks] * blocks
    g = stochastic_block_model(sizes, p_in, p_out, seed=seed)
    canon = "+".join(str(int(s)) for s in sizes)
    return dataclasses.replace(
        g, name=f"sbm:sizes={canon},p_in={p_in},p_out={p_out},seed={seed}"
    )


@register("ring", aliases=("cycle",), defaults={"n": None}, example="ring:n=16",
          doc="Cycle graph (degree 2)")
def _make_ring(*, seed: int, n: int) -> Graph:
    return ring(n)


@register("star", defaults={"n": None}, example="star:n=16",
          doc="Hub-and-spokes (node 0 is the hub)")
def _make_star(*, seed: int, n: int) -> Graph:
    return star(n)


@register("complete", aliases=("full",), defaults={"n": None}, example="complete:n=16",
          doc="Fully connected all-to-all")
def _make_complete(*, seed: int, n: int) -> Graph:
    return complete(n)


@register("kreg", aliases=("k_regular", "regular"), defaults={"n": None, "k": 4},
          example="kreg:n=16,k=4", doc="Circulant k-regular ring lattice")
def _make_kreg(*, seed: int, n: int, k: int = 4) -> Graph:
    return k_regular(n, k)


def _near_square(n: int) -> tuple[int, int]:
    r = int(math.isqrt(n))
    while n % r:
        r -= 1
    return r, n // r


@register("grid", defaults={"n": None, "rows": None, "cols": None}, required=(),
          example="grid:rows=4,cols=5", doc="2-D lattice (non-periodic)")
def _make_grid(*, seed: int, n: int | None = None, rows: int | None = None,
               cols: int | None = None) -> Graph:
    if rows is None or cols is None:
        if n is None:
            raise ValueError("grid needs rows+cols or n")
        rows, cols = _near_square(n)
    return grid_2d(rows, cols, periodic=False)


@register("torus", defaults={"n": None, "rows": None, "cols": None}, required=(),
          example="torus:rows=4,cols=4", doc="2-D lattice with wrap-around (degree 4)")
def _make_torus(*, seed: int, n: int | None = None, rows: int | None = None,
                cols: int | None = None) -> Graph:
    if rows is None or cols is None:
        if n is None:
            raise ValueError("torus needs rows+cols or n")
        rows, cols = _near_square(n)
    return grid_2d(rows, cols, periodic=True)


@register("ws", aliases=("watts_strogatz", "smallworld"),
          defaults={"n": None, "k": 4, "beta": 0.1}, stochastic=True,
          example="ws:n=100,k=4,beta=0.1",
          doc="Watts-Strogatz small world (ring lattice with beta rewiring)")
def _make_ws(*, seed: int, n: int, k: int = 4, beta: float = 0.1) -> Graph:
    return watts_strogatz(n, k, beta, seed=seed)


@register("caveman", aliases=("connected_caveman",),
          defaults={"n": None, "cliques": None, "size": 5}, required=(),
          example="caveman:cliques=4,size=5",
          doc="Connected caveman: ring of cliques (max modularity)")
def _make_caveman(*, seed: int, n: int | None = None, cliques: int | None = None,
                  size: int = 5) -> Graph:
    if cliques is None:
        if n is None:
            raise ValueError("caveman needs cliques or n")
        if n % size:
            raise ValueError(f"caveman: n={n} not divisible by size={size}")
        cliques = n // size
    return connected_caveman(cliques, size)


# ---------------------------------------------------------------------------
# Time-varying topologies
# ---------------------------------------------------------------------------


class TopologySchedule:
    """A (possibly time-varying) sequence of graphs, indexed by round.

    Modes:
      static  — one fixed graph for all rounds.
      regen   — regenerate the family with a fresh seed every ``every`` rounds
                (i.i.d. graph resampling, e.g. per-round random matchings).
      rewire  — rewire ``frac`` of the base graph's edges (random remove +
                random add, node count preserved) every ``every`` rounds; each
                period rewires the *base* graph independently, so any period
                is reproducible from (seed, period) alone.

    ``graph_at(t)`` is cached per period; consumers that precompute per-graph
    state (mixing matrices, CSR) should key it on ``period_of(t)``.
    """

    def __init__(
        self,
        family: str,
        params: dict[str, Any] | None = None,
        *,
        mode: str = "static",
        every: int = 0,
        frac: float = 0.1,
        seed: int = 0,
        defaults: dict[str, Any] | None = None,
        graph: Graph | None = None,
    ):
        if mode not in ("static", "regen", "rewire"):
            raise ValueError(f"unknown schedule mode {mode!r}")
        if mode != "static" and every < 1:
            raise ValueError(f"mode {mode!r} needs every >= 1, got {every}")
        if not 0.0 < frac <= 1.0 and mode == "rewire":
            raise ValueError(f"rewire frac must be in (0,1], got {frac}")
        self.family = family
        self.params = dict(params or {})
        self.mode = mode
        self.every = int(every)
        self.frac = float(frac)
        self.seed = int(seed)
        self._defaults = dict(defaults or {})
        self._fixed = graph
        self._cache: tuple[int, Graph] | None = None

    @classmethod
    def static(cls, graph: Graph) -> "TopologySchedule":
        """Wrap an already-built Graph as a constant schedule."""
        return cls(family=graph.name, mode="static", graph=graph)

    @property
    def is_time_varying(self) -> bool:
        return self.mode != "static"

    @property
    def num_nodes(self) -> int:
        return self.graph_at(0).num_nodes

    def period_of(self, t: int) -> int:
        return 0 if not self.is_time_varying else int(t) // self.every

    def _base_graph(self) -> Graph:
        if self._fixed is None:
            self._fixed = _build(self.family, self.params, self.seed, self._defaults)
        return self._fixed

    def graph_at(self, t: int) -> Graph:
        period = self.period_of(t)
        if self._cache is not None and self._cache[0] == period:
            return self._cache[1]
        if self.mode == "static" or (self.mode == "rewire" and period == 0):
            g = self._base_graph()
        elif self.mode == "regen":
            g = _build(
                self.family, self.params, self.seed + 1_000_003 * period, self._defaults
            )
        else:  # rewire
            g = _rewire(self._base_graph(), self.frac, self.seed + 1_000_003 * period)
        self._cache = (period, g)
        return g

    def __repr__(self) -> str:
        if self.mode == "static":
            return f"TopologySchedule({self._base_graph().name})"
        return (
            f"TopologySchedule({self.family}:{self.params}@{self.mode}="
            f"{self.every},frac={self.frac})"
        )


def _rewire(g: Graph, frac: float, seed: int) -> Graph:
    """Rewire ``frac`` of the edges: remove k random edges, add k random
    non-edges. Degree sequence is not preserved; node count is."""
    rng = np.random.default_rng(seed)
    adj = g.adj.copy()
    ii, jj = np.nonzero(np.triu(adj, k=1))
    n_edges = len(ii)
    if n_edges == 0:
        return g
    k = max(1, int(round(frac * n_edges)))
    drop = rng.choice(n_edges, size=min(k, n_edges), replace=False)
    for e in drop:
        adj[ii[e], jj[e]] = adj[jj[e], ii[e]] = False
    ai, aj = np.nonzero(np.triu(~adj, k=1))
    free = len(ai)
    add = rng.choice(free, size=min(len(drop), free), replace=False)
    for e in add:
        adj[ai[e], aj[e]] = adj[aj[e], ai[e]] = True
    return Graph(adj=adj, blocks=g.blocks, name=f"{g.name}@rewired(seed={seed})")


def make_schedule(spec: str, *, seed: int = 0, **defaults: Any) -> TopologySchedule:
    """Build a TopologySchedule from a spec string.

    Without an ``@`` suffix the schedule is static. ``@regen=R`` resamples the
    family every R rounds; ``@rewire=R[,frac=F]`` rewires fraction F (default
    0.1) of the edges every R rounds.
    """
    name, params, sched = parse_spec(spec)
    mode, every, frac = "static", 0, 0.1
    if sched is not None:
        skv: dict[str, Any] = {}
        for kv in sched.split(","):
            kv = kv.strip()
            if not kv:
                continue
            k, eq, v = kv.partition("=")
            if not eq:
                raise ValueError(f"malformed schedule param {kv!r} in {spec!r}")
            skv[k.strip()] = _parse_value(v.strip())
        if "regen" in skv:
            mode, every = "regen", int(skv.pop("regen"))
        elif "rewire" in skv:
            mode, every = "rewire", int(skv.pop("rewire"))
        else:
            raise ValueError(f"schedule suffix needs regen= or rewire=, got {sched!r}")
        frac = float(skv.pop("frac", frac))
        if skv:
            raise ValueError(f"unknown schedule params {sorted(skv)} in {spec!r}")
    seed = int(params.pop("seed", seed))
    return TopologySchedule(
        name, params, mode=mode, every=every, frac=frac, seed=seed, defaults=defaults
    )


def degree(adj: np.ndarray) -> np.ndarray:
    return adj.sum(axis=1).astype(np.int64)


def connected_components(adj: np.ndarray) -> np.ndarray:
    """Label connected components via BFS. Returns (N,) int labels."""
    n = adj.shape[0]
    labels = np.full(n, -1, dtype=np.int64)
    cur = 0
    for start in range(n):
        if labels[start] >= 0:
            continue
        frontier = [start]
        labels[start] = cur
        while frontier:
            nxt: list[int] = []
            for u in frontier:
                for v in np.flatnonzero(adj[u]):
                    if labels[v] < 0:
                        labels[v] = cur
                        nxt.append(int(v))
            frontier = nxt
        cur += 1
    return labels


def modularity(adj: np.ndarray, communities: np.ndarray) -> float:
    """Newman modularity Q for a hard partition."""
    m2 = adj.sum()  # 2 * |E|
    if m2 == 0:
        return 0.0
    k = degree(adj).astype(np.float64)
    same = communities[:, None] == communities[None, :]
    q = (adj.astype(np.float64) - np.outer(k, k) / m2) * same
    return float(q.sum() / m2)


def clustering_coefficient(adj: np.ndarray) -> float:
    """Global (transitivity) clustering coefficient: 3*triangles / open triads."""
    a = adj.astype(np.float64)
    deg = a.sum(axis=1)
    triangles = float(np.trace(a @ a @ a)) / 6.0
    triads = float((deg * (deg - 1)).sum()) / 2.0
    return 0.0 if triads == 0 else 3.0 * triangles / triads


def graph_summary(g: Graph, *, max_dense_n: int = 2048) -> dict[str, Any]:
    """Realized-graph properties as one JSON-able dict.

    This is the graph side of the experiment harness's analysis join: every
    sweep run records ``graph_summary(realized graph)`` next to its training
    curves so topology properties (degree spread, modularity, clustering) can
    be regressed against knowledge-spread speed. O(N^3) quantities
    (clustering) are skipped above ``max_dense_n`` and reported as None.
    """
    deg = g.degrees().astype(np.float64)
    n = g.num_nodes
    comps = connected_components(g.adj)
    out: dict[str, Any] = {
        "name": g.name,
        "nodes": n,
        "edges": g.num_edges,
        "density": (2.0 * g.num_edges / (n * (n - 1))) if n > 1 else 0.0,
        "degree_min": int(deg.min()) if n else 0,
        "degree_max": int(deg.max()) if n else 0,
        "degree_mean": float(deg.mean()) if n else 0.0,
        "degree_std": float(deg.std()) if n else 0.0,
        "components": int(comps.max()) + 1 if n else 0,
        "modularity": None if g.blocks is None else modularity(g.adj, g.blocks),
        "clustering": clustering_coefficient(g.adj) if n <= max_dense_n else None,
    }
    return out


def external_edge_counts(g: Graph) -> np.ndarray:
    """Per-community counts of edges pointing to each other community
    (paper Table 1's bracketed numbers). Returns (B, B) with zero diagonal."""
    if g.blocks is None:
        raise ValueError("graph has no community labels")
    b = int(g.blocks.max()) + 1
    counts = np.zeros((b, b), dtype=np.int64)
    ii, jj = np.nonzero(np.triu(g.adj, k=1))
    for u, v in zip(ii, jj):
        bu, bv = g.blocks[u], g.blocks[v]
        if bu != bv:
            counts[bu, bv] += 1
            counts[bv, bu] += 1
    return counts
