"""Network-topology generators and graph metrics.

Implements the three families studied in the paper (§4): Erdős–Rényi (ER),
Barabási–Albert (BA) and the Stochastic Block Model (SBM), plus the metrics
the paper's analysis relies on (degree distribution, connectivity threshold
p*, modularity, per-community external-edge counts).

Everything is pure numpy (seeded, deterministic); graphs are returned as a
small `Graph` dataclass holding a dense boolean adjacency matrix — at the
paper's scale (N=100) dense is both simpler and faster on accelerators, and
the mixing matrix downstream (core/mixing.py) is dense anyway.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "Graph",
    "erdos_renyi",
    "barabasi_albert",
    "stochastic_block_model",
    "er_critical_p",
    "degree",
    "connected_components",
    "modularity",
    "external_edge_counts",
]


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected, unweighted graph as a dense symmetric adjacency matrix.

    Attributes:
      adj: (N, N) bool ndarray, symmetric, zero diagonal.
      blocks: optional (N,) int ndarray of community labels (SBM only).
      name: human-readable description of the generator + params.
    """

    adj: np.ndarray
    blocks: np.ndarray | None = None
    name: str = "graph"

    def __post_init__(self):
        a = self.adj
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"adjacency must be square, got {a.shape}")
        if a.dtype != np.bool_:
            raise ValueError("adjacency must be boolean")
        if not np.array_equal(a, a.T):
            raise ValueError("adjacency must be symmetric (undirected graph)")
        if np.any(np.diag(a)):
            raise ValueError("adjacency must have a zero diagonal")

    @property
    def num_nodes(self) -> int:
        return self.adj.shape[0]

    @property
    def num_edges(self) -> int:
        return int(self.adj.sum()) // 2

    def degrees(self) -> np.ndarray:
        return degree(self.adj)

    def neighbors(self, i: int) -> np.ndarray:
        return np.flatnonzero(self.adj[i])


def er_critical_p(n: int) -> float:
    """Sharp connectivity threshold p* = ln(N)/N for ER graphs [Erdős–Rényi 1960]."""
    return math.log(n) / n


def erdos_renyi(n: int, p: float, *, seed: int) -> Graph:
    """ER random graph: each of the C(n,2) edges exists i.i.d. w.p. ``p``."""
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"p must be in [0,1], got {p}")
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < p
    adj = np.triu(upper, k=1)
    adj = adj | adj.T
    return Graph(adj=adj, name=f"er(n={n},p={p})")


def barabasi_albert(n: int, m: int, *, seed: int) -> Graph:
    """BA preferential-attachment graph.

    Starts from a star over the first ``m + 1`` nodes, then each new node
    attaches to ``m`` distinct existing nodes sampled proportionally to their
    current degree (the classic repeated-nodes urn construction).
    """
    if m < 1 or m >= n:
        raise ValueError(f"need 1 <= m < n, got m={m}, n={n}")
    rng = np.random.default_rng(seed)
    adj = np.zeros((n, n), dtype=np.bool_)
    # Seed graph: star over nodes [0, m] — every node has degree >= 1 so the
    # preferential urn is well defined from the first attachment step.
    for i in range(1, m + 1):
        adj[0, i] = adj[i, 0] = True
    # Urn of endpoints: one entry per half-edge, so sampling uniformly from it
    # is sampling proportionally to degree.
    urn: list[int] = []
    for i in range(m + 1):
        urn.extend([i] * int(adj[i].sum()))
    for new in range(m + 1, n):
        targets: set[int] = set()
        while len(targets) < m:
            targets.add(int(urn[rng.integers(len(urn))]))
        for t in targets:
            adj[new, t] = adj[t, new] = True
            urn.extend([new, t])
    return Graph(adj=adj, name=f"ba(n={n},m={m})")


def stochastic_block_model(
    block_sizes: Sequence[int],
    p_in: float | Sequence[float],
    p_out: float,
    *,
    seed: int,
) -> Graph:
    """SBM with within-block prob ``p_in`` (scalar or per-block) and
    cross-block prob ``p_out``."""
    sizes = np.asarray(block_sizes, dtype=np.int64)
    n = int(sizes.sum())
    b = len(sizes)
    p_in_vec = np.full(b, p_in, dtype=np.float64) if np.isscalar(p_in) else np.asarray(p_in, dtype=np.float64)
    if p_in_vec.shape != (b,):
        raise ValueError("p_in must be scalar or one value per block")
    labels = np.repeat(np.arange(b), sizes)
    # Edge probability matrix P[i, j] by block membership.
    pmat = np.full((n, n), p_out, dtype=np.float64)
    same = labels[:, None] == labels[None, :]
    pmat[same] = p_in_vec[labels[np.nonzero(same)[0]]]
    rng = np.random.default_rng(seed)
    upper = rng.random((n, n)) < pmat
    adj = np.triu(upper, k=1)
    adj = adj | adj.T
    return Graph(
        adj=adj,
        blocks=labels,
        name=f"sbm(sizes={list(block_sizes)},p_in={p_in},p_out={p_out})",
    )


def degree(adj: np.ndarray) -> np.ndarray:
    return adj.sum(axis=1).astype(np.int64)


def connected_components(adj: np.ndarray) -> np.ndarray:
    """Label connected components via BFS. Returns (N,) int labels."""
    n = adj.shape[0]
    labels = np.full(n, -1, dtype=np.int64)
    cur = 0
    for start in range(n):
        if labels[start] >= 0:
            continue
        frontier = [start]
        labels[start] = cur
        while frontier:
            nxt: list[int] = []
            for u in frontier:
                for v in np.flatnonzero(adj[u]):
                    if labels[v] < 0:
                        labels[v] = cur
                        nxt.append(int(v))
            frontier = nxt
        cur += 1
    return labels


def modularity(adj: np.ndarray, communities: np.ndarray) -> float:
    """Newman modularity Q for a hard partition."""
    m2 = adj.sum()  # 2 * |E|
    if m2 == 0:
        return 0.0
    k = degree(adj).astype(np.float64)
    same = communities[:, None] == communities[None, :]
    q = (adj.astype(np.float64) - np.outer(k, k) / m2) * same
    return float(q.sum() / m2)


def external_edge_counts(g: Graph) -> np.ndarray:
    """Per-community counts of edges pointing to each other community
    (paper Table 1's bracketed numbers). Returns (B, B) with zero diagonal."""
    if g.blocks is None:
        raise ValueError("graph has no community labels")
    b = int(g.blocks.max()) + 1
    counts = np.zeros((b, b), dtype=np.int64)
    ii, jj = np.nonzero(np.triu(g.adj, k=1))
    for u, v in zip(ii, jj):
        bu, bv = g.blocks[u], g.blocks[v]
        if bu != bv:
            counts[bu, bv] += 1
            counts[bv, bu] += 1
    return counts
