"""Machine fingerprint for benchmark provenance.

Benchmark baselines in the BENCH_*.json files are machine-relative: CI
regenerates them from scratch before guarding, but the committed snapshots
are also read by humans, and a re-baseline is only auditable if the file
says WHERE its numbers came from. Every bench writer embeds this fingerprint
so a large swing between two committed snapshots can be attributed (same
machine -> investigate the code; different machine -> runner variance is a
plausible cause and a same-machine bisect is the next step).

Deliberately excludes anything volatile (load averages, timestamps beyond
the date) so regenerating on the same box yields a stable fingerprint.
"""

from __future__ import annotations

import os
import platform


def machine_fingerprint() -> dict:
    """Stable description of the host + JAX stack a benchmark ran on."""
    import jax

    return {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "devices": [str(d) for d in jax.devices()],
    }
