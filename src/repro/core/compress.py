"""Gossip compression: top-k delta sparsification with reference tracking.

The paper's related work ([8], Sun et al.) pairs decentralized averaging
with quantization to cut communication. We implement top-k delta
compression: each node transmits only the k largest-magnitude entries of
``params - reference``, where ``reference`` is the model its peers
currently hold. Error feedback is *implicit* in the reference: whatever was
not transmitted stays in ``params - reference`` and competes again next
round (an explicit error buffer on top of reference tracking double-counts
the residual and diverges — found by test_error_feedback_catches_up).

Composition with DecAvg: nodes gossip ``reference + sparse_delta`` instead
of raw weights; with the sparse permute schedule (EXPERIMENTS §Perf H2) the
wire volume multiplies: degree x k_frac x member bytes.

Pure-pytree API, vmappable over the node axis like everything else.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class CompressState(NamedTuple):
    reference: PyTree  # what peers currently hold for this node


def init(params: PyTree) -> CompressState:
    # Genuine copies, not astype views: astype(f32) on f32 leaves returns the
    # SAME buffer, and a reference that aliases params breaks callers that
    # donate both to one jitted step ("donate the same buffer twice").
    return CompressState(
        reference=jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params
        )
    )


def _topk_mask(x: jax.Array, k_frac: float) -> jax.Array:
    """Exact top-k mask (index scatter — a >=threshold test over-selects
    whenever magnitudes tie)."""
    flat = jnp.abs(x.reshape(-1))
    k = max(1, int(k_frac * flat.size))
    _, idx = jax.lax.top_k(flat, k)
    mask = jnp.zeros((flat.size,), x.dtype).at[idx].set(1.0)
    return mask.reshape(x.shape)


def compress(
    params: PyTree, state: CompressState, *, k_frac: float = 0.05
) -> tuple[PyTree, CompressState]:
    """Returns (sparse_delta, new_state).

    sparse_delta has ceil(k_frac * size) nonzeros per leaf (wire payload is
    k indices + k values); the reference advances by what was sent, so the
    residual automatically re-enters the next round's selection.
    """
    sent = jax.tree.map(
        lambda p, r: (p.astype(jnp.float32) - r)
        * _topk_mask(p.astype(jnp.float32) - r, k_frac),
        params,
        state.reference,
    )
    ref = jax.tree.map(lambda r, s: r + s, state.reference, sent)
    return sent, CompressState(ref)


def reconstruct(state: CompressState) -> PyTree:
    """The model every peer currently holds for this node."""
    return state.reference


def wire_bytes(params: PyTree, *, k_frac: float) -> int:
    """Per-round payload: k values (f32) + k indices (s32) per leaf."""
    total = 0
    for leaf in jax.tree.leaves(params):
        k = max(1, int(k_frac * leaf.size))
        total += k * 8
    return total
