"""DecAvg: one communication round of decentralized averaging (paper Eq. 1).

All per-node model state is *node-stacked*: every leaf of the parameter
pytree carries a leading ``node`` axis of size N. One communication round is
then the linear map ``P <- W @ P`` applied leaf-wise, where W is the
(N, N) row-stochastic mixing matrix from core/mixing.py.

Three execution paths, all numerically equivalent (tests assert allclose):

1. ``mix_dense``      — XLA einsum per leaf. The default on any backend.
2. ``mix_pallas``     — Pallas blocked-matmul kernel (kernels/gossip_mix.py)
                        per flattened leaf; MXU-tiled for TPU, validated in
                        interpret mode on CPU.
3. ``mix_sharded``    — explicit shard_map collective schedule for a node
                        axis sharded across a mesh axis; two schedules:
                        "allgather" (gather all nodes, multiply locally) and
                        "reduce_scatter" (scatter W-weighted contributions).
                        The RS schedule keeps peak memory at O(P·N/shards)
                        instead of O(P·N) — this is the form used at LLM
                        cohort scale.

The mixing accumulates in float32 regardless of parameter dtype (bf16 models
still contract toward consensus without rounding bias), then casts back.
"""

from __future__ import annotations

import functools
from typing import Any, Literal

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["mix_dense", "mix_pallas", "mix_sharded", "gossip_error"]

PyTree = Any


def _mix_leaf(w: jax.Array, leaf: jax.Array) -> jax.Array:
    """(N,N) x (N, ...) contraction over the node axis, f32 accumulation.

    No reshape: flattening (N, V, d) to (N, V*d) would merge a sharded dim
    and force GSPMD into a full rematerialization (replicating every node's
    params on every device — observed as an 80 GB/device dry-run). The
    dot_general below contracts the node axis in place, so inner-dim
    shardings propagate and a sharded node axis lowers to collectives only
    on the node dimension.
    """
    n = w.shape[0]
    if leaf.shape[0] != n:
        raise ValueError(f"leaf leading axis {leaf.shape[0]} != num_nodes {n}")
    # Output in the leaf dtype: an f32 preferred_element_type materializes a
    # param-sized f32 temporary per leaf (GBs/device at 100B+ scale). The
    # MXU accumulates bf16 dots in f32 internally regardless; for very wide
    # graphs (N=100 paper sims run in f32 anyway) precision is preserved by
    # the f32 leaf dtype itself.
    out = jax.lax.dot_general(
        w.astype(jnp.float32).astype(leaf.dtype),
        leaf,
        (((1,), (0,)), ((), ())),
        preferred_element_type=leaf.dtype,
    )
    return out


def mix_dense(w: jax.Array, params: PyTree) -> PyTree:
    """DecAvg round via per-leaf einsum (paper-faithful reference path)."""
    return jax.tree.map(functools.partial(_mix_leaf, w), params)


def mix_pallas(w: jax.Array, params: PyTree, *, interpret: bool | None = None) -> PyTree:
    """DecAvg round via the Pallas gossip_mix kernel (per flattened leaf)."""
    from repro.kernels import ops  # local import: kernels are optional at import time

    def mix(leaf: jax.Array) -> jax.Array:
        n = w.shape[0]
        flat = leaf.reshape(n, -1)
        out = ops.gossip_mix(w, flat, interpret=interpret)
        return out.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree.map(mix, params)


def mix_sharded(
    w: jax.Array,
    params: PyTree,
    *,
    mesh: jax.sharding.Mesh,
    node_axis: str | tuple[str, ...] = "data",
    schedule: Literal["allgather", "reduce_scatter"] = "reduce_scatter",
) -> PyTree:
    """DecAvg round with the node axis sharded over ``node_axis`` of ``mesh``.

    W is replicated (it is tiny: N^2 floats). Per-leaf inner sharding is
    preserved by passing everything through shard_map with generic specs on
    the trailing dims (we only touch axis 0).

    - allgather:      gather the full node axis, multiply my W-row-block.
      Moves P·(S-1)/S bytes in, peak memory O(P·N).
    - reduce_scatter: multiply my W-column-block by my params (my nodes'
      contributions to everyone), then reduce-scatter over the node axis.
      Moves the same bytes out, peak memory O(P·N/S). Preferred at scale.
    """
    axes = (node_axis,) if isinstance(node_axis, str) else tuple(node_axis)
    shards = 1
    for a in axes:
        shards *= mesh.shape[a]
    n = w.shape[0]
    if n % shards:
        raise ValueError(f"num_nodes {n} not divisible by node shards {shards}")

    def body(w_full: jax.Array, leaf: jax.Array) -> jax.Array:
        # leaf: (n/shards, ...) local block of the node axis.
        idx = jax.lax.axis_index(axes)
        blk = n // shards
        flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
        wf = w_full.astype(jnp.float32)
        if schedule == "allgather":
            full = jax.lax.all_gather(flat, axes, axis=0, tiled=True)  # (n, p)
            rows = jax.lax.dynamic_slice_in_dim(wf, idx * blk, blk, axis=0)
            out = rows @ full
        else:
            cols = jax.lax.dynamic_slice_in_dim(wf, idx * blk, blk, axis=1)  # (n, blk)
            contrib = cols @ flat  # (n, p): my nodes' contribution to everyone
            out = jax.lax.psum_scatter(contrib, axes, scatter_dimension=0, tiled=True)
        return out.reshape(leaf.shape).astype(leaf.dtype)

    def mix_one(leaf: jax.Array) -> jax.Array:
        spec = P(axes, *([None] * (leaf.ndim - 1)))
        return jax.shard_map(
            functools.partial(body),
            mesh=mesh,
            in_specs=(P(), spec),
            out_specs=spec,
        )(w, leaf)

    return jax.tree.map(mix_one, params)


def mix_permute(
    w: jax.Array | Any,
    params: PyTree,
    colors: list[list[tuple[int, int]]],
    *,
    mesh: jax.sharding.Mesh,
    node_axis: str = "data",
) -> PyTree:
    """Sparse topology-aware DecAvg round via edge-colored ppermutes.

    Requires num_nodes == mesh.shape[node_axis] (one node per device row).
    Each color class (a matching, from mixing.edge_coloring) becomes ONE
    ``ppermute``; wire volume per device is O(degree) member-shards instead
    of the dense einsum's O(N) all-gather — the paper's sparse topology IS
    the collective schedule. Numerically identical to ``mix_dense`` with the
    same W (tests assert allclose); W entries off the graph support are
    ignored by construction.
    """
    import numpy as np

    k = mesh.shape[node_axis]
    if w.shape[0] != k:
        raise ValueError(
            f"mix_permute needs num_nodes == |{node_axis}| ({k}), got {w.shape[0]}"
        )
    # W may be a tracer (it is a train_step input): build the per-color
    # coefficient vectors with jnp gathers, not host numpy.
    wf = jnp.asarray(w, jnp.float32)
    self_coef = jnp.diagonal(wf)  # (K,)
    color_coefs = []
    for pairs in colors:
        srcs = np.array([s for s, _ in pairs], np.int32)
        dsts = np.array([d for _, d in pairs], np.int32)
        vec = jnp.zeros((k,), jnp.float32).at[dsts].set(wf[dsts, srcs])
        color_coefs.append(vec)

    other_axes = frozenset(a for a in mesh.axis_names if a != node_axis)

    def body(leaf: jax.Array) -> jax.Array:
        # leaf: (1, ...) — this device row's node shard.
        i = jax.lax.axis_index(node_axis)
        xf = leaf.astype(jnp.float32)
        acc = xf * self_coef[i]
        for pairs, vec in zip(colors, color_coefs):
            y = jax.lax.ppermute(xf, node_axis, pairs)
            acc = acc + y * vec[i]
        return acc.astype(leaf.dtype)

    def mix_one(leaf: jax.Array) -> jax.Array:
        spec = P(node_axis, *([None] * (leaf.ndim - 1)))
        return jax.shard_map(
            body,
            mesh=mesh,
            in_specs=spec,
            out_specs=spec,
            axis_names=frozenset({node_axis}),
        )(leaf)

    return jax.tree.map(mix_one, params)


def gossip_error(params: PyTree) -> jax.Array:
    """Consensus distance: mean over leaves of ||w_i - mean_i w_i||^2 / ||mean||^2.

    The quantity the spectral gap contracts per round; benchmarks report it to
    connect topology properties to knowledge-spread speed.
    """
    def leaf_err(leaf: jax.Array) -> jax.Array:
        f = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
        mean = f.mean(axis=0, keepdims=True)
        num = jnp.sum((f - mean) ** 2)
        den = jnp.sum(mean**2) * f.shape[0] + 1e-12
        return num / den

    errs = [leaf_err(l) for l in jax.tree.leaves(params)]
    return jnp.mean(jnp.stack(errs))
