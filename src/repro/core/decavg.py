"""DecAvg: one communication round of decentralized averaging (paper Eq. 1).

All per-node model state is *node-stacked*: every leaf of the parameter
pytree carries a leading ``node`` axis of size N. One communication round is
then the linear map ``P <- W @ P`` applied leaf-wise, where W is the
(N, N) row-stochastic mixing matrix from core/mixing.py.

Three execution paths, all numerically equivalent (tests assert allclose):

1. ``mix_dense``      — XLA einsum per leaf. The default on any backend.
2. ``mix_pallas``     — Pallas blocked-matmul kernel (kernels/gossip_mix.py)
                        per flattened leaf; MXU-tiled for TPU, validated in
                        interpret mode on CPU.
3. ``mix_sharded``    — explicit shard_map collective schedule for a node
                        axis sharded across a mesh axis; two schedules:
                        "allgather" (gather all nodes, multiply locally) and
                        "reduce_scatter" (scatter W-weighted contributions).
                        The RS schedule keeps peak memory at O(P·N/shards)
                        instead of O(P·N) — this is the form used at LLM
                        cohort scale.

Plus the sparse large-N paths (core/sparse.py): CSR segment-sum, the Pallas
blocked-ELL kernel, and ``mix_sharded_sparse`` — the CSR round with the node
axis sharded over a mesh axis (per-shard row ranges, compact halo buffers
for cross-shard neighbors, assembled by an allgather or ring-ppermute
``halo_schedule``). All O(E·P) per round instead of O(N²·P); the sharded
variant additionally splits the work S ways, and the ring schedule bounds
per-device wire to O(H·P).

``GossipEngine`` is the one front door over all of them: it owns the
topology (static graph or TopologySchedule), builds + caches the mixing
matrix per schedule period, capability-checks the requested backend, and
applies the per-round gossip cadence (``gossip_every`` / identity rounds)
that call sites used to reimplement inline. For fused runs,
``GossipEngine.program(rounds)`` materializes *all* schedule periods up
front as a ``MixingProgram`` (stacked dense W, uniformly padded stacked
CSR, stacked blocked-ELL tiles, or stacked per-shard ``ShardedCSR``
metadata) whose per-round operator is selected by index inside a
``lax.scan`` body — no per-period re-jit (train/trainer.py ``run_fused``).
For the sharded kind the ring/allgather halo exchange itself runs inside
the scan body under ``shard_map``, so a whole multi-host run is one
compiled SPMD program.

Precision contract: the sparse and shard_map paths accumulate in float32
regardless of parameter dtype, then cast back. The dense einsum path
(``mix_dense``/``_mix_leaf``) instead accumulates in the *leaf dtype* — an
f32 ``preferred_element_type`` would materialize a param-sized f32 temporary
per leaf (GBs/device at LLM scale), and the MXU accumulates bf16 dots in f32
internally anyway; tests/test_decavg.py pins the resulting bf16-vs-f32
tolerance. Run in f32 (the paper's sims do) when bit-level dense/sparse
agreement matters.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = [
    "GossipEngine",
    "MixingProgram",
    "mix_dense",
    "mix_pallas",
    "mix_sharded",
    "mix_sharded_sparse",
    "mix_sharded_sparse_faulted",
    "mix_permute",
    "gossip_error",
]

PyTree = Any


def _shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """jax.shard_map across jax versions (experimental home before 0.5).

    ``axis_names`` (the manual axes) maps to the experimental API's ``auto``
    complement when running on older jax.
    """
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": axis_names}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map  # jax < 0.5

    kw = {}
    if axis_names is not None and set(axis_names) != set(mesh.axis_names):
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def _mix_leaf(w: jax.Array, leaf: jax.Array) -> jax.Array:
    """(N,N) x (N, ...) contraction over the node axis, f32 accumulation.

    No reshape: flattening (N, V, d) to (N, V*d) would merge a sharded dim
    and force GSPMD into a full rematerialization (replicating every node's
    params on every device — observed as an 80 GB/device dry-run). The
    dot_general below contracts the node axis in place, so inner-dim
    shardings propagate and a sharded node axis lowers to collectives only
    on the node dimension.
    """
    n = w.shape[0]
    if leaf.shape[0] != n:
        raise ValueError(f"leaf leading axis {leaf.shape[0]} != num_nodes {n}")
    # Output in the leaf dtype: an f32 preferred_element_type materializes a
    # param-sized f32 temporary per leaf (GBs/device at 100B+ scale). The
    # MXU accumulates bf16 dots in f32 internally regardless; for very wide
    # graphs (N=100 paper sims run in f32 anyway) precision is preserved by
    # the f32 leaf dtype itself.
    out = jax.lax.dot_general(
        w.astype(jnp.float32).astype(leaf.dtype),
        leaf,
        (((1,), (0,)), ((), ())),
        preferred_element_type=leaf.dtype,
    )
    return out


def mix_dense(w: jax.Array, params: PyTree) -> PyTree:
    """DecAvg round via per-leaf einsum (paper-faithful reference path)."""
    return jax.tree.map(functools.partial(_mix_leaf, w), params)


def mix_pallas(w: jax.Array, params: PyTree, *, interpret: bool | None = None) -> PyTree:
    """DecAvg round via the Pallas gossip_mix kernel (per flattened leaf)."""
    from repro.kernels import ops  # local import: kernels are optional at import time

    def mix(leaf: jax.Array) -> jax.Array:
        n = w.shape[0]
        flat = leaf.reshape(n, -1)
        out = ops.gossip_mix(w, flat, interpret=interpret)
        return out.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree.map(mix, params)


def mix_sharded(
    w: jax.Array,
    params: PyTree,
    *,
    mesh: jax.sharding.Mesh,
    node_axis: str | tuple[str, ...] = "data",
    schedule: Literal["allgather", "reduce_scatter"] = "reduce_scatter",
) -> PyTree:
    """DecAvg round with the node axis sharded over ``node_axis`` of ``mesh``.

    W is replicated (it is tiny: N^2 floats). Per-leaf inner sharding is
    preserved by passing everything through shard_map with generic specs on
    the trailing dims (we only touch axis 0).

    - allgather:      gather the full node axis, multiply my W-row-block.
      Moves P·(S-1)/S bytes in, peak memory O(P·N).
    - reduce_scatter: multiply my W-column-block by my params (my nodes'
      contributions to everyone), then reduce-scatter over the node axis.
      Moves the same bytes out, peak memory O(P·N/S). Preferred at scale.
    """
    axes = (node_axis,) if isinstance(node_axis, str) else tuple(node_axis)
    shards = 1
    for a in axes:
        shards *= mesh.shape[a]
    n = w.shape[0]
    if n % shards:
        raise ValueError(f"num_nodes {n} not divisible by node shards {shards}")

    def body(w_full: jax.Array, leaf: jax.Array) -> jax.Array:
        # leaf: (n/shards, ...) local block of the node axis.
        idx = jax.lax.axis_index(axes)
        blk = n // shards
        flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
        wf = w_full.astype(jnp.float32)
        if schedule == "allgather":
            full = jax.lax.all_gather(flat, axes, axis=0, tiled=True)  # (n, p)
            rows = jax.lax.dynamic_slice_in_dim(wf, idx * blk, blk, axis=0)
            out = rows @ full
        else:
            cols = jax.lax.dynamic_slice_in_dim(wf, idx * blk, blk, axis=1)  # (n, blk)
            contrib = cols @ flat  # (n, p): my nodes' contribution to everyone
            out = jax.lax.psum_scatter(contrib, axes, scatter_dimension=0, tiled=True)
        return out.reshape(leaf.shape).astype(leaf.dtype)

    def mix_one(leaf: jax.Array) -> jax.Array:
        spec = P(axes, *([None] * (leaf.ndim - 1)))
        return _shard_map(
            functools.partial(body),
            mesh=mesh,
            in_specs=(P(), spec),
            out_specs=spec,
        )(w, leaf)

    return jax.tree.map(mix_one, params)


def _mix_leaves_concatenated(params: PyTree, n: int, mix_cat) -> PyTree:
    """Run ``mix_cat`` ONCE on all leaves' features side by side.

    Mixing is linear over the node axis and columns are independent, so
    concatenating every leaf's flattened features into one (n, P_total) f32
    matrix computes bit-identical results to mixing leaf by leaf — while
    paying the halo exchange (ring ppermutes or allgather) and the
    replicated->sharded boundary movement once per ROUND instead of once per
    leaf. For an MLP that cuts the sharded path's collective count 4x.
    """
    leaves, treedef = jax.tree.flatten(params)
    for leaf in leaves:
        if leaf.shape[0] != n:
            raise ValueError(f"leaf leading axis {leaf.shape[0]} != num_nodes {n}")
    flats = [l.reshape(n, -1).astype(jnp.float32) for l in leaves]
    cat = flats[0] if len(flats) == 1 else jnp.concatenate(flats, axis=1)
    out = mix_cat(cat)
    if len(flats) == 1:
        outs = [out]
    else:
        splits = np.cumsum([f.shape[1] for f in flats])[:-1]
        outs = jnp.split(out, splits, axis=1)
    return jax.tree.unflatten(
        treedef,
        [o.reshape(l.shape).astype(l.dtype) for o, l in zip(outs, leaves)],
    )


def _mix_leaves_concatenated2(params: PyTree, pub: PyTree, n: int, mix_cat2) -> PyTree:
    """Two-tree variant of ``_mix_leaves_concatenated`` for faulted mixing:
    flattens ``params`` (current) and ``pub`` (published snapshots) into
    identically laid out (n, P_total) f32 matrices and runs ``mix_cat2``
    once over both — the faulted round needs both because stragglers gossip
    stale snapshots while the diagonal self-term stays fresh."""
    leaves, treedef = jax.tree.flatten(params)
    pleaves = jax.tree.leaves(pub)
    for leaf in leaves:
        if leaf.shape[0] != n:
            raise ValueError(f"leaf leading axis {leaf.shape[0]} != num_nodes {n}")
    flats = [l.reshape(n, -1).astype(jnp.float32) for l in leaves]
    pflats = [l.reshape(n, -1).astype(jnp.float32) for l in pleaves]
    cat = flats[0] if len(flats) == 1 else jnp.concatenate(flats, axis=1)
    pcat = pflats[0] if len(pflats) == 1 else jnp.concatenate(pflats, axis=1)
    out = mix_cat2(cat, pcat)
    if len(flats) == 1:
        outs = [out]
    else:
        splits = np.cumsum([f.shape[1] for f in flats])[:-1]
        outs = jnp.split(out, splits, axis=1)
    return jax.tree.unflatten(
        treedef,
        [o.reshape(l.shape).astype(l.dtype) for o, l in zip(outs, leaves)],
    )


def _sharded_mix_leaf(
    halo, rows, cols, values, local_src, local_dst, ring_send, ring_recv,
    leaf, *, axes, shards, blk, h, ring, p_chunk,
):
    """Per-device body of one sharded sparse DecAvg round on ONE leaf.

    Runs inside a ``shard_map`` over ``axes``: ``leaf`` is this device's
    (blk, ...) slab of the node axis; the layout arrays arrive replicated
    with a leading (S, ...) axis and are indexed by the device's shard
    position. Shared by ``mix_sharded_sparse`` (one shard_map per call) and
    ``MixingProgram.apply_local`` (the fused trainer's whole-scan shard_map).
    """
    idx = jax.lax.axis_index(axes)
    flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)  # (blk, p)
    if ring:
        # Halo buffer with one scratch row at slot H: padded local/ring
        # destinations point there and are discarded by the slice below.
        buf = jnp.zeros((h + 1, flat.shape[1]), jnp.float32)
        ls = jax.lax.dynamic_index_in_dim(local_src, idx, 0, keepdims=False)
        ld = jax.lax.dynamic_index_in_dim(local_dst, idx, 0, keepdims=False)
        buf = buf.at[ld].set(flat[ls])
        for d, (sidx, rslot) in enumerate(zip(ring_send, ring_recv), 1):
            if sidx.shape[1] == 0:
                continue  # no shard pair exchanges at this distance
            send = jax.lax.dynamic_index_in_dim(sidx, idx, 0, keepdims=False)
            got = jax.lax.ppermute(
                flat[send], axes,
                [(s, (s + d) % shards) for s in range(shards)],
            )
            slot = jax.lax.dynamic_index_in_dim(rslot, idx, 0, keepdims=False)
            buf = buf.at[slot].set(got)
        buf = buf[:h]  # (H, p); cols only ever reference [0, H)
    else:
        full = jax.lax.all_gather(flat, axes, axis=0, tiled=True)  # (n, p)
        need = jax.lax.dynamic_index_in_dim(halo, idx, 0, keepdims=False)
        buf = full[need]  # (H, p): only rows this shard references
    r = jax.lax.dynamic_index_in_dim(rows, idx, 0, keepdims=False)
    c = jax.lax.dynamic_index_in_dim(cols, idx, 0, keepdims=False)
    v = jax.lax.dynamic_index_in_dim(values, idx, 0, keepdims=False)

    def seg(hbuf: jax.Array) -> jax.Array:
        gathered = hbuf[c] * v[:, None]  # (E, pc)
        return jax.ops.segment_sum(
            gathered, r, num_segments=blk, indices_are_sorted=True
        )

    p = flat.shape[1]
    if p_chunk is not None and p_chunk < p:
        pad = (-p) % p_chunk
        if pad:
            buf = jnp.pad(buf, ((0, 0), (0, pad)))
        chunks = buf.reshape(buf.shape[0], -1, p_chunk).transpose(1, 0, 2)
        out = jax.lax.map(seg, chunks)  # serialized: bounds the transient
        out = out.transpose(1, 0, 2).reshape(blk, -1)[:, :p]
    else:
        out = seg(buf)
    return out.reshape(leaf.shape).astype(leaf.dtype)


@functools.partial(
    jax.jit, static_argnames=("mesh", "node_axis", "p_chunk", "halo_schedule")
)
def mix_sharded_sparse(
    shcsr,
    params: PyTree,
    *,
    mesh: jax.sharding.Mesh,
    node_axis: str | tuple[str, ...] = "data",
    p_chunk: int | None = None,
    halo_schedule: Literal["allgather", "ring", "auto"] = "allgather",
) -> PyTree:
    """Sparse DecAvg round with the node axis sharded over ``node_axis``.

    ``shcsr`` is a ``core.sparse.ShardedCSR``: each shard owns a contiguous
    row range of W and stores its entries with halo-local column ids. The
    round per device is

      1. assemble the shard's *halo* — the compact set of source rows its
         W entries actually reference — into an (H, p) buffer,
      2. gather + segment-sum over the shard's nnz entries, O(nnz_s * p).

    Step 1 runs one of two ``halo_schedule``s (numerically identical):

    - "allgather": all_gather the node axis of P, slice the halo rows.
      One collective, O(N * p) wire per device.
    - "ring": S-1 ``ppermute`` steps over the shard ring; step d moves
      exactly the rows each shard needs from its distance-d peer
      (``shcsr.ring_send/ring_recv``), own rows are copied locally. Steps
      with no traffic anywhere compile away, so wire per device is
      O(H * p) — the sparse topology becomes the communication schedule,
      not just the compute schedule.
    - "auto": ring when its modeled wire (``shcsr.ring_width``) undercuts
      the allgather's N - N/S rows, else allgather.

    Compute and W memory are sparse either way (O(nnz/S * P) work per
    device, O(E) total W bytes vs the dense sharded path's O(N^2/S * P)
    matmul and O(N^2) W).

    ``p_chunk`` bounds the per-device gather transient to O(nnz_s * p_chunk)
    (serialized feature-axis chunks, as in ``sparse.mix_sparse``) — use for
    very large per-leaf P at large N.
    """
    axes = (node_axis,) if isinstance(node_axis, str) else tuple(node_axis)
    shards = 1
    for a in axes:
        shards *= mesh.shape[a]
    if shcsr.shards != shards:
        raise ValueError(
            f"ShardedCSR built for {shcsr.shards} shards but mesh axis "
            f"{axes} has {shards}"
        )
    n = shcsr.shape[0]
    blk = shcsr.rows_per_shard
    h = shcsr.halo_width
    if halo_schedule == "auto":
        halo_schedule = "ring" if shcsr.ring_width < n - blk else "allgather"
    if halo_schedule not in ("allgather", "ring"):
        raise ValueError(
            f"halo_schedule must be 'allgather', 'ring' or 'auto', "
            f"got {halo_schedule!r}"
        )
    ring = halo_schedule == "ring"
    body = functools.partial(
        _sharded_mix_leaf, axes=axes, shards=shards, blk=blk, h=h,
        ring=ring, p_chunk=p_chunk,
    )

    def mix_cat(cat: jax.Array) -> jax.Array:
        spec = P(axes, None)
        return _shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(), P(), P(), P(), P(), P(), P(), spec),
            out_specs=spec,
        )(shcsr.halo, shcsr.rows, shcsr.cols, shcsr.values,
          shcsr.local_src, shcsr.local_dst, shcsr.ring_send, shcsr.ring_recv,
          cat)

    return _mix_leaves_concatenated(params, n, mix_cat)


def _sharded_mix_leaf_faulted(
    halo, rows, cols, values, keep, alive, local_src, local_dst,
    ring_send, ring_recv, cur, pub, *, axes, shards, blk, h, ring,
):
    """Faulted twin of ``_sharded_mix_leaf``: one shard's renormalized mix.

    Two data slabs instead of one: ``cur`` (this shard's current params)
    and ``pub`` (its *published* snapshots — stale for stragglers). The
    halo exchange moves published rows; ``keep`` arrives as the round's
    (S, E) entry mask and ``alive`` as the replicated (N,) node mask. The
    round per shard is ``segment_sum(pub_halo * W_renorm) + diag * (cur -
    pub)`` with dead / empty rows passing ``cur`` through bit-unchanged —
    identical semantics to ``faults.mix_faulted_csr`` on global ids, so
    loop and fused faulted sharded runs agree exactly.
    """
    from repro.core import faults as _faults

    idx = jax.lax.axis_index(axes)
    curf = cur.reshape(cur.shape[0], -1).astype(jnp.float32)  # (blk, p)
    pubf = pub.reshape(pub.shape[0], -1).astype(jnp.float32)
    halo_s = jax.lax.dynamic_index_in_dim(halo, idx, 0, keepdims=False)
    if ring:
        buf = jnp.zeros((h + 1, pubf.shape[1]), jnp.float32)
        ls = jax.lax.dynamic_index_in_dim(local_src, idx, 0, keepdims=False)
        ld = jax.lax.dynamic_index_in_dim(local_dst, idx, 0, keepdims=False)
        buf = buf.at[ld].set(pubf[ls])
        for d, (sidx, rslot) in enumerate(zip(ring_send, ring_recv), 1):
            if sidx.shape[1] == 0:
                continue
            send = jax.lax.dynamic_index_in_dim(sidx, idx, 0, keepdims=False)
            got = jax.lax.ppermute(
                pubf[send], axes,
                [(s, (s + d) % shards) for s in range(shards)],
            )
            slot = jax.lax.dynamic_index_in_dim(rslot, idx, 0, keepdims=False)
            buf = buf.at[slot].set(got)
        buf = buf[:h]
    else:
        full = jax.lax.all_gather(pubf, axes, axis=0, tiled=True)  # (n, p)
        buf = full[halo_s]
    r = jax.lax.dynamic_index_in_dim(rows, idx, 0, keepdims=False)
    c = jax.lax.dynamic_index_in_dim(cols, idx, 0, keepdims=False)
    v = jax.lax.dynamic_index_in_dim(values, idx, 0, keepdims=False)
    k = jax.lax.dynamic_index_in_dim(keep, idx, 0, keepdims=False)
    vn, ok = _faults.renorm_values(v, k, r, blk)
    # Diagonal coefficient per local row: entries whose global source is
    # the destination itself (padded slots carry v == 0, so a spurious
    # halo-pad match contributes nothing).
    is_diag = halo_s[c] == idx * blk + r
    dcoef = jax.ops.segment_sum(
        jnp.where(is_diag, vn, 0.0), r, num_segments=blk,
        indices_are_sorted=True,
    )
    # Off-diagonal rewrite (cf. faults.mix_faulted_csr): stale publishes
    # flow through non-self entries only, the fresh self term is added
    # directly — one fewer params-sized elementwise pass per round.
    vn_od = jnp.where(is_diag, 0.0, vn)
    out = jax.ops.segment_sum(
        buf[c] * vn_od[:, None], r, num_segments=blk, indices_are_sorted=True
    )
    out = out + dcoef[:, None] * curf
    alive_s = jax.lax.dynamic_slice_in_dim(alive, idx * blk, blk)
    okr = ok & alive_s
    out = jnp.where(okr[:, None], out, curf)
    return out.reshape(cur.shape).astype(cur.dtype)


@functools.partial(
    jax.jit, static_argnames=("mesh", "node_axis", "halo_schedule")
)
def mix_sharded_sparse_faulted(
    shcsr,
    params: PyTree,
    pub: PyTree,
    keep: jax.Array,
    alive: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    node_axis: str | tuple[str, ...] = "data",
    halo_schedule: Literal["allgather", "ring", "auto"] = "allgather",
) -> PyTree:
    """One faulted sharded sparse DecAvg round (cf. ``mix_sharded_sparse``).

    ``keep`` is the round's (S, E) per-shard entry mask and ``alive`` the
    (N,) node mask (both replicated — they are tiny next to P). ``pub`` is
    the published-snapshot pytree (pass ``params`` when no stragglers).
    Feature-axis chunking is not supported under faults (the engine rejects
    the combination): the renormalization is per-entry, so the chunked
    serialization would recompute it per chunk for no transient win.
    """
    axes = (node_axis,) if isinstance(node_axis, str) else tuple(node_axis)
    shards = 1
    for a in axes:
        shards *= mesh.shape[a]
    if shcsr.shards != shards:
        raise ValueError(
            f"ShardedCSR built for {shcsr.shards} shards but mesh axis "
            f"{axes} has {shards}"
        )
    n = shcsr.shape[0]
    blk = shcsr.rows_per_shard
    h = shcsr.halo_width
    if halo_schedule == "auto":
        halo_schedule = "ring" if shcsr.ring_width < n - blk else "allgather"
    ring = halo_schedule == "ring"
    body = functools.partial(
        _sharded_mix_leaf_faulted, axes=axes, shards=shards, blk=blk, h=h,
        ring=ring,
    )

    def mix_cat2(cat: jax.Array, pcat: jax.Array) -> jax.Array:
        spec = P(axes, None)
        return _shard_map(
            body,
            mesh=mesh,
            in_specs=(P(),) * 10 + (spec, spec),
            out_specs=spec,
        )(shcsr.halo, shcsr.rows, shcsr.cols, shcsr.values, keep, alive,
          shcsr.local_src, shcsr.local_dst, shcsr.ring_send, shcsr.ring_recv,
          cat, pcat)

    return _mix_leaves_concatenated2(params, pub, n, mix_cat2)


def mix_permute(
    w: jax.Array | Any,
    params: PyTree,
    colors: list[list[tuple[int, int]]],
    *,
    mesh: jax.sharding.Mesh,
    node_axis: str = "data",
) -> PyTree:
    """Sparse topology-aware DecAvg round via edge-colored ppermutes.

    Requires num_nodes == mesh.shape[node_axis] (one node per device row).
    Each color class (a matching, from mixing.edge_coloring) becomes ONE
    ``ppermute``; wire volume per device is O(degree) member-shards instead
    of the dense einsum's O(N) all-gather — the paper's sparse topology IS
    the collective schedule. Numerically identical to ``mix_dense`` with the
    same W (tests assert allclose); W entries off the graph support are
    ignored by construction.
    """
    k = mesh.shape[node_axis]
    if w.shape[0] != k:
        raise ValueError(
            f"mix_permute needs num_nodes == |{node_axis}| ({k}), got {w.shape[0]}"
        )
    # W may be a tracer (it is a train_step input): build the per-color
    # coefficient vectors with jnp gathers, not host numpy.
    wf = jnp.asarray(w, jnp.float32)
    self_coef = jnp.diagonal(wf)  # (K,)
    color_coefs = []
    for pairs in colors:
        srcs = np.array([s for s, _ in pairs], np.int32)
        dsts = np.array([d for _, d in pairs], np.int32)
        vec = jnp.zeros((k,), jnp.float32).at[dsts].set(wf[dsts, srcs])
        color_coefs.append(vec)

    def body(leaf: jax.Array) -> jax.Array:
        # leaf: (1, ...) — this device row's node shard.
        i = jax.lax.axis_index(node_axis)
        xf = leaf.astype(jnp.float32)
        acc = xf * self_coef[i]
        for pairs, vec in zip(colors, color_coefs):
            y = jax.lax.ppermute(xf, node_axis, pairs)
            acc = acc + y * vec[i]
        return acc.astype(leaf.dtype)

    def mix_one(leaf: jax.Array) -> jax.Array:
        spec = P(node_axis, *([None] * (leaf.ndim - 1)))
        return _shard_map(
            body,
            mesh=mesh,
            in_specs=spec,
            out_specs=spec,
            axis_names=frozenset({node_axis}),
        )(leaf)

    return jax.tree.map(mix_one, params)


# ---------------------------------------------------------------------------
# MixingProgram: all schedule periods staged up front for a fused lax.scan
# ---------------------------------------------------------------------------


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=(
        "w", "rows", "cols", "values", "period_idx", "gossip_mask",
        "pad_ratio", "bell_idx", "bell_val",
        "sh_halo", "sh_rows", "sh_cols", "sh_values",
        "sh_local_src", "sh_local_dst", "sh_ring_send", "sh_ring_recv",
        "f_alive", "f_keep", "f_delay",
    ),
    meta_fields=(
        "kind", "n", "num_periods", "cadence", "p_chunk",
        "interpret", "mesh", "node_axis", "shards", "halo_schedule",
        "faulted", "delay_max",
    ),
)
@dataclasses.dataclass(frozen=True)
class MixingProgram:
    """Every schedule period of a run, materialized as stacked operators.

    The Python training loop rebuilds (and re-traces against) one mixing
    matrix per schedule period. A fused run cannot: the whole multi-round
    program is a single ``lax.scan``, so *all* periods must exist on device
    before the scan starts and the body must select the current period by
    index. ``GossipEngine.program(rounds)`` builds one of these:

    - kind "dense":  ``w`` is (T, N, N) — the body gathers ``w[period_idx[r]]``
      and runs the ordinary per-leaf contraction.
    - kind "sparse": per-period CSRs padded to a uniform nnz and stacked as
      (T, E) ``rows``/``cols``/``values``. Padding entries carry weight 0 and
      point at row N-1 / column 0 (appended after the sorted real entries, so
      segment ids stay sorted) — they add exact zeros.
    - kind "sparse_pallas": per-period blocked-ELL tiles padded to a common
      block count (``sparse.stack_block_ell``) as ``bell_idx`` (T, NB, KB) +
      ``bell_val`` (T, NB*8, KB*8); the body indexes the period axis and
      invokes the 8-row-blocked kernel (``interpret`` resolved at staging).
    - kind "sparse_sharded": per-period ``ShardedCSR`` metadata padded to
      common widths (``sparse.stack_shard_csr``) as ``sh_*`` arrays with a
      leading period axis. The fused trainer wraps its whole round scan in
      ONE ``shard_map`` over ``node_axis`` and calls ``apply_local`` per
      round: the S-1 ``ppermute`` ring steps (or the allgather) execute
      *inside* the fused scan, with ``halo_schedule`` ("auto" resolves once
      from the stacked widths, common to all periods) and ``p_chunk``
      semantics preserved. ``apply`` remains the self-contained (shard_map
      per call) form, used by the loop-parity tests.

    ``period_idx`` maps the global round index to the stacked period slot;
    ``gossip_mask`` carries the ``gossip_every`` cadence. ``cadence`` is the
    trace-time shortcut: "always" skips the ``lax.cond`` entirely
    (gossip_every == 1), "never" makes ``mix_at`` the identity
    (gossip_every == 0), "mask" selects per round inside the scan body.

    ``pad_ratio`` is the staging-overhead diagnostic: stacked operator slots
    per real W entry (1.0 = no padding waste; dense kind reports 1.0). A
    ``@regen`` schedule whose periods vary widely in edge count pads every
    period to the widest one — a large ratio makes that visible instead of
    silently wasting device memory.

    Registered as a pytree so it passes through ``jax.jit`` as data: a fused
    chunk retraces on a new *shape* (different T/E/rounds), never on new
    values (a different seed's schedule reuses the compiled program).
    """

    kind: str  # "dense" | "sparse" | "sparse_pallas" | "sparse_sharded"
    n: int
    num_periods: int
    cadence: str  # "always" | "never" | "mask"
    period_idx: jax.Array  # (rounds,) int32: round -> stacked period slot
    gossip_mask: jax.Array  # (rounds,) bool
    p_chunk: int | None = None  # sparse gather feature-axis chunk (see sparse.mix_sparse)
    w: jax.Array | None = None  # (T, N, N) f32, kind == "dense"
    rows: jax.Array | None = None  # (T, E) int32, kind == "sparse"
    cols: jax.Array | None = None  # (T, E) int32
    values: jax.Array | None = None  # (T, E) f32
    pad_ratio: float = 1.0  # stacked operator slots per real W entry
    bell_idx: jax.Array | None = None  # (T, NB, KB) int32, kind == "sparse_pallas"
    bell_val: jax.Array | None = None  # (T, NB*8, KB*8) f32
    sh_halo: jax.Array | None = None  # (T, S, H) int32, kind == "sparse_sharded"
    sh_rows: jax.Array | None = None  # (T, S, E) int32
    sh_cols: jax.Array | None = None  # (T, S, E) int32
    sh_values: jax.Array | None = None  # (T, S, E) f32
    sh_local_src: jax.Array | None = None  # (T, S, L) int32
    sh_local_dst: jax.Array | None = None  # (T, S, L) int32
    sh_ring_send: tuple[jax.Array, ...] = ()  # per ring step: (T, S, K_d) int32
    sh_ring_recv: tuple[jax.Array, ...] = ()
    interpret: bool | None = None  # kind == "sparse_pallas" (resolved at staging)
    mesh: jax.sharding.Mesh | None = None  # kind == "sparse_sharded"
    node_axis: str | None = None
    shards: int | None = None
    halo_schedule: str | None = None
    # Fault-injection axis (core/faults.py), staged by round rather than by
    # period — masks are drawn per round even within one schedule period.
    faulted: bool = False
    delay_max: int = 0  # straggler ring-buffer depth is delay_max + 1
    f_alive: jax.Array | None = None  # (rounds, N) bool
    f_keep: jax.Array | None = None  # (rounds,N,N) | (rounds,E) | (rounds,S,E)
    f_delay: jax.Array | None = None  # (N,) int32 per-node staleness

    @property
    def rounds(self) -> int:
        return int(self.period_idx.shape[0])

    def _shcsr_at(self, t: jax.Array):
        """Reconstruct round slot ``t``'s ShardedCSR view (traced slices of
        the stacked metadata; static shapes are period-independent)."""
        from repro.core import sparse

        return sparse.ShardedCSR(
            halo=self.sh_halo[t],
            rows=self.sh_rows[t],
            cols=self.sh_cols[t],
            values=self.sh_values[t],
            local_src=self.sh_local_src[t],
            local_dst=self.sh_local_dst[t],
            ring_send=tuple(a[t] for a in self.sh_ring_send),
            ring_recv=tuple(a[t] for a in self.sh_ring_recv),
            shape=(self.n, self.n),
            shards=self.shards,
            rows_per_shard=self.n // self.shards,
        )

    def apply(self, params: PyTree, r: jax.Array, pub: PyTree | None = None) -> PyTree:
        """One unconditional mixing round with round ``r``'s operator
        (``r`` may be a tracer inside a scan body).

        When the program is ``faulted``, round ``r``'s alive / entry-keep
        masks renormalize the operator on the fly and ``pub`` supplies the
        published snapshots stragglers gossip (defaults to ``params``)."""
        t = self.period_idx[r]
        if self.faulted:
            from repro.core import faults as _faults

            keep, alive = self.f_keep[r], self.f_alive[r]
            if pub is None:
                pub = params
            if self.kind == "dense":
                return _faults.mix_faulted_dense(
                    self.w[t], keep, alive, params, pub
                )
            if self.kind == "sparse":
                return _faults.mix_faulted_csr(
                    self.rows[t], self.cols[t], self.values[t],
                    keep, alive, self.n, params, pub,
                )
            if self.kind == "sparse_sharded":
                return mix_sharded_sparse_faulted(
                    self._shcsr_at(t), params, pub, keep, alive,
                    mesh=self.mesh, node_axis=self.node_axis,
                    halo_schedule=self.halo_schedule,
                )
            raise ValueError(f"kind {self.kind!r} does not support faults")
        if self.kind == "dense":
            return mix_dense(self.w[t], params)
        if self.kind == "sparse_pallas":
            from repro.kernels import ops

            idx, val = self.bell_idx[t], self.bell_val[t]

            def bleaf(l: jax.Array) -> jax.Array:
                flat = l.reshape(self.n, -1)
                out = ops.gossip_mix_sparse_blocked(
                    idx, val, flat, interpret=self.interpret
                )
                return out.reshape(l.shape).astype(l.dtype)

            return jax.tree.map(bleaf, params)
        if self.kind == "sparse_sharded":
            return mix_sharded_sparse(
                self._shcsr_at(t), params,
                mesh=self.mesh, node_axis=self.node_axis,
                p_chunk=self.p_chunk, halo_schedule=self.halo_schedule,
            )
        rows, cols, values = self.rows[t], self.cols[t], self.values[t]

        def seg(flat: jax.Array) -> jax.Array:
            gathered = flat[cols] * values[:, None]  # (E, pc)
            return jax.ops.segment_sum(
                gathered, rows, num_segments=self.n, indices_are_sorted=True
            )

        def leaf(l: jax.Array) -> jax.Array:
            flat = l.reshape(self.n, -1).astype(jnp.float32)
            p = flat.shape[1]
            if self.p_chunk is not None and self.p_chunk < p:
                # Same transient bound as sparse.mix_sparse(p_chunk=...):
                # serialized feature-axis chunks keep the gather buffer at
                # O(E * p_chunk) inside the scan body too.
                pad = (-p) % self.p_chunk
                if pad:
                    flat = jnp.pad(flat, ((0, 0), (0, pad)))
                chunks = flat.reshape(self.n, -1, self.p_chunk).transpose(1, 0, 2)
                out = jax.lax.map(seg, chunks)
                out = out.transpose(1, 0, 2).reshape(self.n, -1)[:, :p]
            else:
                out = seg(flat)
            return out.reshape(l.shape).astype(l.dtype)

        return jax.tree.map(leaf, params)

    def mix_at(self, params: PyTree, r: jax.Array, pub: PyTree | None = None) -> PyTree:
        """``apply`` gated by the gossip cadence (identity on skip rounds)."""
        if self.cadence == "never":
            return params
        if self.cadence == "always":
            return self.apply(params, r, pub)
        if pub is None:
            return jax.lax.cond(
                self.gossip_mask[r], lambda p: self.apply(p, r), lambda p: p, params
            )
        return jax.lax.cond(
            self.gossip_mask[r],
            lambda a: self.apply(a[0], r, a[1]), lambda a: a[0], (params, pub),
        )

    def _sharded_static(self) -> tuple[tuple[str, ...], bool, int]:
        """(axes, ring?, blk) for the stacked sharded layout. The ring/
        allgather decision uses the same rule as ``mix_sharded_sparse`` but
        resolves ONCE from the stacked widths, which ``stack_shard_csr``
        keeps common to every period."""
        axes = (
            (self.node_axis,) if isinstance(self.node_axis, str)
            else tuple(self.node_axis)
        )
        blk = self.n // self.shards
        sched = self.halo_schedule
        if sched == "auto":
            ring_width = sum(int(a.shape[2]) for a in self.sh_ring_send)
            sched = "ring" if ring_width < self.n - blk else "allgather"
        return axes, sched == "ring", blk

    def apply_local(self, params: PyTree, r: jax.Array, pub: PyTree | None = None) -> PyTree:
        """Kind "sparse_sharded" only: round ``r``'s mix on this device's
        LOCAL (N/S, ...) slab — must be called inside a ``shard_map`` over
        ``node_axis``. Under ``faulted`` programs, ``pub`` is the local slab
        of published snapshots (defaults to ``params``).

        This is what lets the fused trainer keep the ENTIRE round scan under
        one shard_map (train step genuinely node-sharded, carry never
        resharded between rounds): the ring ppermutes / allgather execute
        directly in the caller's SPMD context. Calling ``apply`` instead —
        a shard_map per mix inside the scan — makes everything *outside* the
        mix replicated on every device and reshards the carry each iteration.
        """
        if self.kind != "sparse_sharded":
            raise ValueError(
                f"apply_local needs kind 'sparse_sharded', got {self.kind!r}"
            )
        t = self.period_idx[r]
        axes, ring, blk = self._sharded_static()
        if self.faulted:
            mix = functools.partial(
                _sharded_mix_leaf_faulted,
                self.sh_halo[t], self.sh_rows[t], self.sh_cols[t],
                self.sh_values[t], self.f_keep[r], self.f_alive[r],
                self.sh_local_src[t], self.sh_local_dst[t],
                tuple(a[t] for a in self.sh_ring_send),
                tuple(a[t] for a in self.sh_ring_recv),
                axes=axes, shards=self.shards, blk=blk,
                h=int(self.sh_halo.shape[2]), ring=ring,
            )
            return _mix_leaves_concatenated2(
                params, params if pub is None else pub, blk, mix
            )
        mix = functools.partial(
            _sharded_mix_leaf,
            self.sh_halo[t], self.sh_rows[t], self.sh_cols[t],
            self.sh_values[t], self.sh_local_src[t], self.sh_local_dst[t],
            tuple(a[t] for a in self.sh_ring_send),
            tuple(a[t] for a in self.sh_ring_recv),
            axes=axes, shards=self.shards, blk=blk,
            h=int(self.sh_halo.shape[2]), ring=ring, p_chunk=self.p_chunk,
        )
        return _mix_leaves_concatenated(params, blk, mix)

    def mix_at_local(self, params: PyTree, r: jax.Array, pub: PyTree | None = None) -> PyTree:
        """``apply_local`` gated by the gossip cadence (cf. ``mix_at``)."""
        if self.cadence == "never":
            return params
        if self.cadence == "always":
            return self.apply_local(params, r, pub)
        if pub is None:
            return jax.lax.cond(
                self.gossip_mask[r],
                lambda p: self.apply_local(p, r), lambda p: p, params,
            )
        return jax.lax.cond(
            self.gossip_mask[r],
            lambda a: self.apply_local(a[0], r, a[1]), lambda a: a[0],
            (params, pub),
        )


# ---------------------------------------------------------------------------
# GossipEngine: one capability-checked front door over every mixing path
# ---------------------------------------------------------------------------

_MATRIX_KINDS = ("decavg", "uniform", "mh")

# Backend -> {requires, cost, wire, fused, faults, notes}.
# Source of truth for GossipEngine.capabilities() and the README matrix —
# the matrix is generated from this table (`python -m repro.lint
# --write-capmatrix`) and lint rule C001 fails CI when they drift.
# ``fused`` means program() can stage every schedule period for this backend,
# so DecentralizedTrainer.run_fused covers it (its _FUSED_BACKENDS mirrors
# this flag, pinned by test and by C001). ``faults`` means the backend
# supports the core/faults.py renormalized-mixing semantics (per-round alive
# / edge-drop masks + straggler snapshots): the Pallas kernels bake W values
# into tiles and the dense-sharded / permute paths precompute their
# collective coefficients, so per-round renormalization is
# dense/sparse/sparse_sharded territory.
_BACKEND_INFO = {
    "dense": {
        "requires": "any backend; W materialized (N,N)",
        "cost": "O(N^2 * P)",
        "wire": "—",
        "fused": True,
        "faults": True,
        "notes": "XLA einsum per leaf; reference path",
    },
    "pallas": {
        "requires": "TPU (interpret elsewhere); W materialized (N,N)",
        "cost": "O(N^2 * P), zero W tiles skipped",
        "wire": "—",
        "fused": False,
        "faults": False,
        "notes": "MXU-tiled blocked matmul",
    },
    "sparse": {
        "requires": "any backend; W stored CSR, O(E) memory",
        "cost": "O(E * P)",
        "wire": "—",
        "fused": True,
        "faults": True,
        "notes": "CSR gather + segment-sum; default at N >= 512",
    },
    "sparse_pallas": {
        "requires": "TPU (interpret elsewhere); W stored blocked ELL",
        "cost": "O(E * P)",
        "wire": "—",
        "fused": True,
        "faults": False,
        "notes": "8-row-blocked ELL kernel (sublane-packed block DMAs); "
                 "scalar row-gather fallback under interpret",
    },
    "sharded": {
        "requires": "mesh with node axis; N divisible by shards",
        "cost": "O(N^2 * P / S) per device",
        "wire": "always O(N * P) allgather",
        "fused": False,
        "faults": False,
        "notes": "shard_map allgather / reduce-scatter",
    },
    "sparse_sharded": {
        "requires": "mesh with node axis (default: all local devices); N "
                    "divisible by shards; W stored per-shard CSR with halo "
                    "columns; halo_schedule allgather|ring|auto",
        "cost": "O(E * P / S) work per device",
        "wire": "allgather O(N * P) / ring O(H * P); auto picks ring when "
                "it undercuts",
        "fused": True,
        "faults": True,
        "notes": "per-shard CSR row ranges + halo buffers; default at "
                 "N >= 512 with a mesh",
    },
    "permute": {
        "requires": "mesh with node axis; N == |axis|; recolors per "
                    "schedule period",
        "cost": "O(degree * P) compute per device",
        "wire": "O(degree * P) per device",
        "fused": False,
        "faults": False,
        "notes": "edge-colored ppermute schedule; recolors per period for "
                 "time-varying schedules",
    },
}


class GossipEngine:
    """Owns topology, mixing matrix, backend dispatch and gossip cadence.

    One engine replaces the per-call-site wiring of graph construction,
    ``decavg_matrix``, backend choice and the ``gossip_every`` loop logic::

        engine = GossipEngine("ba:n=4096,m=2", backend="auto", gossip_every=2)
        params = engine.mix(params, round=i)   # identity rounds are free

    Args:
      topology: a registry spec string (``"ba:n=100,m=2"``, may carry an
        ``@regen=``/``@rewire=`` schedule suffix), a built ``Graph``, or a
        ``TopologySchedule``.
      data_sizes: per-node |D_j| for the Eq. 1 weights (default: uniform).
      matrix: "decavg" (paper Eq. 1), "uniform" (closed-neighborhood mean)
        or "mh" (Metropolis–Hastings, doubly stochastic).
      backend: one of ``GossipEngine.BACKENDS`` or "auto" (sparse at
        N >= sparse_threshold, else dense; with a mesh, sparse_sharded at
        N >= sparse_threshold, else sharded). "sparse_sharded" without a
        mesh builds a 1-D mesh over all local devices.
      gossip_every: mix on rounds with ``round % gossip_every == 0``; other
        rounds are identity and skip all work.
      mesh/node_axis/sharded_schedule: for the shard_map backends.
      halo_schedule: sparse_sharded halo assembly — "allgather" (one
        collective, O(N*P) wire), "ring" (S-1 ppermute steps, O(H*P) wire)
        or "auto" (ring whenever its modeled wire undercuts the allgather's).
      interpret: forwarded to the Pallas backends (default: auto-detect).
      sparse_p_chunk: feature-axis chunk for the sparse gather — an int,
        "auto" (sized from nnz to a ~16 MiB transient), or None (off).
        Bounds the O(nnz * P) gather buffer for very large per-leaf P.
      faults: a fault spec string or ``FaultSchedule`` (core/faults.py) —
        per-round churn / straggler / edge-drop injection, expanded
        deterministically from ``seed``. Only the fault-capable backends
        (``capabilities()[b]["faults"]``) accept it, and it does not
        compose with ``sparse_p_chunk``.
      **topology_defaults: fallback spec params (e.g. ``n=...``) when
        ``topology`` is a spec string.
    """

    BACKENDS = (
        "dense", "pallas", "sparse", "sparse_pallas", "sharded",
        "sparse_sharded", "permute",
    )

    def __init__(
        self,
        topology,
        *,
        data_sizes: np.ndarray | None = None,
        matrix: str = "decavg",
        backend: str = "auto",
        gossip_every: int = 1,
        mesh: jax.sharding.Mesh | None = None,
        node_axis: str = "data",
        sharded_schedule: Literal["allgather", "reduce_scatter"] = "reduce_scatter",
        halo_schedule: Literal["allgather", "ring", "auto"] = "auto",
        interpret: bool | None = None,
        sparse_threshold: int = 512,
        sparse_p_chunk: int | Literal["auto"] | None = None,
        faults: Any = None,
        validate: bool = True,
        seed: int = 0,
        **topology_defaults,
    ):
        from repro.core import topology as topo

        if isinstance(topology, str):
            topology = topo.make_schedule(topology, seed=seed, **topology_defaults)
        elif isinstance(topology, topo.Graph):
            topology = topo.TopologySchedule.static(topology)
        elif not isinstance(topology, topo.TopologySchedule):
            raise TypeError(f"topology must be spec/Graph/TopologySchedule, got {type(topology)}")
        self.schedule = topology
        self.num_nodes = topology.num_nodes
        if matrix not in _MATRIX_KINDS:
            raise ValueError(f"matrix must be one of {_MATRIX_KINDS}, got {matrix!r}")
        self.matrix = matrix
        self.data_sizes = (
            np.ones(self.num_nodes) if data_sizes is None
            else np.asarray(data_sizes, dtype=np.float64)
        )
        self.gossip_every = int(gossip_every)
        self.mesh = mesh
        self.node_axis = node_axis
        self.sharded_schedule = sharded_schedule
        if halo_schedule not in ("allgather", "ring", "auto"):
            raise ValueError(
                f"halo_schedule must be 'allgather', 'ring' or 'auto', "
                f"got {halo_schedule!r}"
            )
        self.halo_schedule = halo_schedule
        self.interpret = interpret
        self.sparse_threshold = int(sparse_threshold)
        # Feature-axis chunking for the sparse gather (None = off; "auto"
        # sizes the chunk from nnz so the transient buffer stays ~16 MiB).
        self.sparse_p_chunk = sparse_p_chunk
        self.validate = validate
        self.seed = int(seed)
        if faults is not None:
            from repro.core import faults as faults_mod

            self.faults = faults_mod.FaultSchedule.parse(faults)
            if sparse_p_chunk is not None:
                raise ValueError(
                    "faults do not compose with sparse_p_chunk: the faulted "
                    "mix renormalizes per entry, so chunked gathers would "
                    "redo it per chunk for no transient win"
                )
        else:
            self.faults = None
        self._fault_trace = None
        self._fault_hist = None  # loop-path straggler ring buffer (mix())
        self.backend = self._resolve_backend(backend)
        if self.backend == "sparse_sharded" and self.mesh is None:
            self.mesh = self._default_node_mesh()
        self.check(self.backend)
        self._period: int | None = None
        self._graph = None
        self._w = None
        self._csr = None
        self._ell = None
        self._bell = None
        self._shcsr = None
        self._colors = None
        # Edge colorings are deterministic per schedule period; cache them so
        # revisiting a period (or mixing repeatedly within one) never recolors.
        self._colors_cache: dict[int, list] = {}
        self.refresh(0)

    # -- capability checking -------------------------------------------------

    @classmethod
    def capabilities(cls) -> dict[str, dict[str, str | bool]]:
        """Backend -> {requires, cost, wire, fused, faults, notes} — the
        README matrix rows (repro.lint C001 keeps the two in lockstep)."""
        return {b: dict(info) for b, info in _BACKEND_INFO.items()}

    def _resolve_backend(self, backend: str) -> str:
        if backend != "auto":
            if backend not in self.BACKENDS:
                raise ValueError(
                    f"unknown backend {backend!r}; one of {self.BACKENDS} or 'auto'"
                )
            return backend
        if self.mesh is not None:
            return (
                "sparse_sharded"
                if self.faults is not None  # dense-sharded can't renormalize
                or self.num_nodes >= self.sparse_threshold
                else "sharded"
            )
        return "sparse" if self.num_nodes >= self.sparse_threshold else "dense"

    def _default_node_mesh(self) -> jax.sharding.Mesh:
        """1-D mesh over every local device — the sparse_sharded default, so
        large-N sparse cohorts run node-sharded without call-site mesh wiring."""
        return jax.sharding.Mesh(np.asarray(jax.devices()), (self.node_axis,))

    def check(self, backend: str, mesh: jax.sharding.Mesh | None = None) -> None:
        """Raise with an actionable message if ``backend`` can't run here.
        ``mesh`` overrides ``self.mesh`` for the check (per-call overrides)."""
        mesh = self.mesh if mesh is None else mesh
        if backend in ("sharded", "sparse_sharded", "permute") and mesh is None:
            raise ValueError(f"backend {backend!r} needs a mesh (mesh=...)")
        if backend == "permute":
            k = mesh.shape[self.node_axis]
            if self.num_nodes != k:
                raise ValueError(
                    f"backend 'permute' needs num_nodes == |{self.node_axis}| "
                    f"({k}), got {self.num_nodes}"
                )
        if backend in ("sharded", "sparse_sharded"):
            shards = mesh.shape[self.node_axis]
            if self.num_nodes % shards:
                raise ValueError(
                    f"backend {backend!r}: num_nodes {self.num_nodes} not divisible "
                    f"by node shards {shards}"
                )
        if self.faults is not None and not _BACKEND_INFO[backend]["faults"]:
            capable = tuple(
                b for b, info in _BACKEND_INFO.items() if info["faults"]
            )
            raise ValueError(
                f"backend {backend!r} does not support faults; "
                f"fault-capable backends: {capable}"
            )

    # -- per-period state ----------------------------------------------------

    def refresh(self, round: int) -> bool:
        """Rebuild graph/W/CSR if ``round`` enters a new schedule period.
        Returns True when the mixing state changed."""
        period = self.schedule.period_of(round)
        if period == self._period:
            return False
        from repro.core import mixing, sparse

        g = self.schedule.graph_at(round)
        if self.matrix == "decavg":
            w = mixing.decavg_matrix(g, self.data_sizes)
        elif self.matrix == "uniform":
            w = mixing.uniform_neighbor_matrix(g)
        else:
            w = mixing.metropolis_hastings_matrix(g)
        if self.validate:
            mixing.validate_mixing(w, g)
        self._period = period
        self._graph = g
        self._w = jnp.asarray(w, jnp.float32)
        # Built from the edge list, not the dense W: the exact same
        # construction GossipEngine.program uses for its stacked periods, so
        # the loop and fused paths mix with bit-identical CSR values.
        self._csr = (
            sparse.csr_from_graph(g, self.data_sizes, matrix=self.matrix)
            if self.backend in ("sparse", "sparse_pallas", "sparse_sharded")
            else None
        )
        # Period-constant derived layouts, built lazily on first use.
        self._ell = None  # scalar ELL view of _csr
        self._bell = None  # blocked ELL view of _csr
        self._shcsr = None  # sharded-CSR view of _csr
        self._colors = (
            self._coloring_for(period, g) if self.backend == "permute" else None
        )
        return True

    def _coloring_for(self, period: int, graph) -> list:
        """Edge coloring for ``period``, cached — recoloring per schedule
        period is what lets ``permute`` track time-varying topologies."""
        colors = self._colors_cache.get(period)
        if colors is None:
            from repro.core import mixing

            colors = mixing.edge_coloring(graph)
            if len(self._colors_cache) >= 64:  # bound memory on long regen runs
                self._colors_cache.pop(next(iter(self._colors_cache)))
            self._colors_cache[period] = colors
        return colors

    @property
    def graph(self):
        return self._graph

    @property
    def w(self) -> jax.Array:
        """Dense (N, N) f32 mixing matrix for the current period."""
        return self._w

    @property
    def csr(self):
        from repro.core import sparse

        if self._csr is None:
            self._csr = sparse.csr_from_dense(np.asarray(self._w))
        return self._csr

    def w_at(self, round: int) -> jax.Array:
        self.refresh(round)
        return self._w

    def graph_at(self, round: int):
        self.refresh(round)
        return self._graph

    def is_gossip_round(self, round: int) -> bool:
        # gossip_every == 0 disables gossip entirely (isolated training),
        # matching the legacy launch/train.py falsy-flag semantics.
        if self.gossip_every < 1:
            return False
        return self.gossip_every == 1 or round % self.gossip_every == 0

    @property
    def fault_trace(self):
        """The engine's deterministic ``FaultTrace`` (requires ``faults=``).
        Lazy and cached: loop mixing, fused staging, and runner analytics
        all read the same per-round masks."""
        if self.faults is None:
            raise ValueError("engine has no fault schedule (faults=...)")
        if self._fault_trace is None:
            from repro.core import faults as faults_mod

            self._fault_trace = faults_mod.FaultTrace(
                self.faults, self.schedule, seed=self.seed
            )
        return self._fault_trace

    def sharded_csr(self, mesh: jax.sharding.Mesh | None = None):
        """Current period's ``ShardedCSR`` for the mesh's shard count
        (cached; rebuilt on a new period or a different shard count)."""
        from repro.core import sparse

        mesh = self.mesh if mesh is None else mesh
        shards = mesh.shape[self.node_axis]
        if self._shcsr is None or self._shcsr.shards != shards:
            self._shcsr = sparse.shard_csr(self.csr, shards)
        return self._shcsr

    def program(self, rounds: int, *, kind: str | None = None) -> MixingProgram:
        """Stage every schedule period of a ``rounds``-long run up front.

        Returns a ``MixingProgram`` — stacked per-period operators plus the
        round -> period map and the gossip cadence — for the fused
        single-``lax.scan`` training path. ``kind`` defaults to the backend's
        own kind for the sparse backends ("sparse", "sparse_pallas",
        "sparse_sharded") and "dense" otherwise.

        With ``faults=`` set, the program additionally stages the whole
        run's per-round alive and entry-keep masks (``f_alive``/``f_keep``,
        one more stacked axis) plus the static per-node staleness delays —
        a faulty multi-host run stays one compiled SPMD ``lax.scan``.

        The sparse kinds build each period's CSR straight from the
        schedule's graphs (``sparse.csr_from_graph``) — the dense (N, N)
        matrix is never materialized, so staging a T-period ``@rewire`` run
        is O(T * E) host memory, not O(T * N^2). The loop path's ``refresh``
        builds its CSR the same way, which is what keeps fused and loop runs
        bit-identical for the sparse backends. For the dense kind the
        engine's period state is walked and then restored to round 0, so an
        interleaved Python-loop run sees the same state it would have
        without this call.
        """
        prog = self._program_operators(rounds, kind=kind)
        if self.faults is None:
            return prog
        return self._attach_faults(prog, int(rounds))

    def _attach_faults(self, prog: MixingProgram, rounds: int) -> MixingProgram:
        """Stage the fault axis onto a built program: per-round alive masks
        and entry-keep masks in the program's own operator layout."""
        if prog.kind not in ("dense", "sparse", "sparse_sharded"):
            raise ValueError(
                f"program kind {prog.kind!r} does not support faults"
            )
        trace = self.fault_trace
        trace.ensure(rounds)
        f_alive = trace.alive_matrix(rounds)
        pid = np.asarray(prog.period_idx)
        if prog.kind == "dense":
            keep = np.stack([trace.dense_keep(r) for r in range(rounds)])
        elif prog.kind == "sparse":
            rows = np.asarray(prog.rows)
            cols = np.asarray(prog.cols)
            values = np.asarray(prog.values)
            keep = np.stack([
                trace.entry_keep(r, rows[pid[r]], cols[pid[r]], values[pid[r]])
                for r in range(rounds)
            ])
        else:  # sparse_sharded: per-shard layout with halo-local columns
            halo = np.asarray(prog.sh_halo)
            rows = np.asarray(prog.sh_rows)
            cols = np.asarray(prog.sh_cols)
            values = np.asarray(prog.sh_values)
            blk = prog.n // prog.shards
            offs = np.arange(prog.shards)[:, None] * blk
            keep = np.stack([
                trace.entry_keep(
                    r,
                    rows[pid[r]] + offs,  # local row -> global id
                    np.take_along_axis(halo[pid[r]], cols[pid[r]], axis=1),
                    values[pid[r]],
                )
                for r in range(rounds)
            ])
        return dataclasses.replace(
            prog,
            faulted=True,
            delay_max=trace.delay_max,
            f_alive=jnp.asarray(f_alive),
            f_keep=jnp.asarray(keep),
            f_delay=jnp.asarray(trace.delay),
        )

    def _program_operators(self, rounds: int, *, kind: str | None = None) -> MixingProgram:
        """The fault-free operator staging behind ``program`` (docs there)."""
        from repro.core import sparse

        rounds = int(rounds)
        if rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {rounds}")
        sparse_kinds = ("sparse", "sparse_pallas", "sparse_sharded")
        if kind is None:
            kind = self.backend if self.backend in sparse_kinds else "dense"
        if kind not in ("dense",) + sparse_kinds:
            raise ValueError(
                f"program kind must be one of {('dense',) + sparse_kinds}, "
                f"got {kind!r}"
            )
        first_round: dict[int, int] = {}
        for r in range(rounds):
            first_round.setdefault(self.schedule.period_of(r), r)
        period_list = sorted(first_round)
        slot = {p: i for i, p in enumerate(period_list)}
        period_idx = np.array(
            [slot[self.schedule.period_of(r)] for r in range(rounds)], np.int32
        )
        gossip_mask = np.array([self.is_gossip_round(r) for r in range(rounds)], bool)
        cadence = (
            "never" if self.gossip_every < 1
            else "always" if self.gossip_every == 1
            else "mask"
        )
        common = dict(
            n=self.num_nodes,
            num_periods=len(period_list),
            cadence=cadence,
            period_idx=jnp.asarray(period_idx),
            gossip_mask=jnp.asarray(gossip_mask),
        )
        if kind == "dense":
            ws = [np.asarray(self.w_at(first_round[p])) for p in period_list]
            self.refresh(0)  # leave the engine where a fresh run expects it
            return MixingProgram(kind="dense", w=jnp.asarray(np.stack(ws)), **common)
        # Sparse kinds: per-period CSR straight from the graphs — no dense
        # (N, N) staging, no engine period churn (graph_at reads the
        # schedule's own period cache).
        csrs = [
            sparse.csr_from_graph(
                self.schedule.graph_at(first_round[p]), self.data_sizes,
                matrix=self.matrix,
            )
            for p in period_list
        ]
        if self.validate:
            for c in csrs:  # O(E) row-stochasticity check, no dense rebuild
                rs = np.bincount(
                    np.asarray(c.rows),
                    weights=np.asarray(c.values, np.float64),
                    minlength=self.num_nodes,
                )
                if not np.allclose(rs, 1.0, atol=1e-5):
                    raise ValueError("staged mixing rows must sum to 1")
        real_nnz = sum(c.nnz for c in csrs)
        e_max = max(c.nnz for c in csrs)
        p_chunk = self.sparse_p_chunk
        n = self.num_nodes
        if kind == "sparse_pallas":
            from repro.kernels import ops

            interp = (not ops.on_tpu()) if self.interpret is None else bool(self.interpret)
            bell_idx, bell_val = sparse.stack_block_ell(csrs)
            return MixingProgram(
                kind="sparse_pallas",
                bell_idx=jnp.asarray(bell_idx),
                bell_val=jnp.asarray(bell_val),
                interpret=interp,
                pad_ratio=bell_val.size / real_nnz,
                **common,
            )
        if kind == "sparse_sharded":
            mesh = self.mesh if self.mesh is not None else self._default_node_mesh()
            self.check("sparse_sharded", mesh)
            shards = mesh.shape[self.node_axis]
            st = sparse.stack_shard_csr([sparse.shard_csr(c, shards) for c in csrs])
            if p_chunk == "auto":
                # Per-device transient: size from the padded per-shard width.
                p_chunk = sparse.auto_p_chunk(int(st["values"].shape[2]))
            return MixingProgram(
                kind="sparse_sharded",
                sh_halo=jnp.asarray(st["halo"]),
                sh_rows=jnp.asarray(st["rows"]),
                sh_cols=jnp.asarray(st["cols"]),
                sh_values=jnp.asarray(st["values"]),
                sh_local_src=jnp.asarray(st["local_src"]),
                sh_local_dst=jnp.asarray(st["local_dst"]),
                sh_ring_send=tuple(jnp.asarray(a) for a in st["ring_send"]),
                sh_ring_recv=tuple(jnp.asarray(a) for a in st["ring_recv"]),
                mesh=mesh,
                node_axis=self.node_axis,
                shards=shards,
                halo_schedule=self.halo_schedule,
                p_chunk=None if p_chunk is None else int(p_chunk),
                pad_ratio=st["values"].size / real_nnz,
                **common,
            )
        if p_chunk == "auto":
            # Size from the padded entry count: the in-scan gather transient
            # is O(e_max * chunk) per leaf, same bound as the loop path's.
            p_chunk = sparse.auto_p_chunk(e_max)
        rows = np.full((len(csrs), e_max), n - 1, np.int32)
        cols = np.zeros((len(csrs), e_max), np.int32)
        values = np.zeros((len(csrs), e_max), np.float32)
        for t, c in enumerate(csrs):
            # Real entries first (rows sorted ascending), zero-weight padding
            # at row n-1 after them — segment ids stay sorted, sums are exact.
            rows[t, : c.nnz] = np.asarray(c.rows)
            cols[t, : c.nnz] = np.asarray(c.indices)
            values[t, : c.nnz] = np.asarray(c.values)
        return MixingProgram(
            kind="sparse",
            rows=jnp.asarray(rows),
            cols=jnp.asarray(cols),
            values=jnp.asarray(values),
            p_chunk=None if p_chunk is None else int(p_chunk),
            pad_ratio=(len(csrs) * e_max) / real_nnz,
            **common,
        )

    # -- mixing --------------------------------------------------------------

    def mix(
        self,
        params: PyTree,
        *,
        round: int | None = None,
        backend: str | None = None,
        spec: str | None = None,
    ) -> PyTree:
        """One communication round.

        With ``round`` given, the engine applies the cadence (identity
        rounds return ``params`` untouched — no identity matmul) and
        refreshes schedule state for that round. Without ``round``, the
        current-period matrix is applied unconditionally (callers that
        manage ``refresh`` themselves, e.g. the trainer's jitted closure,
        must not have their period reset here). ``backend`` (alias
        ``spec``) overrides the engine's backend for this call.

        With ``faults=`` set the engine runs the faulted round instead:
        renormalized mixing over surviving neighbors, straggler snapshots
        from an internal ring buffer (which assumes one ``mix`` call per
        round, in round order — the lm loop's contract), dead/empty rows
        passing through bit-unchanged. Freezing dead nodes' *training* is
        the trainer's job; the engine only governs gossip."""
        if self.faults is not None:
            if round is None:
                raise ValueError("faulted mixing needs round= (per-round masks)")
            return self._mix_faulted(params, round, backend or spec or self.backend)
        if round is not None:
            if not self.is_gossip_round(round):
                return params
            self.refresh(round)
        backend = backend or spec or self.backend
        mesh = self.mesh
        if backend != self.backend:
            if backend == "sparse_sharded" and mesh is None:
                # Local to this call: an override must not mutate the engine's
                # capability surface for later calls with other backends.
                mesh = self._default_node_mesh()
            self.check(backend, mesh)
        if backend == "dense":
            return mix_dense(self._w, params)
        if backend == "pallas":
            return mix_pallas(self._w, params, interpret=self.interpret)
        if backend == "sparse":
            from repro.core import sparse

            p_chunk = self.sparse_p_chunk
            if p_chunk == "auto":
                p_chunk = sparse.auto_p_chunk(self.csr.nnz)
            return sparse.mix_sparse(self.csr, params, p_chunk=p_chunk)
        if backend == "sparse_pallas":
            from repro.core import sparse
            from repro.kernels import ops

            interp = (not ops.on_tpu()) if self.interpret is None else self.interpret
            if interp:  # scalar row-gather fallback kernel under interpret
                if self._ell is None:  # period-constant; avoids per-call rebuild
                    self._ell = sparse.ell_from_csr(self.csr)
                return sparse.mix_sparse_pallas(
                    self.csr, params, ell=self._ell, interpret=True, blocked=False
                )
            if self._bell is None:
                self._bell = sparse.block_ell_from_csr(self.csr)
            return sparse.mix_sparse_pallas(
                self.csr, params, bell=self._bell, interpret=False, blocked=True
            )
        if backend == "sharded":
            return mix_sharded(
                self._w, params, mesh=mesh, node_axis=self.node_axis,
                schedule=self.sharded_schedule,
            )
        if backend == "sparse_sharded":
            from repro.core import sparse

            self.sharded_csr(mesh)
            p_chunk = self.sparse_p_chunk
            if p_chunk == "auto":
                # Size from the per-shard entry count: the gather transient
                # is O(nnz_s * chunk) per device, not O(nnz * chunk).
                p_chunk = sparse.auto_p_chunk(int(self._shcsr.values.shape[1]))
            return mix_sharded_sparse(
                self._shcsr, params, mesh=mesh, node_axis=self.node_axis,
                p_chunk=p_chunk, halo_schedule=self.halo_schedule,
            )
        if backend == "permute":
            if self._colors is None:
                self._colors = self._coloring_for(self._period, self._graph)
            return mix_permute(
                self._w, params, self._colors, mesh=mesh,
                node_axis=self.node_axis,
            )
        raise ValueError(f"unknown backend {backend!r}")

    def _mix_faulted(self, params: PyTree, round: int, backend: str) -> PyTree:
        """One faulted loop-path round (see ``mix``)."""
        from repro.core import faults as faults_mod

        self.check(backend, self.mesh)
        self.refresh(round)
        trace = self.fault_trace
        # Push into the straggler ring buffer BEFORE the cadence gate: a
        # straggler's history advances whether or not this round gossips.
        pub = None
        if trace.delay_max > 0:
            if self._fault_hist is None:
                self._fault_hist = faults_mod.init_history(
                    params, trace.delay_max + 1
                )
            pub, self._fault_hist = faults_mod.push_and_publish(
                params, self._fault_hist, jnp.int32(round),
                jnp.asarray(trace.delay),
            )
        if not self.is_gossip_round(round):
            return params
        alive = jnp.asarray(trace.alive(round))
        if backend == "dense":
            keep = jnp.asarray(trace.dense_keep(round))
            return faults_mod.mix_faulted_dense(
                self._w, keep, alive, params, pub
            )
        if backend == "sparse":
            csr = self.csr
            keep = jnp.asarray(trace.entry_keep(
                round, np.asarray(csr.rows), np.asarray(csr.indices),
                np.asarray(csr.values),
            ))
            return faults_mod.mix_faulted_csr(
                csr.rows, csr.indices, csr.values, keep, alive,
                self.num_nodes, params, pub,
            )
        if backend == "sparse_sharded":
            shcsr = self.sharded_csr()
            blk = shcsr.rows_per_shard
            rows_g = np.asarray(shcsr.rows) + np.arange(shcsr.shards)[:, None] * blk
            cols_g = np.take_along_axis(
                np.asarray(shcsr.halo), np.asarray(shcsr.cols), axis=1
            )
            keep = jnp.asarray(trace.entry_keep(
                round, rows_g, cols_g, np.asarray(shcsr.values)
            ))
            return mix_sharded_sparse_faulted(
                shcsr, params, params if pub is None else pub, keep, alive,
                mesh=self.mesh, node_axis=self.node_axis,
                halo_schedule=self.halo_schedule,
            )
        raise ValueError(f"backend {backend!r} does not support faults")

    def __repr__(self) -> str:
        return (
            f"GossipEngine(n={self.num_nodes}, backend={self.backend}, "
            f"matrix={self.matrix}, gossip_every={self.gossip_every}, "
            f"topology={self.schedule!r})"
        )


def gossip_error(params: PyTree) -> jax.Array:
    """Consensus distance: mean over leaves of ||w_i - mean_i w_i||^2 / ||mean||^2.

    The quantity the spectral gap contracts per round; benchmarks report it to
    connect topology properties to knowledge-spread speed.
    """
    def leaf_err(leaf: jax.Array) -> jax.Array:
        f = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
        mean = f.mean(axis=0, keepdims=True)
        num = jnp.sum((f - mean) ** 2)
        den = jnp.sum(mean**2) * f.shape[0] + 1e-12
        return num / den

    errs = [leaf_err(l) for l in jax.tree.leaves(params)]
    return jnp.mean(jnp.stack(errs))
