"""DecAvg mixing matrices (paper Eq. 1).

Eq. 1 averages, at node i, the models of the closed neighborhood N(i)
(neighbors + self) with weights proportional to trust * dataset size:

    w_i(t) <- sum_{j in N(i)} omega_ij * alpha_ij * w_j(t-1) / Z_i ,
    alpha_ij = |D_j| / sum_{k in N(i)} |D_k| .

Fidelity note: Eq. 1 as printed normalizes by Z_i = sum_j omega_ij, which for
unweighted graphs (omega=1) would shrink every row by 1/|N(i)| — a clearly
unintended contraction (models would collapse to zero). We use the standard
row-stochastic normalization Z_i = sum_j omega_ij * alpha_ij, which for
omega = 1 reduces to exactly the FedAvg-style dataset-size-weighted average
w_i <- sum_j alpha_ij w_j. This matches the paper's verbal description
("averages it with its local model ... weighted average") and its results.

The mixing matrix W (rows = receiving node i, cols = source node j) is the
single object the whole system consumes: one DecAvg communication round is
``P <- W @ P`` on node-stacked parameters.
"""

from __future__ import annotations

import numpy as np

from repro.core.topology import Graph

__all__ = [
    "decavg_matrix",
    "uniform_neighbor_matrix",
    "metropolis_hastings_matrix",
    "validate_mixing",
    "spectral_gap",
]


def _closed_neighborhood(adj: np.ndarray) -> np.ndarray:
    return adj.astype(np.float64) + np.eye(adj.shape[0])


def decavg_matrix(
    g: Graph,
    data_sizes: np.ndarray,
    *,
    trust: np.ndarray | None = None,
    self_trust: float = 1.0,
) -> np.ndarray:
    """Paper Eq. 1 mixing matrix, row-stochastic.

    Args:
      g: the collaboration graph.
      data_sizes: (N,) per-node |D_j| (zero-size nodes contribute nothing).
      trust: optional (N, N) symmetric non-negative edge weights omega_ij;
        defaults to the unweighted case omega_ij = 1 on edges.
      self_trust: omega_ii, the paper's "self-trust pseudo-parameter".
    """
    n = g.num_nodes
    sizes = np.asarray(data_sizes, dtype=np.float64)
    if sizes.shape != (n,):
        raise ValueError(f"data_sizes must be ({n},), got {sizes.shape}")
    if trust is None:
        omega = g.adj.astype(np.float64)
    else:
        omega = np.asarray(trust, dtype=np.float64) * g.adj  # restrict to edges
        if not np.allclose(omega, omega.T):
            raise ValueError("trust matrix must be symmetric")
    np.fill_diagonal(omega, self_trust)
    w = omega * sizes[None, :]  # omega_ij * |D_j| over the closed neighborhood
    row = w.sum(axis=1, keepdims=True)
    if np.any(row == 0):
        # Isolated node with zero data: keep its own model unchanged.
        bad = row[:, 0] == 0
        w[bad] = 0.0
        w[bad, np.flatnonzero(bad)] = 1.0
        row = w.sum(axis=1, keepdims=True)
    return w / row


def uniform_neighbor_matrix(g: Graph) -> np.ndarray:
    """Uniform average over the closed neighborhood (alpha_ij = 1/|N(i)|)."""
    w = _closed_neighborhood(g.adj)
    return w / w.sum(axis=1, keepdims=True)


def metropolis_hastings_matrix(g: Graph) -> np.ndarray:
    """Symmetric, doubly-stochastic MH weights (beyond-paper baseline).

    W_ij = 1 / (1 + max(d_i, d_j)) on edges, W_ii = 1 - sum_j W_ij.
    Doubly-stochastic mixing preserves the global average — the classical
    gossip-averaging choice, giving the fastest consensus contraction for a
    given topology.
    """
    adj = g.adj
    d = adj.sum(axis=1).astype(np.float64)
    w = np.where(adj, 1.0 / (1.0 + np.maximum(d[:, None], d[None, :])), 0.0)
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def validate_mixing(w: np.ndarray, g: Graph | None = None, atol: float = 1e-9) -> None:
    """Assert W is a valid gossip matrix: row-stochastic, non-negative, and
    supported only on the closed neighborhood of ``g`` (if given)."""
    if np.any(w < -atol):
        raise ValueError("mixing matrix has negative entries")
    if not np.allclose(w.sum(axis=1), 1.0, atol=atol):
        raise ValueError("mixing matrix rows must sum to 1")
    if g is not None:
        support = _closed_neighborhood(g.adj) > 0
        if np.any((np.abs(w) > atol) & ~support):
            raise ValueError("mixing matrix has weight outside graph edges")


def edge_coloring(g: Graph) -> list[list[tuple[int, int]]]:
    """Decompose the graph's edges into matchings (greedy edge coloring,
    <= 2*Delta - 1 colors; typically Delta or Delta + 1).

    Each color class is a set of vertex-disjoint edges; emitted as DIRECTED
    pairs (both (i, j) and (j, i) — sources and destinations within a color
    are distinct, so one ``jax.lax.ppermute`` realizes the whole class).
    This is the topology-as-collective-schedule optimization (EXPERIMENTS
    §Perf H2): DecAvg only needs *neighbor* models, so gossip wire volume is
    O(degree) shards instead of the dense all-gather's O(N).
    """
    n = g.num_nodes
    used: list[set[int]] = [set() for _ in range(n)]
    color_of: dict[tuple[int, int], int] = {}
    ncolors = 0
    ii, jj = np.nonzero(np.triu(g.adj, k=1))
    for u, v in zip(ii.tolist(), jj.tolist()):
        c = 0
        while c in used[u] or c in used[v]:
            c += 1
        color_of[(u, v)] = c
        used[u].add(c)
        used[v].add(c)
        ncolors = max(ncolors, c + 1)
    colors: list[list[tuple[int, int]]] = [[] for _ in range(ncolors)]
    for (u, v), c in color_of.items():
        colors[c].append((u, v))
        colors[c].append((v, u))
    return colors


def spectral_gap(w: np.ndarray) -> float:
    """1 - |lambda_2(W)|: the consensus contraction rate per gossip round.

    Used by the analysis benchmarks to relate topology (connectivity,
    modularity) to knowledge-spread speed: small gap <=> slow spread.
    """
    eig = np.linalg.eigvals(w)
    mags = np.sort(np.abs(eig))[::-1]
    return float(1.0 - (mags[1] if len(mags) > 1 else 0.0))
