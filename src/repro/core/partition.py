"""Non-IID data partitioners from the paper's §5.1.

Given a labeled dataset and a graph, assign per-node index sets:

- ``iid``: uniform random split of everything.
- ``hub_focused`` / ``edge_focused``: all nodes get an equal share of the G1
  classes (0-4); the G2 classes (5-9) go only to the 10% highest- (lowest-)
  degree nodes, with the paper's tie-breaking rule: walk degrees from the
  extreme inward, and if taking every node at the boundary degree would
  overshoot 10%, pick a random subset at that degree to fill exactly 10%.
- ``community``: for SBM — community ``c`` receives classes {2c, 2c+1}
  exclusively (classes 8, 9 discarded for 4 communities).
- ``dirichlet``: standard Dir(beta) label-skew partitioner (not in the paper;
  used by the extended benchmarks).

Partitioners return a list of per-node integer index arrays into the dataset.
Each node receives an equal share of every class it is assigned (paper: "on
the assigned classes, each node gets the same amount of images").
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.topology import Graph

__all__ = [
    "select_extreme_degree_nodes",
    "iid",
    "hub_focused",
    "edge_focused",
    "community",
    "dirichlet",
    "partition_summary",
]


def _split_class_evenly(
    idx: np.ndarray, recipients: Sequence[int], rng: np.random.Generator
) -> dict[int, np.ndarray]:
    """Shuffle ``idx`` and deal equal-size shares to ``recipients``
    (drop the remainder so shares are exactly equal, as in the paper)."""
    idx = idx.copy()
    rng.shuffle(idx)
    k = len(recipients)
    share = len(idx) // k
    return {node: idx[i * share : (i + 1) * share] for i, node in enumerate(recipients)}


def select_extreme_degree_nodes(
    g: Graph, frac: float, *, highest: bool, seed: int
) -> np.ndarray:
    """Pick ``frac`` of nodes by extreme degree with the paper's tie-break.

    Starting from the highest (lowest) degree, take whole degree classes while
    they fit; at the boundary degree, sample uniformly without replacement to
    fill the quota exactly.
    """
    rng = np.random.default_rng(seed)
    n = g.num_nodes
    quota = max(1, int(round(frac * n)))
    deg = g.degrees()
    order = np.argsort(-deg if highest else deg, kind="stable")
    chosen: list[int] = []
    i = 0
    while len(chosen) < quota:
        d = deg[order[i]]
        tier = [int(v) for v in order[i:] if deg[v] == d]
        if len(chosen) + len(tier) <= quota:
            chosen.extend(tier)
        else:
            need = quota - len(chosen)
            chosen.extend(rng.choice(tier, size=need, replace=False).tolist())
        i += len(tier)
    return np.asarray(sorted(chosen), dtype=np.int64)


def iid(labels: np.ndarray, num_nodes: int, *, seed: int) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(labels))
    return [np.sort(s) for s in np.array_split(idx, num_nodes)]


def _focused(
    labels: np.ndarray,
    g: Graph,
    *,
    highest: bool,
    seed: int,
    g1_classes: Sequence[int] = (0, 1, 2, 3, 4),
    g2_classes: Sequence[int] = (5, 6, 7, 8, 9),
    frac: float = 0.10,
) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    n = g.num_nodes
    focus = select_extreme_degree_nodes(g, frac, highest=highest, seed=seed + 1)
    per_node: list[list[np.ndarray]] = [[] for _ in range(n)]
    all_nodes = list(range(n))
    focus_nodes = [int(v) for v in focus]
    for c in g1_classes:
        for node, share in _split_class_evenly(np.flatnonzero(labels == c), all_nodes, rng).items():
            per_node[node].append(share)
    for c in g2_classes:
        for node, share in _split_class_evenly(np.flatnonzero(labels == c), focus_nodes, rng).items():
            per_node[node].append(share)
    return [np.sort(np.concatenate(parts)) if parts else np.empty(0, np.int64) for parts in per_node]


def hub_focused(labels: np.ndarray, g: Graph, *, seed: int, **kw) -> list[np.ndarray]:
    """G2 classes concentrated on the 10% highest-degree nodes."""
    return _focused(labels, g, highest=True, seed=seed, **kw)


def edge_focused(labels: np.ndarray, g: Graph, *, seed: int, **kw) -> list[np.ndarray]:
    """G2 classes concentrated on the 10% lowest-degree nodes (leaves)."""
    return _focused(labels, g, highest=False, seed=seed, **kw)


def community(
    labels: np.ndarray, g: Graph, *, seed: int, classes_per_community: int = 2
) -> list[np.ndarray]:
    """SBM partition: community c exclusively holds classes
    [c*k, c*k + k); leftover classes are discarded (paper: 8 and 9)."""
    if g.blocks is None:
        raise ValueError("community partition requires an SBM graph with block labels")
    rng = np.random.default_rng(seed)
    n = g.num_nodes
    per_node: list[list[np.ndarray]] = [[] for _ in range(n)]
    num_comm = int(g.blocks.max()) + 1
    for comm in range(num_comm):
        members = [int(v) for v in np.flatnonzero(g.blocks == comm)]
        for c in range(comm * classes_per_community, (comm + 1) * classes_per_community):
            for node, share in _split_class_evenly(np.flatnonzero(labels == c), members, rng).items():
                per_node[node].append(share)
    return [np.sort(np.concatenate(parts)) if parts else np.empty(0, np.int64) for parts in per_node]


def dirichlet(
    labels: np.ndarray, num_nodes: int, *, beta: float, seed: int
) -> list[np.ndarray]:
    """Label-skew Dir(beta) partitioner (beyond-paper; common FL baseline)."""
    rng = np.random.default_rng(seed)
    num_classes = int(labels.max()) + 1
    buckets: list[list[int]] = [[] for _ in range(num_nodes)]
    for c in range(num_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet([beta] * num_nodes)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for node, share in enumerate(np.split(idx, cuts)):
            buckets[node].extend(share.tolist())
    return [np.sort(np.asarray(b, dtype=np.int64)) for b in buckets]


def partition_summary(labels: np.ndarray, parts: list[np.ndarray]) -> np.ndarray:
    """(num_nodes, num_classes) label-count matrix, for tests and reports."""
    num_classes = int(labels.max()) + 1
    out = np.zeros((len(parts), num_classes), dtype=np.int64)
    for i, p in enumerate(parts):
        if len(p):
            cls, cnt = np.unique(labels[p], return_counts=True)
            out[i, cls] = cnt
    return out
