"""Post-SPMD HLO walker: attribute collective ops to their enclosing loops
and multiply by trip counts.

Why: XLA's ``compiled.cost_analysis()`` (and naive text scans) count each op
ONCE, but our step functions nest everything in loops — the layer scan
(num_groups), the grad-accumulation scan (microbatches), attention q/kv
chunk loops, MoE group maps. A collective inside the 88-layer scan moves
88x the bytes a single-occurrence count reports (observed: useful-FLOPs
"ratios" of 454 before correction).

Approach: parse computations from the HLO text, build the call graph
(while/body+condition, fusion/calls, call/to_apply, conditional branches),
read each while's trip count from the loop-condition's comparison constant,
then DFS from ENTRY propagating a multiplier. Collective wire bytes are
summed as bytes x multiplier.

Trip-count parsing is heuristic (largest integer compared in the condition);
unknown conditions default to 1 and are reported in ``unknown_loops``.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# Header params may contain nested parens (tuple types): match lazily up to
# ") -> " and require a trailing "{".
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CALLED = re.compile(r"(condition|body|calls|to_apply)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_COLLECTIVE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[\w\[\],{}]+))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_WHILE = re.compile(r"\bwhile\(")
_COMPARE_CONST = re.compile(r"compare\([^)]*\)")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def _array_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    lines: list[str]
    is_entry: bool = False


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1), [], is_entry=line.startswith("ENTRY"))
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(line)
    return comps


def _trip_count(cond: Computation) -> int | None:
    """Largest integer constant in the loop condition — counter-style loops
    compare the induction variable against the trip count."""
    best: int | None = None
    for line in cond.lines:
        for m in _CONST_INT.finditer(line):
            v = int(m.group(1))
            if best is None or v > best:
                best = v
    return best


@dataclasses.dataclass
class CollectiveReport:
    wire_by_kind: dict[str, float]
    op_counts: dict[str, int]
    unknown_loops: int = 0

    @property
    def total(self) -> float:
        return float(sum(self.wire_by_kind.values()))


def _line_wire_bytes(line: str) -> tuple[str, float] | None:
    m = _COLLECTIVE.search(line)
    if not m:
        return None
    result_type, kind, start = m.groups()
    if "-done" in line.split("=")[1][:40]:
        return None
    call = line[m.end() - 1 :]
    depth = 0
    end = 0
    for i, ch in enumerate(call):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    in_b = _array_bytes(call[1:end])
    out_b = _array_bytes(result_type)
    if start:  # async start: result tuple contains (in, out, ...) — take diff of halves
        out_b = max(out_b - in_b, in_b)
    # HLO call sites don't always annotate operand types; fall back to the
    # result size (AG: counts the full gathered buffer — an upper bound).
    if kind == "all-gather":
        wire = max(out_b - in_b, 0) if in_b else out_b
    elif kind == "reduce-scatter":
        wire = max(in_b - out_b, 0) if in_b else out_b
    elif kind == "all-reduce":
        wire = 2 * (in_b or out_b)
    elif kind == "all-to-all":
        wire = in_b or out_b
    else:  # collective-permute
        wire = in_b or out_b
    return kind, float(wire)


def collective_wire_bytes_looped(hlo: str) -> CollectiveReport:
    comps = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    report = CollectiveReport(wire_by_kind={}, op_counts={})
    if entry is None:
        return report

    # multiplier per computation, propagated from ENTRY
    mult: dict[str, float] = {}

    def visit(comp: Computation, m: float) -> None:
        if mult.get(comp.name, 0) >= m:
            return
        mult[comp.name] = m
        for line in comp.lines:
            called = []
            for cm in _CALLED.finditer(line):
                role, name = cm.groups()
                if name in comps:
                    called.append((role, name))
            for bm in _BRANCHES.finditer(line):
                for name in re.split(r"[, ]+", bm.group(1)):
                    name = name.strip().lstrip("%")
                    if name in comps:
                        called.append(("branch", name))
            is_while = bool(_WHILE.search(line))
            trip = None
            if is_while:
                for role, name in called:
                    if role.startswith("condition"):
                        trip = _trip_count(comps[name])
                if trip is None:
                    report.unknown_loops += 1
                    trip = 1
            for role, name in called:
                child_m = m * trip if (is_while and role.startswith("body")) else m
                visit(comps[name], child_m)

    visit(entry, 1.0)

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        for line in comp.lines:
            res = _line_wire_bytes(line)
            if res is None:
                continue
            kind, wire = res
            report.wire_by_kind[kind] = report.wire_by_kind.get(kind, 0.0) + wire * m
            report.op_counts[kind] = report.op_counts.get(kind, 0) + 1
    return report
