"""Batched serving driver: prefill a batch of prompts, decode with KV cache.

CPU-runnable on reduced configs; the full-scale serve_step for the
production mesh is lowered by launch/dryrun.py (decode_32k / long_500k).

Run:  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --gen 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import base as cfgbase
from repro.models import transformer as TF
from repro.serve import decode as SD


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--long-context", action="store_true",
                    help="sliding-window ring cache instead of full cache")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = cfgbase.get(args.arch).reduced()
    params = TF.init_params(jax.random.PRNGKey(args.seed), cfg)
    prompt = jax.random.randint(
        jax.random.PRNGKey(args.seed + 1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    total = args.prompt_len + args.gen
    cache_len = SD.cache_len_for(cfg, total, long_context=args.long_context)
    cache = TF.init_cache(cfg, args.batch, cache_len)

    kw = {}
    if cfg.enc_dec:
        frames = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, 32, cfg.d_model), cfg.dtype()
        )
        kw["memory"] = TF.encode(params, cfg, frames)

    print(
        f"arch={cfg.arch_id} batch={args.batch} cache_len={cache_len} "
        f"({'sliding-window' if args.long_context else 'full'})"
    )
    t0 = time.perf_counter()
    toks = SD.generate(
        params, cfg, prompt, cache,
        steps=args.gen, key=jax.random.PRNGKey(args.seed + 2),
        temperature=args.temperature, **kw,
    )
    dt = time.perf_counter() - t0
    print(f"generated {toks.shape} in {dt:.1f}s = {args.batch * args.gen / dt:.1f} tok/s")
    print("first sequence:", toks[0, :16].tolist(), "...")


if __name__ == "__main__":
    main()
