"""The four assigned input shapes and per-(arch × shape) input specs.

``input_specs(cfg, shape_name, ...)`` returns ShapeDtypeStruct stand-ins for
every input of the step function that shape lowers — weak-type-correct,
shardable, zero allocation.

Shape semantics (per assignment):
  train_4k     seq 4096,   global_batch 256  -> decentralized train_step
  prefill_32k  seq 32768,  global_batch 32   -> prefill (forward, no grad)
  decode_32k   seq 32768,  global_batch 128  -> serve_step (1 token, 32k cache)
  long_500k    seq 524288, global_batch 1    -> serve_step, sub-quadratic only
                                               (SSM/hybrid state, or
                                               sliding-window ring cache)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as TF

PyTree = Any


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# Whisper's decoder context is 448; its encoder consumes the frame axis.
WHISPER_DEC_LEN = 448
# Whisper encoder frames for decode shapes (30 s window -> 1500 frames).
WHISPER_ENC_FRAMES = 1500


def tokens_spec(batch: int, seq: int):
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def train_inputs(
    cfg: ArchConfig, shape: InputShape, num_nodes: int, *, microbatches: int = 1
) -> dict:
    """Microbatched node-stacked (M, N, B/M, S) token/label specs (+ stub
    frontends). The microbatch axis is a leading input axis so the per-node
    batch dim keeps its "data" sharding through the grad-accumulation scan."""
    assert shape.kind == "train"
    if shape.global_batch % (num_nodes * microbatches):
        raise ValueError(
            f"global_batch {shape.global_batch} not divisible by "
            f"nodes*microbatches {num_nodes}*{microbatches}"
        )
    m = microbatches
    b = shape.global_batch // num_nodes // m
    s = shape.seq_len
    out: dict = {}
    if cfg.enc_dec:
        out["frames"] = jax.ShapeDtypeStruct((m, num_nodes, b, s, cfg.d_model), cfg.dtype())
        out["tokens"] = jax.ShapeDtypeStruct((m, num_nodes, b, WHISPER_DEC_LEN), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((m, num_nodes, b, WHISPER_DEC_LEN), jnp.int32)
        return out
    if cfg.family == "vlm":
        p = int(s * cfg.vlm_prefix_frac)
        out["prefix_embeds"] = jax.ShapeDtypeStruct((m, num_nodes, b, p, cfg.d_model), cfg.dtype())
        out["tokens"] = jax.ShapeDtypeStruct((m, num_nodes, b, s - p), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((m, num_nodes, b, s), jnp.int32)
        return out
    out["tokens"] = jax.ShapeDtypeStruct((m, num_nodes, b, s), jnp.int32)
    out["labels"] = jax.ShapeDtypeStruct((m, num_nodes, b, s), jnp.int32)
    return out


def prefill_inputs(cfg: ArchConfig, shape: InputShape) -> dict:
    assert shape.kind == "prefill"
    b, s = shape.global_batch, shape.seq_len
    if cfg.enc_dec:
        return {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.dtype()),
            "tokens": tokens_spec(b, WHISPER_DEC_LEN),
        }
    if cfg.family == "vlm":
        p = int(s * cfg.vlm_prefix_frac)
        return {
            "prefix_embeds": jax.ShapeDtypeStruct((b, p, cfg.d_model), cfg.dtype()),
            "tokens": tokens_spec(b, s - p),
        }
    return {"tokens": tokens_spec(b, s)}


def decode_cache_len(cfg: ArchConfig, shape: InputShape) -> int:
    """Ring-buffer length for attention caches at this decode shape."""
    if shape.name == "long_500k":
        # Sub-quadratic requirement: dense archs use the sliding window.
        return cfg.sliding_window
    if cfg.enc_dec:
        return min(shape.seq_len, 32768)  # synthetic for whisper (DESIGN §4)
    return shape.seq_len


def decode_inputs(cfg: ArchConfig, shape: InputShape) -> dict:
    assert shape.kind == "decode"
    b = shape.global_batch
    clen = decode_cache_len(cfg, shape)
    cache = jax.eval_shape(lambda: TF.init_cache(cfg, b, clen))
    out = {
        "token": jax.ShapeDtypeStruct((b,), jnp.int32),
        "cache": cache,
    }
    if cfg.enc_dec:
        out["memory"] = jax.ShapeDtypeStruct(
            (b, WHISPER_ENC_FRAMES, cfg.d_model), cfg.dtype()
        )
    return out


def long_context_applicable(cfg: ArchConfig) -> tuple[bool, str]:
    """Everything runs long_500k here: SSM/hybrid natively, attention archs
    via the sliding-window variant (first-class config knob). Whisper lowers
    but is architecturally synthetic (448-token decoder)."""
    if cfg.family in ("ssm", "hybrid"):
        return True, "native sub-quadratic (recurrent state)"
    if cfg.enc_dec:
        return True, "lowered with ring cache; synthetic for a 448-ctx decoder"
    return True, f"sliding-window attention (window={cfg.sliding_window})"
