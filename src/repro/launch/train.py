"""Decentralized LLM-cohort training driver.

Two modes:
- default (CPU-runnable): reduced member models, real data, real DecAvg
  steps — the full training loop with checkpointing and the WSD/cosine
  schedules. This is what CI and the examples exercise.
- ``--lower-only``: build the FULL-scale step for the production mesh and
  stop after .lower().compile() (delegates the heavy lifting to dryrun.py's
  builders) — use launch/dryrun.py for the complete sweep.

Run:  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --steps 50
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import base as cfgbase
from repro.core import decavg
from repro.data import tokens as tok
from repro.launch import steps as ST
from repro.models import transformer as TF
from repro.optim import adamw, schedules, sgd


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--topology", default="ring",
                    help="topology registry spec, e.g. 'ring', 'ba:n=8,m=2', "
                         "'er:p=0.3@regen=10' (n defaults to --nodes; "
                         "see core/topology.py for the grammar)")
    ap.add_argument("--mix-backend", default="auto",
                    choices=["auto"] + list(decavg.GossipEngine.BACKENDS),
                    help="gossip backend (auto: sparse at large N, else dense)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["const", "cosine", "wsd"])
    ap.add_argument("--gossip-every", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-path", default="results/train_ckpt.npz")
    ap.add_argument("--full-scale", action="store_true",
                    help="use the unreduced arch config (requires TPU-scale memory)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = cfgbase.get(args.arch)
    if not args.full_scale:
        cfg = dataclasses.replace(
            cfg.reduced(), param_dtype="float32", optimizer=cfg.optimizer
        )
    n = args.nodes

    # The engine owns the whole gossip side: topology (possibly
    # time-varying), mixing matrix, backend, and the per-round cadence.
    engine = decavg.GossipEngine(
        args.topology, backend=args.mix_backend, gossip_every=args.gossip_every,
        seed=args.seed, n=n,
    )
    if engine.num_nodes != n:
        raise SystemExit(
            f"--topology spec pins n={engine.num_nodes} but --nodes is {n}"
        )
    sched = schedules.get(args.schedule, args.lr, args.steps)

    key = jax.random.PRNGKey(args.seed)
    per_node = TF.init_params(key, cfg)
    params = jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), per_node)
    opt = adamw.init(params) if cfg.optimizer == "adamw" else sgd.init(params)
    print(
        f"arch={cfg.arch_id} members={TF.param_count(per_node)/1e6:.1f}M x {n} nodes "
        f"topology={engine.graph.name} backend={engine.backend} "
        f"optimizer={cfg.optimizer} schedule={args.schedule}"
    )

    loss_fn = ST.node_loss_fn(cfg)
    opt_update = adamw.update if cfg.optimizer == "adamw" else sgd.update

    @jax.jit
    def train_step(params, opt, batch, lr):
        b = jax.tree.map(lambda x: x[0], batch)
        losses, grads = jax.vmap(jax.value_and_grad(loss_fn))(params, b)
        params, opt = opt_update(grads, opt, params, lr=lr)
        return params, opt, losses.mean()

    data = tok.token_batches(n, args.batch, args.seq, cfg.vocab_size, steps=args.steps, seed=args.seed)
    t0 = time.time()
    for i, (toks, labels) in enumerate(data):
        batch = {"tokens": jnp.asarray(toks)[None], "labels": jnp.asarray(labels)[None]}
        params, opt, loss = train_step(params, opt, batch, float(sched(i)))
        params = engine.mix(params, round=i)  # identity rounds are free
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}  lr {float(sched(i)):.2e}  ({time.time()-t0:.0f}s)")
        if args.ckpt_every and i and i % args.ckpt_every == 0:
            ckpt.save(args.ckpt_path, {"params": params}, step=i)
    print(f"done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
