"""Decentralized LLM-cohort training driver — a thin CLI over the experiment
harness (repro/experiments/runner.py, model kind "lm").

The CLI builds one ExperimentSpec and hands it to ``runner.run_spec``: the
training loop, per-step JSONL streaming and the run-id bookkeeping all live
in the harness, so single runs land in the same results-store format as
sweeps (``--store``, default results/train_runs.jsonl).

Two modes:
- default (CPU-runnable): reduced member models, real data, real DecAvg
  steps — the full training loop with checkpointing and the WSD/cosine
  schedules. This is what CI and the examples exercise.
- ``--lower-only``: build the FULL-scale step for the production mesh and
  stop after .lower().compile() (delegates the heavy lifting to dryrun.py's
  builders) — use launch/dryrun.py for the complete sweep.

Run:  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --steps 50
"""

from __future__ import annotations

import argparse

from repro.core import decavg
from repro.experiments import runner
from repro.experiments.spec import ExperimentSpec
from repro.experiments.store import ResultsStore


def _parse_compress(value: str):
    """--compress flag: 'auto' (default), 'none'/'off', or a top-k fraction."""
    if value == "auto":
        return "auto"
    if value in ("none", "off"):
        return None
    return float(value)


def build_spec(args: argparse.Namespace) -> ExperimentSpec:
    """One LM-cohort ExperimentSpec from the CLI flags.

    Non-default execution knobs (compress/fused/resume) are only added to
    the model dict when set — combined with canonical()'s default-stripping
    this keeps pre-existing run ids (and store resume semantics) stable.
    """
    model = {
        "kind": "lm",
        "arch": args.arch,
        "nodes": args.nodes,
        "batch": args.batch,
        "seq": args.seq,
        "schedule": args.schedule,
        "full_scale": bool(args.full_scale),
        "ckpt_every": args.ckpt_every,
        "ckpt_path": args.ckpt_path,
    }
    compress = _parse_compress(args.compress)
    if compress != "auto":
        model["compress"] = compress
    if not args.fused:
        model["fused"] = False
    if args.resume:
        model["resume"] = True
    return ExperimentSpec(
        topology=args.topology,
        partitioner="iid",  # LM cohorts share the token stream (tokens.py)
        backend=args.mix_backend,
        rounds=args.steps,
        eval_every=20,
        lr=args.lr,
        gossip_every=args.gossip_every,
        faults=args.faults,
        seed=args.seed,
        model=model,
        tag="launch.train",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--topology", default="ring",
                    help="topology registry spec, e.g. 'ring', 'ba:n=8,m=2', "
                         "'er:p=0.3@regen=10' (n defaults to --nodes; "
                         "see core/topology.py for the grammar)")
    ap.add_argument("--mix-backend", default="auto",
                    choices=["auto"] + list(decavg.GossipEngine.BACKENDS),
                    help="gossip backend (auto: sparse at large N, else dense)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["const", "cosine", "wsd"])
    ap.add_argument("--gossip-every", type=int, default=1)
    ap.add_argument("--compress", default="auto",
                    help="CHOCO top-k gossip fraction in (0,1], 'none'/'off', "
                         "or 'auto' (on for members above ~1 MB of pytree)")
    ap.add_argument("--no-fused", dest="fused", action="store_false",
                    help="force the per-round Python loop instead of the "
                         "fused lax.scan path")
    ap.add_argument("--faults", default=None,
                    help="fault-injection spec (core/faults.py grammar)")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-path", default="results/train_ckpt.npz")
    ap.add_argument("--resume", action="store_true",
                    help="restore (params, opt, step) from --ckpt-path and "
                         "continue bit-identically from the saved round")
    ap.add_argument("--full-scale", action="store_true",
                    help="use the unreduced arch config (requires TPU-scale memory)")
    ap.add_argument("--store", default="results/train_runs.jsonl",
                    help="results JSONL (same schema as the sweep store)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = build_spec(args)
    result = runner.run_spec(spec, ResultsStore(args.store), verbose=True)
    final = result["final"]
    spread = final.get("g2_token_spread")
    spread_s = f"  g2_spread {spread:.4f}" if spread is not None else ""
    print(
        f"done in {final['wall_s']:.0f}s  loss {final['loss']:.4f}  "
        f"consensus {final['consensus_mean']:.3g}{spread_s}  "
        f"-> {args.store} ({result['run_id']})"
    )


if __name__ == "__main__":
    main()
