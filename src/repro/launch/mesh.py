"""Production mesh definitions (TPU v5e).

Single pod = 256 chips as (16, 16) ("data", "model"); multi-pod = 2 pods =
512 chips as (2, 16, 16) ("pod", "data", "model"). Functions (not module
constants) so importing never touches jax device state — the dry-run forces
512 fake host devices *before* any jax init (see dryrun.py), while tests and
benches see the single real CPU device.
"""

from __future__ import annotations

import jax

SINGLE_POD_SHAPE = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")

# v5e hardware constants for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh over however many (possibly fake) local devices exist —
    used by tests that exercise the sharded gossip paths."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def node_axes_for(num_nodes: int, mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Longest prefix of ("pod","data") mesh axes the node axis shards over.

    Small archs: num_nodes == pod*data -> fully sharded gossip (the einsum
    lowers to cross-`data` collectives). Big archs: num_nodes == pods (or 1)
    -> gossip over the `pod` axis only (cross-silo), params FSDP elsewhere.
    """
    out: list[str] = []
    prod = 1
    for a in ("pod", "data"):
        if a not in mesh.shape:
            continue
        nxt = prod * mesh.shape[a]
        if num_nodes % nxt == 0:
            out.append(a)
            prod = nxt
        else:
            break
    return tuple(out)
