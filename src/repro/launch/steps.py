"""Step-function builders: the decentralized train step (local grad step +
DecAvg gossip), the prefill step, and the serve (decode) step.

These are what the dry-run lowers and what launch/train.py / launch/serve.py
drive for real. Everything is a pure function of (params, opt_state, mixing
matrix, batch) so jit + in_shardings fully describes the distribution.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import decavg
from repro.models import transformer as TF
from repro.optim import adamw, sgd
from repro.train.losses import lm_loss

PyTree = Any


def node_loss_fn(
    cfg: ArchConfig,
    *,
    aux_coef: float = 0.01,
    remat: bool = True,
    act_sharding=None,
):
    """Per-node LM loss over one (B, S) batch dict."""

    def loss(params: PyTree, batch: dict) -> jax.Array:
        kw = {}
        if cfg.enc_dec:
            kw["memory"] = TF.encode(params, cfg, batch["frames"])
        if "prefix_embeds" in batch:
            kw["prefix_embeds"] = batch["prefix_embeds"]
        logits, aux = TF.forward(
            params, cfg, batch["tokens"], remat=remat, act_sharding=act_sharding, **kw
        )
        return lm_loss(logits, batch["labels"]) + aux_coef * aux

    return loss


def build_train_step(
    cfg: ArchConfig,
    *,
    num_nodes: int,
    microbatches: int = 1,
    optimizer: str = "adamw",
    lr: float = 3e-4,
    aux_coef: float = 0.01,
    mix_fn: Callable | None = None,
    act_sharding=None,
    acc_dtype=jnp.float32,
) -> Callable:
    """One DecAvg communication round at LLM-cohort scale.

    Signature: (params, opt_state, w_mix, batch) -> (params, opt_state, loss)
    with every batch leaf shaped (num_nodes, B, ...) and every param leaf
    node-stacked. The gossip is a mixing-matrix einsum on the node axis —
    sharded node axes make XLA lower it to the cross-pod/data collectives
    (DESIGN.md §5).
    """
    loss_fn = node_loss_fn(cfg, aux_coef=aux_coef, act_sharding=act_sharding)
    opt_update = adamw.update if optimizer == "adamw" else sgd.update
    mix = mix_fn or decavg.mix_dense

    # Batch leaves arrive as (microbatches, N, B/mb, ...): the microbatch
    # axis is a *leading input axis*, not an in-step reshape — splitting a
    # data-sharded batch dim inside the step defeats GSPMD's sharding
    # propagation (observed: activations silently replicated, 17 GB/device).
    def all_node_grads(params: PyTree, batch: dict) -> tuple[PyTree, jax.Array]:
        def one_mb(carry, b):
            g_acc, l_acc = carry
            losses, grads = jax.vmap(jax.value_and_grad(loss_fn, argnums=0))(params, b)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), g_acc, grads)
            return (g_acc, l_acc + losses.mean()), None

        if microbatches == 1:
            b = jax.tree.map(lambda x: x[0], batch)
            losses, grads = jax.vmap(jax.value_and_grad(loss_fn, argnums=0))(params, b)
            return grads, losses.mean()

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
        (g, l), _ = jax.lax.scan(one_mb, (g0, jnp.zeros((), jnp.float32)), batch)
        inv = 1.0 / microbatches
        return jax.tree.map(lambda x: x * inv, g), l * inv

    def train_step(params, opt_state, w_mix, batch):
        grads, loss = all_node_grads(params, batch)
        params, opt_state = opt_update(grads, opt_state, params, lr=lr)
        params = mix(w_mix, params)
        return params, opt_state, loss

    return train_step


def build_prefill_step(cfg: ArchConfig) -> Callable:
    """Inference prefill: full-sequence forward -> last-token logits.
    (KV-cache materialization is exercised by the decode shapes; see
    EXPERIMENTS.md §Dry-run notes.)"""

    def prefill_step(params, batch: dict):
        kw = {}
        if cfg.enc_dec:
            kw["memory"] = TF.encode(params, cfg, batch["frames"])
        if "prefix_embeds" in batch:
            kw["prefix_embeds"] = batch["prefix_embeds"]
        logits, _ = TF.forward(params, cfg, batch["tokens"], last_only=True, **kw)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    return prefill_step


def build_serve_step(cfg: ArchConfig, *, window: int | None = None) -> Callable:
    """Single-token decode against an existing cache (decode_32k/long_500k)."""

    def serve_step(params, token, cache, memory=None):
        logits, cache = TF.decode_step(
            params, cfg, token, cache, memory=memory, window=window
        )
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return serve_step
