"""Sharding rules: map every leaf of params / optimizer state / batch /
cache pytrees to a PartitionSpec on the production mesh.

Policy (DESIGN.md §5):
- node axis (leading, training only): sharded over the longest prefix of
  ("pod", "data") that divides num_nodes (mesh.node_axes_for); replicated
  otherwise (big archs, FSDP carries the memory instead).
- tensor parallel ("model"): the conventional TP dim of each matrix — the
  fused-head / ffn / expert dim on in-projections, the contraction dim on
  out-projections (megatron column/row split). MoE experts use expert
  parallelism (E -> "model") so dispatch/combine lower to all-to-alls.
- FSDP ("data", only when the node axis leaves it free): the d_model dim of
  each large matrix; gathered per-layer by XLA during the scan.

Implemented as a generic heuristic over trailing dims + explicit overrides,
with divisibility checks (e.g. minicpm's vocab 122753 falls back to
replicating the vocab dim and sharding d_model).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import node_axes_for

PyTree = Any


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _divides(dim: int, size: int) -> bool:
    return dim % size == 0


def leaf_spec(
    path: str,
    shape: tuple[int, ...],
    cfg: ArchConfig,
    mesh: jax.sharding.Mesh,
    *,
    node_axes: tuple[str, ...] = (),
    has_node_axis: bool = False,
) -> P:
    """PartitionSpec for one parameter leaf."""
    model = mesh.shape.get("model", 1)
    data = mesh.shape.get("data", 1)
    data_free = "data" not in node_axes
    ndim = len(shape)
    specs: list = [None] * ndim
    start = 0
    if has_node_axis:
        specs[0] = node_axes if node_axes else None
        start = 1

    body = shape[start:]
    if not body:
        return P(*specs)
    off = start  # index offset of body dim 0 in the full shape

    def try_set(rel_idx: int, axis: str, size: int) -> bool:
        i = off + (rel_idx % len(body))
        if specs[i] is None and _divides(shape[i], size):
            specs[i] = axis
            return True
        return False

    # Leading scan axis (layer groups) is never sharded: treat dims after it.
    # Identify by path: blocks/cross/encoder leaves have the group axis first.
    is_stacked = any(seg in path for seg in ("blocks/", "cross/", "encoder/blocks"))
    if is_stacked and len(body) >= 1:
        off += 1
        body = body[1:]
        if not body:
            return P(*specs)

    if len(body) == 1:
        return P(*specs)  # norms / biases / small vectors: replicate

    # --- explicit family rules -------------------------------------------
    if path.endswith("embed"):
        # Token-gather tables: shard d_model only. A vocab-sharded table
        # turns every embedding lookup into an SPMD full-rematerialization
        # (observed: multi-GB replicated gather transients); the table itself
        # is small next to layer weights.
        try_set(-1, "model", model)
        return P(*specs)

    if "/moe/" in path:
        name = path.rsplit("/", 1)[-1]
        if name == "router":  # (d, E)
            try_set(-1, "model", model)
            if data_free:
                try_set(0, "data", data)
            return P(*specs)
        if name in ("w_gate", "w_in", "w_out") and len(body) == 3:  # (E, d|ff, ff|d)
            try_set(0, "model", model)  # expert parallelism
            if data_free:
                # FSDP the larger of the two non-expert dims.
                rel = 1 if shape[off + 1] >= shape[off + 2] else 2
                try_set(rel, "data", data)
            return P(*specs)
        # dense-residual ffn inside moe falls through to the generic rule.

    # --- generic megatron-style rule -------------------------------------
    last = body[-1]
    if last == cfg.d_model and len(body) >= 2:
        # out-projection (X, d): TP on X (row-parallel), FSDP on d.
        try_set(-2, "model", model)
        if data_free:
            try_set(-1, "data", data)
    else:
        # in-projection (d, X) or embedding (V, d-like): TP on the last dim.
        try_set(-1, "model", model)
        if data_free:
            try_set(-2, "data", data)
    return P(*specs)


def param_shardings(
    shapes_tree: PyTree,
    cfg: ArchConfig,
    mesh: jax.sharding.Mesh,
    *,
    num_nodes: int | None = None,
) -> PyTree:
    """NamedSharding tree for a param (or optimizer-state) shape tree.

    num_nodes=None -> serving layout (no node axis).
    """
    has_node = num_nodes is not None
    naxes = node_axes_for(num_nodes, mesh) if has_node else ()

    def one(path, leaf):
        spec = leaf_spec(
            _path_str(path),
            tuple(leaf.shape),
            cfg,
            mesh,
            node_axes=naxes,
            has_node_axis=has_node,
        )
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, shapes_tree)


def batch_shardings(
    shapes_tree: PyTree,
    mesh: jax.sharding.Mesh,
    *,
    num_nodes: int,
    layout: str = "tp",
) -> PyTree:
    """Train inputs (M, N, B, ...): microbatch axis unsharded, node axis over
    its mesh axes, per-node batch over whatever of ("pod","data") the node
    axis left unused — plus "model" in the fsdp_model layout (small archs:
    batch-parallel over the model axis, weights gathered ZeRO-3 style,
    instead of 16-way tensor parallelism)."""
    naxes = node_axes_for(num_nodes, mesh)
    free = tuple(a for a in ("pod", "data") if a in mesh.shape and a not in naxes)
    if layout == "fsdp_model":
        free = free + ("model",)

    def one(leaf):
        b = leaf.shape[2]
        bspec = None
        if free:
            prod = 1
            used = []
            for a in free:
                if b % (prod * mesh.shape[a]) == 0:
                    used.append(a)
                    prod *= mesh.shape[a]
            bspec = tuple(used) if used else None
        spec = [None, naxes if naxes else None, bspec] + [None] * (leaf.ndim - 3)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, shapes_tree)


def decode_shardings(
    inputs: dict,
    cfg: ArchConfig,
    mesh: jax.sharding.Mesh,
) -> dict:
    """Serve-step inputs.

    - token (B,): batch over "data" when divisible.
    - attention caches (B, T, hkv, hd): batch over "data", cache seq over
      "model" (flash-decoding: XLA partial-softmaxes over the sharded T and
      combines with a small collective).
    - recurrent states: batch over "data", inner (d-like) dim over "model".
    - memory (B, T, d): batch over "data".
    """
    data = mesh.shape.get("data", 1)
    model = mesh.shape.get("model", 1)

    def bspec(b):
        return "data" if b % data == 0 else None

    def cache_leaf(path, leaf):
        pstr = _path_str(path)
        shp = leaf.shape
        if pstr.endswith("index") or leaf.ndim <= 1:
            return NamedSharding(mesh, P())
        # leading group-stack axis then (B, ...) body
        specs: list = [None] * leaf.ndim
        specs[1] = bspec(shp[1])
        if pstr.endswith("/k") or pstr.endswith("/v"):
            if shp[2] % model == 0:
                specs[2] = "model"  # cache seq dim -> flash-decoding split
        elif pstr.endswith("ssm") or pstr.endswith("conv"):
            # (G, B, di, n) or (G, B, K-1, di): shard the di dim.
            di_idx = 2 if pstr.endswith("ssm") else 3
            if shp[di_idx] % model == 0:
                specs[di_idx] = "model"
        elif pstr.endswith("wkv"):
            if shp[2] % model == 0:
                specs[2] = "model"  # heads
        elif pstr.endswith("shift"):
            if shp[2] % model == 0:
                specs[2] = "model"  # d_model
        return NamedSharding(mesh, P(*specs))

    out: dict = {}
    for k, v in inputs.items():
        if k == "cache":
            out[k] = jax.tree_util.tree_map_with_path(cache_leaf, v)
        elif k == "token":
            out[k] = NamedSharding(mesh, P(bspec(v.shape[0])))
        else:  # memory / frames: (B, T, d)
            out[k] = NamedSharding(mesh, P(bspec(v.shape[0]), None, None))
    return out


def prefill_shardings(inputs: dict, mesh: jax.sharding.Mesh) -> dict:
    data = mesh.shape.get("data", 1)

    def one(leaf):
        b = leaf.shape[0]
        spec = ["data" if b % data == 0 else None] + [None] * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*spec))

    return {k: jax.tree.map(one, v) for k, v in inputs.items()}
