"""Roofline extraction from compiled dry-run artifacts.

- ``compiled.cost_analysis()``: HLO FLOPs + bytes accessed (per partition —
  SPMD modules are per-device programs).
- collective bytes: NOT in cost_analysis; parsed from the post-SPMD HLO text
  by summing operand/result sizes of every all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, converted to per-device
  *wire bytes*:
      all-gather:        out - in          (received from peers)
      reduce-scatter:    in - out          (sent to peers)
      all-reduce:        2 * (in - in/S)   (ring RS+AG)  ~ 2 * in
      all-to-all:        in * (S-1)/S      ~ in
      collective-permute: in
- model FLOPs: 6·N·D with N = active params (MoE: top-k experts + shared).

Hardware constants (v5e) live in launch/mesh.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

from repro.configs.base import ArchConfig
from repro.launch import mesh as M

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],{}:#* ]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _array_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_wire_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device wire bytes by collective kind, from post-SPMD HLO text."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_type, kind, _ = m.groups()
        # operands: everything inside the call parens
        call = line[m.end() - 1 :]
        depth = 0
        end = 0
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = call[1:end]
        in_b = _array_bytes(operands)
        out_b = _array_bytes(result_type)
        if kind == "all-gather":
            wire = max(out_b - in_b, 0)
        elif kind == "reduce-scatter":
            wire = max(in_b - out_b, 0)
        elif kind == "all-reduce":
            wire = 2 * in_b
        elif kind == "all-to-all":
            wire = in_b
        else:  # collective-permute
            wire = in_b
        out[kind] = out.get(kind, 0.0) + wire
    return out


def model_flops_per_step(cfg: ArchConfig, tokens: int) -> float:
    """6 · N_active · tokens (the §Roofline MODEL_FLOPS convention)."""
    n_active = active_param_count(cfg)
    return 6.0 * n_active * tokens


def _attn_layer_count(cfg: ArchConfig) -> int:
    reps = cfg.num_layers // cfg.period
    return reps * sum(1 for s in cfg.pattern if s.mixer == "attn")


def analytic_step_flops(cfg: ArchConfig, *, kind: str, batch: int, seq: int,
                        cache_len: int = 0, window: int | None = None) -> float:
    """Whole-step FLOPs across all chips, from the workload math.

    Why analytic: XLA-CPU ``cost_analysis`` counts loop bodies ONCE (no trip
    counts), undercounting scanned/chunked models by up to ~500x. The
    matmul-dominated FLOPs of this system are exactly computable:
      param term      mult * 2 * N_active * tokens   (mult=3 for fwd+bwd)
      attention term  mult * 4 * B * S * T_eff * H * hd per attn layer
                      (QK^T + PV; causal halves T_eff)
      MoE dispatch    mult * 3 einsums * 2 * T * E * Cg * d per MoE layer
      rwkv/mamba scan small elementwise terms (included approximately)
    """
    mult = 3.0 if kind == "train" else 1.0
    if kind == "decode":
        tokens = batch  # one token per sequence
    else:
        tokens = batch * seq
    total = mult * 2.0 * active_param_count(cfg) * tokens

    # attention quadratic term
    la = _attn_layer_count(cfg)
    h, hd = cfg.num_heads, cfg.hd
    if la:
        if kind == "decode":
            t_eff = min(cache_len, window) if window else cache_len
            total += mult * 4.0 * batch * t_eff * h * hd * la
        else:
            t_eff = min(seq, window) if window else seq
            # causal: average attended length ~ t_eff/2
            total += mult * 4.0 * batch * seq * (t_eff / 2.0) * h * hd * la

    # MoE dispatch/combine overhead (as implemented: dense one-hot einsums)
    if cfg.moe is not None:
        reps = cfg.num_layers // cfg.period
        lm = reps * sum(1 for s in cfg.pattern if s.ffn == "moe")
        tg = min(cfg.moe.group_size, tokens)
        cg = max(int(cfg.moe.capacity_factor * cfg.moe.top_k * tg / cfg.moe.num_experts), 1)
        d = cfg.d_model
        # 3 one-hot einsums (dispatch-in, combine, expert-out gather), each
        # 2 * Tg * E * Cg * d per group -> 2 * T * E * Cg * d in total.
        total += mult * lm * 3.0 * 2.0 * tokens * cfg.moe.num_experts * cg * d

    # rwkv WKV chunked recurrence (D=head_dim): ~4*T*H*D^2 inter/state +
    # 4*T*C*H*D intra per layer
    if cfg.rwkv is not None:
        reps = cfg.num_layers // cfg.period
        lr = reps * sum(1 for s in cfg.pattern if s.mixer == "rwkv")
        hd_r = cfg.rwkv.head_dim
        heads = cfg.d_model // hd_r
        c = cfg.rwkv.chunk
        total += mult * lr * tokens * heads * (4.0 * hd_r * hd_r + 4.0 * c * hd_r)

    # mamba selective scan: ~10 elementwise ops per (t, di, n) element
    if cfg.mamba is not None:
        reps = cfg.num_layers // cfg.period
        lm_ = reps * sum(1 for s in cfg.pattern if s.mixer == "mamba")
        di = cfg.mamba.inner(cfg.d_model)
        total += mult * lm_ * 10.0 * tokens * di * cfg.mamba.d_state
    return total


def analytic_hbm_bytes_per_device(
    cfg: ArchConfig,
    *,
    kind: str,
    num_nodes: int,
    microbatches: int,
    arg_bytes: float,
    temp_bytes: float,
) -> float:
    """Per-device HBM traffic estimate for one step.

    Weights are re-streamed from HBM once per microbatch in fwd and once in
    bwd (scan over layer groups reads every group's shard); optimizer state
    is read+written once; transients (activations, attention tiles) are
    written and read back ~once. arg/temp sizes come from the compiled
    buffer assignment (per-device truth, modulo XLA-CPU's f32 legalization
    of bf16 GEMMs, which inflates temp — noted in EXPERIMENTS.md).
    """
    if kind == "train":
        weight_passes = 2 * microbatches + 2  # fwd+bwd reads, grad+opt write
    else:
        weight_passes = 1
    return weight_passes * arg_bytes + 2.0 * temp_bytes


def active_param_count(cfg: ArchConfig) -> float:
    """Active params per token: full count minus non-selected experts."""
    total = 0.0
    d = cfg.d_model
    # embeddings + head (counted: embedding lookups are cheap but the head
    # matmul is real compute; follow the 6ND convention of counting both).
    total += 2.0 * cfg.vocab_size * d
    for spec in cfg.pattern:
        reps = cfg.num_layers // cfg.period
        if spec.mixer == "attn":
            mix = d * cfg.num_heads * cfg.hd * 2 + d * cfg.num_kv_heads * cfg.hd * 2
        elif spec.mixer == "mamba":
            di = cfg.mamba.inner(d)
            dr = cfg.mamba.rank(d)
            mix = d * 2 * di + di * (dr + 2 * cfg.mamba.d_state) + dr * di + di * d
        else:  # rwkv
            mix = 6 * d * d
        if spec.ffn == "dense":
            ffn = 3.0 * d * cfg.d_ff
        elif spec.ffn == "moe":
            ffn = 3.0 * d * cfg.moe.d_ff * cfg.moe.top_k + d * cfg.moe.num_experts
            if cfg.moe.dense_residual:
                ffn += 3.0 * d * (cfg.moe.dense_d_ff or cfg.moe.d_ff)
        elif spec.ffn == "rwkv":
            ffn = 2.0 * d * cfg.d_ff + d * d
        else:
            ffn = 0.0
        total += reps * (mix + ffn)
    if cfg.enc_dec:
        total += cfg.enc_layers * (4 * d * d + 2.0 * d * cfg.d_ff)
        total += cfg.num_layers * 4 * d * d  # cross-attention
    return total


def total_param_count(cfg: ArchConfig) -> float:
    """Full parameter count (MoE: all experts)."""
    d = cfg.d_model
    total = 2.0 * cfg.vocab_size * d
    for spec in cfg.pattern:
        reps = cfg.num_layers // cfg.period
        if spec.mixer == "attn":
            mix = d * cfg.num_heads * cfg.hd * 2 + d * cfg.num_kv_heads * cfg.hd * 2
        elif spec.mixer == "mamba":
            di = cfg.mamba.inner(d)
            dr = cfg.mamba.rank(d)
            mix = d * 2 * di + di * (dr + 2 * cfg.mamba.d_state) + dr * di + di * d
        else:
            mix = 6 * d * d
        if spec.ffn == "dense":
            ffn = 3.0 * d * cfg.d_ff
        elif spec.ffn == "moe":
            ffn = 3.0 * d * cfg.moe.d_ff * cfg.moe.num_experts + d * cfg.moe.num_experts
            if cfg.moe.dense_residual:
                ffn += 3.0 * d * (cfg.moe.dense_d_ff or cfg.moe.d_ff)
        elif spec.ffn == "rwkv":
            ffn = 2.0 * d * cfg.d_ff + d * d
        else:
            ffn = 0.0
        total += reps * (mix + ffn)
    if cfg.enc_dec:
        total += cfg.enc_layers * (4 * d * d + 2.0 * d * cfg.d_ff)
        total += cfg.num_layers * 4 * d * d
    return total


def analytic_collective_bytes(
    cfg: ArchConfig,
    *,
    kind: str,
    batch: int,
    seq: int,
    num_nodes: int,
    microbatches: int,
    mesh_shape: dict[str, int],
    node_sharded: bool,
    layout: str = "tp",
    gossip: str = "dense",
    serve_layout: str = "sharded",
) -> dict[str, float]:
    """Per-device wire bytes per step, by source, from the sharding design.

    Why analytic: the compiled HLO's loops are rewritten by XLA (peeling,
    double-buffer "wide" clones), so textual trip-count multiplication over-
    counts by ~10x, while count-once parsing undercounts by ~100x. The
    collective SCHEDULE (which kinds appear, where) is taken from the HLO
    (hlo_walk inventory, reported alongside); the byte volumes below follow
    from the sharding rules, which we control:

      fsdp_ag   weight all-gathers over `data` (node-replicated archs only):
                one full re-gather per microbatch in fwd and again in bwd
                (remat), (Dd-1)/Dd of the TP-sharded member bytes.
      grad_rs   gradient reduce-scatter over `data`, once per microbatch.
      gossip    DecAvg mixing over a sharded node axis: all-gather of the
                other nodes' TP shards ((K-1)/K x K x member-TP bytes).
                Node-replicated archs mix locally: 0.
      tp_ar     Megatron-style activation all-reduces: ~6 per layer per
                microbatch (2 fwd, 2 remat re-fwd, 2 bwd), 2x payload each.
      moe_a2a   dispatch+combine all-to-alls: 2 x cf x k x token-bytes per
                MoE layer (x3 for train fwd+bwd).
      serve_ag  decode/prefill weight gathers (weights `data`-sharded in the
                serving layout): one full pass per step.
    """
    dm = mesh_shape.get("model", 1)
    dd = mesh_shape.get("data", 1)
    pods = mesh_shape.get("pod", 1)
    devices = dm * dd * pods
    bpp = 2.0 if cfg.param_dtype == "bfloat16" else 4.0
    p_total = total_param_count(cfg)
    member_tp = p_total * bpp / dm  # one member model after TP sharding
    d = cfg.d_model
    la = cfg.num_layers
    out: dict[str, float] = {}
    mult_train = 3.0 if kind == "train" else 1.0

    if kind == "train":
        tokens = batch * seq
        tokens_dev = tokens / max(devices / dm, 1)  # per device column
        if node_sharded and layout == "fsdp_model":
            # Optimized small-arch layout (§Perf H1): weights FSDP over
            # `model`, batch-parallel over `model` within each node. Weights
            # are re-gathered per microbatch (fwd + bwd), grads reduce-
            # scattered; no activation all-reduces at all.
            frac_m = (dm - 1) / dm if dm > 1 else 0.0
            member_full = p_total * bpp
            out["fsdp_ag"] = 2.0 * microbatches * member_full * frac_m
            out["grad_rs"] = microbatches * member_full * frac_m
            if gossip == "sparse":
                # edge-colored permutes: mean-degree neighbor shards move,
                # not (K-1) of them (ER at 2*p*: mean degree ~ 2 ln K)
                import math

                mean_deg = 2.0 * math.log(max(num_nodes, 2))
                out["gossip"] = mean_deg * member_full / dm
            else:
                out["gossip"] = (num_nodes - 1) * member_full / dm / max(num_nodes / dd, 1)
            out["tp_ar"] = 0.0
        elif node_sharded:
            # Node axis occupies `data`: weights are TP-resident (no FSDP
            # gathers) and grads are node-local (no cross-node reduction);
            # the gossip all-gather over the node axis moves the params.
            out["fsdp_ag"] = 0.0
            out["grad_rs"] = 0.0
            out["gossip"] = (num_nodes - 1) * member_tp / max(num_nodes / dd, 1)
            out["tp_ar"] = 6.0 * la * 2.0 * tokens_dev * d * bpp
        else:
            frac = (dd - 1) / dd if dd > 1 else 0.0
            out["fsdp_ag"] = 2.0 * microbatches * num_nodes * member_tp * frac
            out["grad_rs"] = microbatches * num_nodes * member_tp * frac
            out["gossip"] = 0.0
            out["tp_ar"] = 6.0 * la * 2.0 * tokens_dev * d * bpp
    else:
        tokens = batch if kind == "decode" else batch * seq
        tokens_dev = tokens / max(devices / dm, 1)
        frac = (dd - 1) / dd if dd > 1 else 0.0
        if kind == "decode" and serve_layout == "pipeline":
            # §Perf H3: weights/cache stay on their stage; (2S-1) activation
            # hops of one microgroup + the final logits psum.
            stages = dd
            mbb = max(batch // stages, 1)
            out["pipeline_permute"] = (2 * stages - 1) * mbb * d * bpp
            out["logits_psum"] = 2.0 * batch * d * bpp
            out["serve_ag"] = 0.0
        else:
            out["serve_ag"] = member_tp * frac  # weights re-streamed once
        out["tp_ar"] = 2.0 * la * 2.0 * tokens_dev * d * bpp

    if cfg.moe is not None:
        reps = cfg.num_layers // cfg.period
        lm = reps * sum(1 for s in cfg.pattern if s.ffn == "moe")
        k_eff = cfg.moe.capacity_factor * cfg.moe.top_k
        tokens_dev_m = (batch * (seq if kind != "decode" else 1)) / max(devices / dm, 1)
        out["moe_a2a"] = mult_train * lm * 2.0 * k_eff * tokens_dev_m * d * bpp
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh_name: str
    chips: int
    step_flops: float           # whole step, all chips (analytic — see
                                # analytic_step_flops for why not cost_analysis)
    hbm_bytes_dev: float        # per-device HBM traffic estimate
    wire_bytes: float           # per device, analytic model (see
                                # analytic_collective_bytes for why not HLO)
    wire_by_kind: dict[str, float]
    hlo_collectives: dict[str, float]  # HLO inventory: per-kind count-once bytes
    collective_ops: dict[str, int]
    model_flops: float          # 6·N_active·D convention, whole step
    per_device_hbm: int         # peak bytes, from memory_analysis
    raw_cost_flops: float       # cost_analysis() raw value (per-iteration
                                # undercount on CPU; kept for transparency)
    unknown_loops: int = 0
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def finalize(self) -> "Roofline":
        self.compute_s = self.step_flops / (self.chips * M.PEAK_FLOPS_BF16)
        self.memory_s = self.hbm_bytes_dev / M.HBM_BW
        self.collective_s = self.wire_bytes / M.ICI_BW
        return self

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / step FLOPs: how much of the executed compute is the
        6·N·D 'useful' part (the rest: attention quadratic, MoE dispatch,
        remat recompute folded into mult)."""
        return self.model_flops / self.step_flops if self.step_flops else 0.0

    def row(self) -> dict[str, Any]:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh_name,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "step_flops": self.step_flops,
            "useful_ratio": self.useful_flops_ratio,
            "per_device_hbm_gb": self.per_device_hbm / 1e9,
            "wire_by_kind": self.wire_by_kind,
            "hlo_collectives": self.hlo_collectives,
            "collective_ops": self.collective_ops,
            "raw_cost_flops": self.raw_cost_flops,
            "unknown_loops": self.unknown_loops,
        }


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cfg: ArchConfig,
    kind: str,
    batch: int,
    seq: int,
    cache_len: int,
    window: int | None,
    num_nodes: int,
    microbatches: int,
    cost: dict,
    hlo_text: str,
    memory_analysis,
    model_flops: float,
    layout: str = "tp",
    gossip: str = "dense",
    serve_layout: str = "sharded",
) -> Roofline:
    from repro.launch.hlo_walk import collective_wire_bytes_looped
    from repro.launch.mesh import node_axes_for

    rep = collective_wire_bytes_looped(hlo_text)
    arg_b = temp_b = 0.0
    if memory_analysis is not None:
        arg_b = float(getattr(memory_analysis, "argument_size_in_bytes", 0))
        temp_b = float(getattr(memory_analysis, "temp_size_in_bytes", 0))
    # Outputs are donated (alias inputs): peak ~ args + temps.
    per_dev_hbm = int(arg_b + temp_b)
    step_flops = analytic_step_flops(
        cfg, kind=kind, batch=batch, seq=seq, cache_len=cache_len, window=window
    )
    hbm_dev = analytic_hbm_bytes_per_device(
        cfg, kind=kind, num_nodes=num_nodes, microbatches=microbatches,
        arg_bytes=arg_b, temp_bytes=temp_b,
    )
    mesh_shape = (
        {"pod": 2, "data": 16, "model": 16} if chips == 512 else {"data": 16, "model": 16}
    )
    node_sharded = kind == "train" and num_nodes % mesh_shape["data"] == 0
    wire = analytic_collective_bytes(
        cfg, kind=kind, batch=batch, seq=seq, num_nodes=num_nodes,
        microbatches=microbatches, mesh_shape=mesh_shape,
        node_sharded=node_sharded, layout=layout, gossip=gossip,
        serve_layout=serve_layout,
    )
    return Roofline(
        arch=arch,
        shape=shape,
        mesh_name=mesh_name,
        chips=chips,
        step_flops=step_flops,
        hbm_bytes_dev=hbm_dev,
        wire_bytes=float(sum(wire.values())),
        wire_by_kind=wire,
        # HLO evidence: count-once per-kind bytes (lower bound; loops run the
        # same op many times — see analytic_collective_bytes docstring).
        hlo_collectives={k: round(v) for k, v in collective_wire_bytes(hlo_text).items()},
        collective_ops=rep.op_counts,
        model_flops=model_flops,
        per_device_hbm=per_dev_hbm,
        raw_cost_flops=float(cost.get("flops", 0.0)),
        unknown_loops=rep.unknown_loops,
    ).finalize()
