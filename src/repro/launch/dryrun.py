"""Multi-pod dry-run: prove every (architecture × input shape × mesh)
combination lowers, compiles, and fits — without TPU hardware.

MUST be the very first two lines (before any other import, including repro.*,
since jax locks the device count on first init):
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

# ---------------------------------------------------------------------------

import argparse
import json
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cfgbase
from repro.launch import analysis, shapes as SH, sharding as SR, steps as ST
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as TF
from repro.optim import adamw

# Per-arch gradient-accumulation factor for train_4k: bounds activation
# memory (DESIGN §5). Keys are config module names; default 1.
MICROBATCHES = {
    "mistral-large-123b": 8,
    "internvl2-76b": 8,
    "dbrx-132b": 8,
    "arctic-480b": 16,
    "jamba-v0.1-52b": 4,
    "stablelm-3b": 2,
    "minicpm-2b": 2,
    "rwkv6-3b": 2,
    "llama3.2-1b": 2,
}


def _replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def build_train(cfg, mesh, shape, *, num_nodes, microbatches, layout="tp", gossip="dense"):
    """Returns (fn, args_specs, in_shardings, out_shardings, donate)."""
    from repro.optim import sgd

    per_node = jax.eval_shape(
        lambda: TF.init_params(jax.random.PRNGKey(0), cfg)
    )
    params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((num_nodes,) + s.shape, s.dtype), per_node
    )
    opt_dtype = jnp.dtype(cfg.opt_dtype)
    if cfg.optimizer == "adamw":
        opt = jax.eval_shape(lambda p: adamw.init(p, dtype=opt_dtype), params)
    else:
        opt = jax.eval_shape(lambda p: sgd.init(p, dtype=opt_dtype), params)
    w_mix = jax.ShapeDtypeStruct((num_nodes, num_nodes), jnp.float32)
    batch = SH.train_inputs(cfg, shape, num_nodes, microbatches=microbatches)

    p_sh = SR.param_shardings(params, cfg, mesh, num_nodes=num_nodes)
    if cfg.optimizer == "adamw":
        opt_sh = adamw.AdamWState(mu=p_sh, nu=p_sh, count=NamedSharding(mesh, P()))
    else:
        opt_sh = sgd.SGDState(momentum=p_sh)
    b_sh = SR.batch_shardings(batch, mesh, num_nodes=num_nodes, layout=layout)
    w_sh = NamedSharding(mesh, P())

    # Residual-stream constraint (B, S, d) inside the node vmap: batch over
    # whatever of ("pod","data") the node axis left free, d_model over TP.
    from repro.launch.mesh import node_axes_for

    naxes = node_axes_for(num_nodes, mesh)
    free = tuple(a for a in ("pod", "data") if a in mesh.shape and a not in naxes)
    if layout == "fsdp_model":
        free = free + ("model",)
    per_node_b = shape.global_batch // num_nodes // microbatches
    bdims = []
    prod = 1
    for a in free:
        if per_node_b % (prod * mesh.shape[a]) == 0:
            bdims.append(a)
            prod *= mesh.shape[a]
    # Residual layout: batch over its axes; d_model over "model" only in the
    # TP layout (fsdp_model keeps d local and gathers weights instead).
    dspec = None if layout == "fsdp_model" else "model"
    act_sh = NamedSharding(mesh, P(tuple(bdims) if bdims else None, None, dspec))

    mix_fn = None
    if gossip == "sparse":
        # Topology-aware gossip (§Perf H2): the DecAvg graph is an ER graph
        # at 2*p* over the cohort; only neighbor shards move (edge-colored
        # ppermute schedule) instead of the dense node-axis all-gather.
        import functools

        from repro.core import decavg, mixing as MX, topology as TO

        if num_nodes != mesh.shape.get("data", 0):
            raise ValueError("sparse gossip requires num_nodes == |data|")
        g = TO.make(f"er:n={num_nodes}", seed=0)  # registry default p = 2*p*
        colors = MX.edge_coloring(g)
        mix_fn = lambda w, p: decavg.mix_permute(
            w, p, colors, mesh=mesh, node_axis="data"
        )

    fn = ST.build_train_step(
        cfg,
        num_nodes=num_nodes,
        microbatches=microbatches,
        optimizer=cfg.optimizer,
        act_sharding=act_sh,
        acc_dtype=opt_dtype,  # grad accumulator follows the optimizer dtype
        mix_fn=mix_fn,
    )
    args = (params, opt, w_mix, batch)
    shardings = (p_sh, opt_sh, w_sh, b_sh)
    out_shardings = (p_sh, opt_sh, NamedSharding(mesh, P()))
    return fn, args, shardings, out_shardings, (0, 1)


def build_prefill(cfg, mesh, shape):
    params = jax.eval_shape(lambda: TF.init_params(jax.random.PRNGKey(0), cfg))
    batch = SH.prefill_inputs(cfg, shape)
    p_sh = SR.param_shardings(params, cfg, mesh, num_nodes=None)
    b_sh = SR.prefill_shardings(batch, mesh)
    fn = ST.build_prefill_step(cfg)
    data = mesh.shape.get("data", 1)
    out_sh = NamedSharding(mesh, P("data" if shape.global_batch % data == 0 else None))
    return fn, (params, batch), (p_sh, b_sh), out_sh, ()


def build_decode(cfg, mesh, shape):
    params = jax.eval_shape(lambda: TF.init_params(jax.random.PRNGKey(0), cfg))
    inputs = SH.decode_inputs(cfg, shape)
    p_sh = SR.param_shardings(params, cfg, mesh, num_nodes=None)
    in_sh = SR.decode_shardings(inputs, cfg, mesh)
    window = cfg.sliding_window if shape.name == "long_500k" else None
    fn = ST.build_serve_step(cfg, window=window)
    args = [params, inputs["token"], inputs["cache"]]
    shardings = [p_sh, in_sh["token"], in_sh["cache"]]
    if cfg.enc_dec:
        args.append(inputs["memory"])
        shardings.append(in_sh["memory"])
    out_shardings = (in_sh["token"], in_sh["cache"])
    donate = (2,)
    return fn, tuple(args), tuple(shardings), out_shardings, donate


def build_decode_pipeline(cfg, mesh, shape):
    """§Perf H3 serving layout: `data` axis = pipeline stages (weights and
    cache stay put; activations rotate via ppermute), manual megatron TP
    over `model`, per-rank int8 KV-head cache. See serve/pipeline_manual.py
    for why the auto-partitioned variant (serve/pipeline.py) cannot be used
    at 256 devices."""
    from repro.serve import pipeline_manual as PM
    from repro.serve.pipeline import build_pipeline_step

    clen = SH.decode_cache_len(cfg, shape)
    tp = mesh.shape["model"]
    params = jax.eval_shape(lambda: TF.init_params(jax.random.PRNGKey(0), cfg))
    p_sh = PM.param_shardings(cfg, mesh, params)
    cache = jax.eval_shape(
        lambda: PM.init_kv_cache(cfg, shape.global_batch, clen, tp=tp)
    )
    c_sh = PM.cache_shardings(mesh)
    token = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    window = cfg.sliding_window if shape.name == "long_500k" else None
    fn = build_pipeline_step(cfg, mesh, manual=True, window=window)
    args = (params, token, cache)
    tok_sh = NamedSharding(mesh, P("pod") if "pod" in mesh.shape else P())
    shardings = (p_sh, tok_sh, c_sh)
    out_shardings = (tok_sh, c_sh)
    return fn, args, shardings, out_shardings, (2,)


def run_one(arch: str, shape_name: str, *, multi_pod: bool, layout: str = "tp", microbatches: int | None = None, gossip: str = "dense", serve_layout: str = "sharded") -> dict[str, Any]:
    cfg = cfgbase.get(arch)
    shape = SH.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    num_nodes = cfg.num_nodes_multi_pod if multi_pod else cfg.num_nodes_single_pod

    t0 = time.perf_counter()
    mb = 1
    window = None
    cache_len = 0
    eff_seq = SH.WHISPER_DEC_LEN if cfg.enc_dec else shape.seq_len
    if shape.kind == "train":
        mb = microbatches or MICROBATCHES.get(cfg.arch_id, 1)
        fn, args, in_sh, out_sh, donate = build_train(
            cfg, mesh, shape, num_nodes=num_nodes, microbatches=mb,
            layout=layout, gossip=gossip,
        )
        model_flops = 6.0 * analysis.active_param_count(cfg) * shape.global_batch * eff_seq
    elif shape.kind == "prefill":
        fn, args, in_sh, out_sh, donate = build_prefill(cfg, mesh, shape)
        model_flops = 2.0 * analysis.active_param_count(cfg) * shape.global_batch * eff_seq
    else:
        if serve_layout == "pipeline":
            fn, args, in_sh, out_sh, donate = build_decode_pipeline(cfg, mesh, shape)
        else:
            fn, args, in_sh, out_sh, donate = build_decode(cfg, mesh, shape)
        window = cfg.sliding_window if shape.name == "long_500k" else None
        cache_len = SH.decode_cache_len(cfg, shape)
        model_flops = 2.0 * analysis.active_param_count(cfg) * shape.global_batch

    jitted = jax.jit(
        fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate
    )
    lowered = jitted.lower(*args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    cost_raw = compiled.cost_analysis()
    cost = cost_raw[0] if isinstance(cost_raw, (list, tuple)) else cost_raw
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()

    roof = analysis.analyze(
        arch=cfg.arch_id,
        shape=shape_name,
        mesh_name=mesh_name,
        chips=chips,
        cfg=cfg,
        kind=shape.kind,
        batch=shape.global_batch,
        seq=eff_seq,
        cache_len=cache_len,
        window=window,
        num_nodes=num_nodes,
        microbatches=mb,
        cost=dict(cost) if cost else {},
        hlo_text=hlo,
        memory_analysis=mem,
        model_flops=model_flops,
        layout=layout,
        gossip=gossip,
        serve_layout=serve_layout,
    )
    row = roof.row()
    row["layout"] = layout
    row.update(
        num_nodes=num_nodes,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        status="ok",
    )
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SH.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="every arch x shape")
    ap.add_argument("--layout", default="tp", choices=["tp", "fsdp_model"])
    ap.add_argument("--microbatches", type=int, default=None, help="override per-arch default")
    ap.add_argument("--gossip", default="dense", choices=["dense", "sparse"])
    ap.add_argument("--serve-layout", default="sharded", choices=["sharded", "pipeline"])
    ap.add_argument("--out", default=None, help="append JSONL results here")
    args = ap.parse_args()

    archs = list(cfgbase.ASSIGNED_ARCHS) if args.all or not args.arch else [args.arch]
    shape_names = list(SH.SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    results = []
    for arch in archs:
        for shape_name in shape_names:
            for mp in meshes:
                tag = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
                try:
                    row = run_one(arch, shape_name, multi_pod=mp, layout=args.layout, microbatches=args.microbatches, gossip=args.gossip, serve_layout=args.serve_layout)
                    print(
                        f"[ok] {tag}: dominant={row['dominant']} "
                        f"compute={row['compute_s']:.3e}s memory={row['memory_s']:.3e}s "
                        f"collective={row['collective_s']:.3e}s "
                        f"hbm/dev={row['per_device_hbm_gb']:.2f}GB "
                        f"(lower {row['lower_s']}s compile {row['compile_s']}s)"
                    )
                except Exception as e:  # noqa: BLE001 — report and continue
                    row = {
                        "arch": arch, "shape": shape_name,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": f"FAIL: {type(e).__name__}: {e}",
                    }
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
                results.append(row)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(row) + "\n")

    ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\n{ok}/{len(results)} combinations lowered+compiled successfully")
    if ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
