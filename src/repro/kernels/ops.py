"""Public jit'd wrappers around the Pallas kernels.

Handles padding to MXU-aligned block multiples, interpret-mode selection
(interpret=True whenever we are not actually on TPU — this container is
CPU-only, so kernels execute through the Pallas interpreter for
correctness validation), and unpadding of results.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gossip_mix import DEFAULT_BLOCKS, gossip_mix_pallas
from repro.kernels.sparse_gossip import (
    BLOCK_ROWS,
    DEFAULT_BD,
    sparse_gossip_blocked_pallas,
    sparse_gossip_pallas,
)

__all__ = [
    "gossip_mix",
    "gossip_mix_sparse",
    "gossip_mix_sparse_blocked",
    "flash_attention",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bd", "interpret", "block_sparse")
)
def gossip_mix(
    w: jax.Array,
    p: jax.Array,
    *,
    bm: int | None = None,
    bk: int | None = None,
    bd: int | None = None,
    interpret: bool | None = None,
    block_sparse: bool = True,
) -> jax.Array:
    """DecAvg mixing ``W @ P`` via the Pallas kernel.

    w: (N, N) mixing matrix; p: (N, D) node-stacked flat params.
    Pads to block multiples with zeros (zero W rows/cols contribute nothing;
    padded rows of the output are sliced away).
    """
    if interpret is None:
        interpret = not on_tpu()
    bm = bm or DEFAULT_BLOCKS["bm"]
    bk = bk or DEFAULT_BLOCKS["bk"]
    bd = bd or DEFAULT_BLOCKS["bd"]
    n, d = p.shape
    wp = _pad_to(w.astype(jnp.float32), (bm, bk))
    # W must also be padded consistently on the contraction axis.
    rem_k = (-n) % bk
    pp = _pad_to(p, (bk, bd))
    out = gossip_mix_pallas(
        wp, pp, bm=bm, bk=bk, bd=bd, interpret=interpret, block_sparse=block_sparse
    )
    return out[:n, :d]


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def gossip_mix_sparse(
    idx: jax.Array,
    val: jax.Array,
    p: jax.Array,
    *,
    bd: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Sparse DecAvg mixing ``W @ P`` via the Pallas ELL row-gather kernel.

    idx/val: (N, K) ELL neighbor indices + weights (core/sparse.ell_from_csr);
    p: (N, D) node-stacked flat params. Pads D to a block multiple with zeros
    (padded columns are sliced away; padded ELL slots carry weight 0).
    """
    if interpret is None:
        interpret = not on_tpu()
    bd = bd or DEFAULT_BD
    n, d = p.shape
    # Don't over-pad tiny leaves: one block that covers D is enough.
    bd = min(bd, max(128, d))
    pp = _pad_to(p, (n, bd))
    out = sparse_gossip_pallas(idx, val, pp, bd=bd, interpret=interpret)
    return out[:, :d]


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def gossip_mix_sparse_blocked(
    blk_idx: jax.Array,
    blk_val: jax.Array,
    p: jax.Array,
    *,
    bd: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Sparse DecAvg mixing ``W @ P`` via the 8-row-blocked ELL kernel.

    blk_idx/blk_val: blocked-ELL source-block ids + stacked (8, 8) weight
    tiles (core/sparse.block_ell_from_csr); p: (N, D) node-stacked flat
    params. Pads N to the block multiple and D to a bd multiple with zeros
    (padded rows carry weight 0 and are sliced away).
    """
    if interpret is None:
        interpret = not on_tpu()
    bd = bd or DEFAULT_BD
    n, d = p.shape
    bd = min(bd, max(128, d))
    nb = blk_idx.shape[0]
    pp = _pad_to(p, (nb * BLOCK_ROWS, bd))
    out = sparse_gossip_blocked_pallas(blk_idx, blk_val, pp, bd=bd, interpret=interpret)
    return out[:n, :d]


def flash_attention(
    q: jax.Array,   # (B, S, H, hd)
    k: jax.Array,   # (B, T, Hkv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash attention via the Pallas kernel. Pads S/T to block multiples
    (padded key positions are masked by causality: they sit in the future)."""
    if interpret is None:
        interpret = not on_tpu()
    b, s, h, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    pad_s = (-s) % bq
    pad_t = (-t) % bk
    qp = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
    # fold batch x heads
    qf = qp.transpose(0, 2, 1, 3).reshape(b * h, s + pad_s, hd)
    kf = kp.transpose(0, 2, 1, 3).reshape(b * hkv, t + pad_t, hd)
    vf = vp.transpose(0, 2, 1, 3).reshape(b * hkv, t + pad_t, hd)
    out = flash_attention_pallas(
        qf, kf, vf, group=group, causal=causal, window=window,
        bq=bq, bk=bk, interpret=interpret,
    )
    out = out.reshape(b, h, s + pad_s, hd).transpose(0, 2, 1, 3)
    return out[:, :s]
