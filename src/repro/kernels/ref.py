"""Pure-jnp oracles for every Pallas kernel (allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gossip_mix_ref", "flash_attention_ref"]


def gossip_mix_ref(w: jax.Array, p: jax.Array) -> jax.Array:
    """f32-accumulated ``W @ P`` cast back to P's dtype."""
    out = w.astype(jnp.float32) @ p.astype(jnp.float32)
    return out.astype(p.dtype)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Plain softmax attention oracle.

    q: (S, H, D); k, v: (T, Hkv, D) with H a multiple of Hkv (GQA).
    ``window``: sliding-window width (each query attends to the last
    ``window`` keys, inclusive of itself).
    """
    s, h, d = q.shape
    t, hkv, _ = k.shape
    group = h // hkv
    scale = scale if scale is not None else d**-0.5
    qf = q.astype(jnp.float32).reshape(s, hkv, group, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("shgd,thd->hgst", qf, kf) * scale  # (hkv, g, s, t)
    qpos = jnp.arange(s)[:, None] + (t - s)  # queries sit at the cache tail
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hgst,thd->shgd", probs, vf)
    return out.reshape(s, h, d).astype(q.dtype)
