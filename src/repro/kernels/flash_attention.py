"""Pallas TPU flash-attention kernel (causal / sliding-window, GQA).

The model zoo's pure-JAX 2D-tiled attention (models/layers.py) is the
portable implementation; this kernel is the TPU-native hot path: one
(q_block, kv_block) online-softmax tile pipelined through VMEM with the
running (m, l, acc) statistics in scratch, MXU-aligned block shapes.

Layout: q (B*H, S, hd), k/v (B*Hkv, T, hd) — the wrapper (ops.py) folds
batch and heads so the grid is (BH, S/bq, T/bk) with the KV index innermost
(statistics stay resident across the kv loop). GQA is handled by an
explicit head map (BH -> B*Hkv) baked into the index_map.

Causality/window: blocks fully in the future are masked by position; blocks
fully in the past of the window are zero contribution — both are still
visited (grid is static) but their tiles are masked; the block-skip
refinement is a recorded future optimization.

Validated against kernels/ref.py::flash_attention_ref in interpret mode
(tests/test_kernels.py sweeps shapes, GQA ratios, windows and dtypes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, bq, bk, nk,
            scale, causal, window):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale      # (bq, hd)
    k = k_ref[0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bq", "bk", "causal", "window", "interpret", "group"),
)
def flash_attention_pallas(
    q: jax.Array,   # (BH, S, hd)
    k: jax.Array,   # (BHkv, T, hd)
    v: jax.Array,
    *,
    group: int,     # BH / BHkv (GQA ratio)
    causal: bool = True,
    window: int | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, s, hd = q.shape
    bhkv, t, _ = k.shape
    assert bh == bhkv * group
    if s % bq or t % bk:
        raise ValueError(f"S={s} % bq={bq} or T={t} % bk={bk} != 0 (pad in ops.py)")
    nq, nk = s // bq, t // bk
    scale = hd**-0.5

    grid = (bh, nq, nk)
    return pl.pallas_call(
        functools.partial(
            _kernel, bq=bq, bk=bk, nk=nk, scale=scale, causal=causal, window=window
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
