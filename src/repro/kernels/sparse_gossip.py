"""Pallas TPU kernels for sparse (ELL) DecAvg gossip ``C = W @ P``.

Unlike the dense kernel (gossip_mix.py) — which streams (bm, bk) W tiles
through the MXU and merely *skips* zero blocks — these kernels never
materialize W at all. Per-round work and wire volume are O(E * D), the
row-gather analogue of the segment-sum path in core/sparse.py, which both
kernels match allclose (tests/test_sparse.py, tests/test_backend_equivalence.py).

Two layouts, two kernels:

1. **8-row-blocked ELL** (``sparse_gossip_blocked_pallas``) — the real TPU
   path. Rows are grouped into blocks of 8 (the f32 sublane count); the
   layout (core/sparse.block_ell_from_csr) enumerates, per destination
   block, the distinct *source blocks* its rows touch and stores the
   coupling weights as dense (8, 8) tiles stacked to a lane-aligned
   (N, 8*KB) array. The grid is (NB, D/bd, KB); at step (b, j, k) the
   scalar-prefetched index map DMAs the full 8-row slab of source block
   ``blk_idx[b, k]`` — one aligned (8, bd) transfer instead of eight
   (1, bd) row gathers — and the VPU/MXU accumulates the (8, 8) @ (8, bd)
   mini-matmul into an f32 scratch block, flushed at k == KB-1. Every DMA
   and every tile is sublane-packed: (8, bd) P slabs and 8-row weight
   strips, nothing narrower than the hardware's native f32 tile height.

2. **Scalar ELL row-gather** (``sparse_gossip_pallas``) — the original
   per-row kernel, kept as the *interpret-mode fallback*: its grid is
   O(N * K) single-row steps, which on TPU underutilizes the sublanes but
   through the Pallas interpreter (CPU CI) is far cheaper than the blocked
   kernel's denser tile stream. ``kernels/ops.py`` selects the kernel:
   blocked on real TPU, scalar under interpret, override via ``blocked=``.

Scalar prefetch (pltpu.PrefetchScalarGridSpec) is the canonical Pallas
pattern for data-dependent tile addressing: the index array lands in SMEM
before the body runs, so each P block fetch is a regular pipelined DMA.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "sparse_gossip_kernel",
    "sparse_gossip_pallas",
    "sparse_gossip_blocked_kernel",
    "sparse_gossip_blocked_pallas",
    "DEFAULT_BD",
    "BLOCK_ROWS",
]

DEFAULT_BD = 512
BLOCK_ROWS = 8  # f32 sublane count: the row granularity of the blocked kernel


# ---------------------------------------------------------------------------
# 8-row-blocked ELL kernel (TPU sublane packing)
# ---------------------------------------------------------------------------


def sparse_gossip_blocked_kernel(idx_ref, val_ref, p_ref, out_ref, acc_ref, *, nkb: int):
    """One (b, j, k) grid step: acc += W_tile(8, 8) @ P_block(8, bd).

    Refs:
      idx_ref: (NB, KB) int32 scalar-prefetch (SMEM) — consumed by the index
               maps; unused in the body but part of the kernel signature.
      val_ref: (8, 8) f32 VMEM — the weight tile coupling destination block b
               to source block idx_ref[b, k].
      p_ref:   (8, bd) VMEM — the gathered source block's D-slab.
      out_ref: (8, bd) output block, written once per (b, j).
      acc_ref: (8, bd) f32 VMEM scratch accumulator.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        val_ref[...],
        p_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nkb - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def sparse_gossip_blocked_pallas(
    blk_idx: jax.Array,
    blk_val: jax.Array,
    p: jax.Array,
    *,
    bd: int = DEFAULT_BD,
    interpret: bool = False,
) -> jax.Array:
    """Blocked-ELL ``W @ P`` with f32 accumulation.

    blk_idx: (NB, KB) int32 source-block ids; blk_val: (NB*8, KB*8) f32
    stacked weight tiles (core/sparse.block_ell_from_csr). P must be
    pre-padded to NB*8 rows and a D multiple of ``bd`` (the ops.py wrapper
    handles padding/unpadding); padded rows/tiles carry weight 0.
    """
    nb, kb = blk_idx.shape
    n, d = p.shape
    if n != nb * BLOCK_ROWS:
        raise ValueError(f"P rows {n} != {nb} blocks x {BLOCK_ROWS}")
    if blk_val.shape != (nb * BLOCK_ROWS, kb * BLOCK_ROWS):
        raise ValueError(
            f"blk_val {blk_val.shape} != ({nb * BLOCK_ROWS}, {kb * BLOCK_ROWS})"
        )
    if d % bd:
        raise ValueError(f"D={d} must be padded to a multiple of bd={bd}")
    grid = (nb, d // bd, kb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, BLOCK_ROWS), lambda b, j, k, idx_ref: (b, k)),  # lint: allow[P001] — 8x8 weight tile is the ELL block itself; VPU-only, never fed to the MXU
            pl.BlockSpec((BLOCK_ROWS, bd), lambda b, j, k, idx_ref: (idx_ref[b, k], j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, bd), lambda b, j, k, idx_ref: (b, j)),
        scratch_shapes=[pltpu.VMEM((BLOCK_ROWS, bd), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(sparse_gossip_blocked_kernel, nkb=kb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), p.dtype),
        interpret=interpret,
    )(blk_idx, blk_val.astype(jnp.float32), p)


# ---------------------------------------------------------------------------
# Scalar ELL row-gather kernel (interpret-mode fallback)
# ---------------------------------------------------------------------------


def sparse_gossip_kernel(idx_ref, val_ref, p_ref, out_ref, acc_ref, *, nk: int):
    """One (i, j, k) grid step: acc += val[i, k] * P[idx[i, k], j-block].

    Refs:
      idx_ref: (N, K) int32 scalar-prefetch (SMEM) — consumed by index maps;
               unused in the body but part of the kernel signature.
      val_ref: (1, K) f32 VMEM — row i's ELL weights.
      p_ref:   (1, bd) VMEM — the gathered neighbor row's D-block.
      out_ref: (1, bd) output block, written once per (i, j).
      acc_ref: (1, bd) f32 VMEM scratch accumulator.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += val_ref[0, k] * p_ref[...].astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def sparse_gossip_pallas(
    idx: jax.Array,
    val: jax.Array,
    p: jax.Array,
    *,
    bd: int = DEFAULT_BD,
    interpret: bool = False,
) -> jax.Array:
    """ELL ``W @ P`` with f32 accumulation. D must be pre-padded to a
    multiple of ``bd`` (the ops.py wrapper handles padding/unpadding)."""
    n, kmax = idx.shape
    if val.shape != (n, kmax):
        raise ValueError(f"idx {idx.shape} vs val {val.shape} mismatch")
    n2, d = p.shape
    if n2 != n:
        raise ValueError(f"ELL rows {n} != params rows {n2}")
    if d % bd:
        raise ValueError(f"D={d} must be padded to a multiple of bd={bd}")
    grid = (n, d // bd, kmax)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, kmax), lambda i, j, k, idx_ref: (i, 0)),  # lint: allow[P001] — scalar row-gather fallback: interpret-only, no TPU tiling
            pl.BlockSpec((1, bd), lambda i, j, k, idx_ref: (idx_ref[i, k], j)),  # lint: allow[P001] — scalar row-gather fallback: interpret-only, no TPU tiling
        ],
        out_specs=pl.BlockSpec((1, bd), lambda i, j, k, idx_ref: (i, j)),  # lint: allow[P001] — scalar row-gather fallback: interpret-only, no TPU tiling
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(sparse_gossip_kernel, nk=kmax),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), p.dtype),
        interpret=interpret,
    )(idx, val.astype(jnp.float32), p)
