"""Pallas TPU kernel for sparse (ELL) DecAvg gossip ``C = W @ P``.

W arrives ELL-padded: ``idx (N, K) int32`` column indices and ``val (N, K)
f32`` weights, K = max row nnz (padding entries carry weight 0). P is the
(N, D) node-stacked flattened parameter matrix.

Unlike the dense kernel (gossip_mix.py) — which streams (bm, bk) W tiles
through the MXU and merely *skips* zero blocks — this kernel never
materializes W at all. The grid is (N, D/bd, K); at step (i, j, k) the
scalar-prefetched index map DMAs exactly the neighbor row ``idx[i, k]``'s
(1, bd) slice of P into VMEM and the VPU accumulates ``val[i, k] * P[idx[i,
k], j]`` into an f32 scratch row, flushed at k == K-1. Per-round work and
wire volume are O(E * D) — the row-gather analogue of the segment-sum path
in core/sparse.py, which it matches allclose (tests/test_sparse.py).

Scalar prefetch (pltpu.PrefetchScalarGridSpec) is the canonical Pallas
pattern for data-dependent tile addressing: ``idx`` lands in SMEM before the
body runs, so each P block fetch is a regular pipelined DMA. Rows are
processed one at a time ((1, bd) blocks) because neighbor sets differ per
row; at paper scale (N<=4096, K<=~64 for BA/ER) the grid stays small. An
8-row blocked variant with per-row gather DMAs is the obvious TPU follow-up
once sublane-packing matters.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["sparse_gossip_kernel", "sparse_gossip_pallas", "DEFAULT_BD"]

DEFAULT_BD = 512


def sparse_gossip_kernel(idx_ref, val_ref, p_ref, out_ref, acc_ref, *, nk: int):
    """One (i, j, k) grid step: acc += val[i, k] * P[idx[i, k], j-block].

    Refs:
      idx_ref: (N, K) int32 scalar-prefetch (SMEM) — consumed by index maps;
               unused in the body but part of the kernel signature.
      val_ref: (1, K) f32 VMEM — row i's ELL weights.
      p_ref:   (1, bd) VMEM — the gathered neighbor row's D-block.
      out_ref: (1, bd) output block, written once per (i, j).
      acc_ref: (1, bd) f32 VMEM scratch accumulator.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += val_ref[0, k] * p_ref[...].astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def sparse_gossip_pallas(
    idx: jax.Array,
    val: jax.Array,
    p: jax.Array,
    *,
    bd: int = DEFAULT_BD,
    interpret: bool = False,
) -> jax.Array:
    """ELL ``W @ P`` with f32 accumulation. D must be pre-padded to a
    multiple of ``bd`` (the ops.py wrapper handles padding/unpadding)."""
    n, kmax = idx.shape
    if val.shape != (n, kmax):
        raise ValueError(f"idx {idx.shape} vs val {val.shape} mismatch")
    n2, d = p.shape
    if n2 != n:
        raise ValueError(f"ELL rows {n} != params rows {n2}")
    if d % bd:
        raise ValueError(f"D={d} must be padded to a multiple of bd={bd}")
    grid = (n, d // bd, kmax)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, kmax), lambda i, j, k, idx_ref: (i, 0)),
            pl.BlockSpec((1, bd), lambda i, j, k, idx_ref: (idx_ref[i, k], j)),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda i, j, k, idx_ref: (i, j)),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(sparse_gossip_kernel, nk=kmax),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), p.dtype),
        interpret=interpret,
    )(idx, val.astype(jnp.float32), p)
