"""Pallas TPU kernel for the DecAvg gossip mixing step ``C = W @ P``.

W is the (N, N) row-stochastic mixing matrix (f32, tiny — N is the node
count, 100 in the paper), P is the (N, D) node-stacked flattened parameter
matrix (bf16 or f32, D = parameter count, up to hundreds of millions).

TPU adaptation (vs the paper's per-edge Python message loop): the mixing is
a *matmul*, so we feed the MXU with 128-aligned tiles. The working set per
grid step is one (bm, bk) W tile + one (bk, bd) P tile + one (bm, bd) f32
accumulator — sized to sit comfortably in VMEM (~16 MB on v5e):

    bm = bk = 128, bd = 512  ->  128*128*4 + 128*512*2 + 128*512*4 ≈ 0.45 MB

Grid is (M/bm, D/bd, N/bk) with the contraction axis innermost so the
accumulator scratch stays resident across the k-loop. Accumulation is always
f32, independent of P's dtype — consensus averaging in bf16 would bias the
contraction.

The topology is also *sparse* (an ER graph at p=0.05 has ~5% density); the
kernel takes a (M/bm, N/bk) int32 block-mask and skips fully-zero W tiles
(`block_sparse=True`) — a beyond-paper optimization recorded in
EXPERIMENTS.md §Perf. For the paper's N=100 (a single 128-tile) this is
moot, but at cohort scale (N up to 4096 federated silos) an ER topology at
p* has ~0.2% block density and the skip is a ~100x FLOP reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gossip_mix_kernel", "gossip_mix_pallas", "DEFAULT_BLOCKS"]

DEFAULT_BLOCKS = dict(bm=128, bk=128, bd=512)


def gossip_mix_kernel(mask_ref, w_ref, p_ref, out_ref, acc_ref, *, nk: int):
    """One (i, j, k) grid step: acc += W[i,k] @ P[k,j]; flush at k == nk-1.

    Refs:
      mask_ref: (nm, nk) int32 block-support map (SMEM, whole array).
      w_ref:    (bm, bk) f32 mixing tile (VMEM).
      p_ref:    (bk, bd) params tile (VMEM, any float dtype).
      out_ref:  (bm, bd) output tile, written once per (i, j).
      acc_ref:  (bm, bd) f32 VMEM scratch accumulator.
    """
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[i, k] != 0)
    def _accum():
        w = w_ref[...]
        p = p_ref[...].astype(jnp.float32)
        acc_ref[...] += jax.lax.dot_general(
            w, p, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(k == nk - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bd", "interpret", "block_sparse")
)
def gossip_mix_pallas(
    w: jax.Array,
    p: jax.Array,
    *,
    bm: int = 128,
    bk: int = 128,
    bd: int = 512,
    interpret: bool = False,
    block_sparse: bool = True,
) -> jax.Array:
    """``W @ P`` with f32 accumulation. Shapes must be pre-padded to block
    multiples (the ops.py wrapper handles padding/unpadding)."""
    m, n = w.shape
    n2, d = p.shape
    if n != n2:
        raise ValueError(f"contraction mismatch: W {w.shape} vs P {p.shape}")
    if m % bm or n % bk or d % bd:
        raise ValueError(
            f"shapes must be padded to blocks: ({m},{n},{d}) vs ({bm},{bk},{bd})"
        )
    nm, nk, nd = m // bm, n // bk, d // bd
    w = w.astype(jnp.float32)

    if block_sparse:
        # Support map over W tiles; zero tiles contribute nothing and are
        # skipped inside the kernel (the tile is still prefetched by the
        # pipeline, so the win is MXU issue + accumulator traffic, not HBM).
        tiles = w.reshape(nm, bm, nk, bk)
        mask = (jnp.abs(tiles).sum(axis=(1, 3)) > 0).astype(jnp.int32)
    else:
        mask = jnp.ones((nm, nk), dtype=jnp.int32)

    return pl.pallas_call(
        functools.partial(gossip_mix_kernel, nk=nk),
        grid=(nm, nd, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # mask: whole array in SMEM
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), p.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bd), jnp.float32)],
        interpret=interpret,
    )(mask, w, p)
