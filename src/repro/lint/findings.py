"""The Finding record every lint rule emits."""

from __future__ import annotations

import dataclasses

__all__ = ["Finding"]


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str
    hint: str = ""

    def format(self) -> str:
        s = f"{self.path}:{self.line}: {self.rule} {self.message}"
        if self.hint:
            s += f"\n    fix: {self.hint}"
        return s
