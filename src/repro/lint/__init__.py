"""repro.lint — repo-specific static analysis for the contracts tests can't see.

``python -m repro.lint src`` parses every ``.py`` file under the given paths
and runs the AST rules (``repro.lint.astrules``), then the runtime
cross-checks (``repro.lint.contracts``: hash-compat introspection of
``ExperimentSpec`` and the README capability-matrix diff). Exit status 1 on
any finding; each finding prints ``path:line: RULE message`` plus a one-line
fix hint.

Suppress an intentional site with ``# lint: allow[RULE] — reason`` on the
flagged line or the line above; the reason is mandatory (see
``repro.lint.pragmas``).

The rule set (each locked by fixture tests under ``tests/fixtures/lint/``):

=====  ====================================================================
J001   jax.jit constructed inside a loop body (re-traces every iteration)
J002   donate_argnums arg reachable in the return through a no-op view
D001   unseeded RNG: bare default_rng(), np.random globals, stdlib random
D002   wall clock (time.time/datetime.now) in a run path
P001   Pallas BlockSpec block dims off the (8, 128) sublane/lane grid
H001   ExperimentSpec field with a default missing from _HASH_OPTIONAL
C001   README backend matrix drifted from GossipEngine.capabilities()
L001   allow[...] pragma without a reason
E001   file does not parse
=====  ====================================================================
"""

from __future__ import annotations

import os

from repro.lint.findings import Finding

__all__ = ["Finding", "RULES", "lint_source", "lint_paths", "run"]

RULES = {
    "J001": "jit-in-loop: jax.jit constructed inside a loop body",
    "J002": "donation-alias: donated arg reaches an output via a no-op view",
    "D001": "unseeded-rng: RNG draw not derived from the spec seed",
    "D002": "wallclock-in-run-path: time.time()/datetime.now() in src",
    "P001": "pallas-tile-shape: BlockSpec dims off the (8, 128) grid",
    "H001": "hash-compat: spec field default missing from _HASH_OPTIONAL",
    "C001": "capability-drift: README matrix vs GossipEngine.capabilities()",
    "L001": "bare-pragma: allow[...] without a trailing reason",
    "E001": "parse-error: file does not parse",
}


def lint_source(src: str, path: str) -> list[Finding]:
    """AST rules + pragma handling over one file's source text."""
    import ast

    from repro.lint import pragmas
    from repro.lint.astrules import AST_RULES

    lines = src.splitlines()
    allow, findings = pragmas.collect_pragmas(lines, path)
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return findings + [Finding(
            rule="E001", path=path, line=e.lineno or 1,
            message=f"file does not parse: {e.msg}", hint="fix the syntax",
        )]
    raw: list[Finding] = []
    for rule in AST_RULES:
        raw.extend(rule(tree, path, lines))
    return findings + pragmas.suppress(raw, allow)


def _py_files(paths: list[str]) -> list[str]:
    files: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
            files.extend(os.path.join(dirpath, f)
                         for f in sorted(filenames) if f.endswith(".py"))
    return files


def lint_paths(paths: list[str]) -> tuple[int, list[Finding]]:
    """AST-lint every ``.py`` under ``paths`` -> (file count, findings)."""
    findings: list[Finding] = []
    files = _py_files(paths)
    for f in files:
        with open(f, encoding="utf-8") as fh:
            findings.extend(lint_source(fh.read(), f))
    return len(files), findings


def run(paths: list[str], *, root: str = ".", runtime: bool = True) -> tuple[int, list[Finding]]:
    """The full pass the CLI and the tier-1 test both run."""
    nfiles, findings = lint_paths(paths)
    if runtime:
        from repro.lint import contracts

        findings.extend(contracts.check_hash_compat())
        findings.extend(contracts.check_capability_matrix(
            readme_path=os.path.join(root, "README.md")))
    return nfiles, sorted(findings)
