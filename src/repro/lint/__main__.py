"""CLI: ``python -m repro.lint [paths...]`` (default: src)."""

from __future__ import annotations

import argparse
import sys

import repro.lint as lint


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="repo-specific static analysis (see repro.lint.RULES)",
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to lint (default: src)")
    ap.add_argument("--root", default=".",
                    help="repo root for the runtime checks (README.md)")
    ap.add_argument("--no-runtime", action="store_true",
                    help="AST rules only; skip H001/C001 (no jax import)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--write-capmatrix", action="store_true",
                    help="regenerate the README capability matrix and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(lint.RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    if args.write_capmatrix:
        import os

        from repro.lint import contracts

        readme = os.path.join(args.root, "README.md")
        changed = contracts.write_capmatrix(readme)
        print(f"{readme}: {'regenerated' if changed else 'already current'}")
        return 0

    paths = args.paths or ["src"]
    nfiles, findings = lint.run(paths, root=args.root,
                                runtime=not args.no_runtime)
    for f in findings:
        print(f.format())
    nrules = len(lint.RULES) - (2 if args.no_runtime else 0)
    print(f"repro.lint: {nfiles} files, {len(findings)} findings "
          f"({nrules} rules)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
