"""Runtime cross-checks: contracts the AST can't see.

These rules import the live modules and introspect them, so they run once per
lint invocation (not per file):

- **H001 hash-compat** — ``ExperimentSpec.run_id`` is a content hash, and
  every pre-existing JSONL store keys resume/skip-completed on it. A new
  default-valued spec field (or ``model`` dict key) silently rewrites every
  stored run id unless it is registered in ``_HASH_OPTIONAL`` /
  ``_HASH_OPTIONAL_MODEL`` so ``canonical()`` drops it while it holds its
  default. PRs 7 and 8 each re-discovered this by hand; H001 makes the
  registration mechanical: any field outside the shipped baseline must have
  a ``_HASH_OPTIONAL`` entry whose recorded default matches the dataclass
  default, and the default ``ring:n=8`` spec must keep hashing to the pinned
  golden id.
- **C001 capability-drift** — ``decavg._BACKEND_INFO`` declares itself the
  source of truth for ``GossipEngine.capabilities()`` and the README backend
  matrix. C001 regenerates the matrix via ``capability_matrix_lines()`` and
  diffs it against the marker-fenced block in README.md, and cross-checks
  ``trainer._FUSED_BACKENDS`` / ``_LM_FUSED_BACKENDS`` against the
  ``fused`` capability flags.
"""

from __future__ import annotations

import ast
import dataclasses
import inspect
import os
import textwrap

from repro.lint.findings import Finding

__all__ = [
    "GOLDEN_RUN_ID", "check_hash_compat", "check_capability_matrix",
    "capability_matrix_lines", "write_capmatrix", "CAP_BEGIN", "CAP_END",
]

# -- H001 -------------------------------------------------------------------

# ExperimentSpec fields at the moment the store format shipped (PR 2). Their
# values always hash; only fields added *after* this set may (must) be
# registered in _HASH_OPTIONAL so old stores keep their run ids.
_SPEC_BASELINE = frozenset({
    "topology", "partitioner", "partitioner_params", "backend", "matrix",
    "rounds", "eval_every", "lr", "momentum", "local_epochs", "batch_size",
    "gossip_every", "same_init", "seed", "data", "model",
})
# Excluded from the hash by name, not by default-dropping.
_SPEC_NONHASH = frozenset({"tag"})

# ExperimentSpec(topology="ring:n=8").run_id as of PR 9. If this moves, the
# canonicalization changed and every pre-existing store's resume semantics
# broke with it.
GOLDEN_RUN_ID = "ring-iid-s0-c20bcfda"

_PROBE_TOPOLOGY = "ring:n=8"


def _spec_anchor(spec_cls, field_name: str | None = None) -> tuple[str, int]:
    """(path, line) of the class or of one annotated field, best effort."""
    try:
        path = inspect.getsourcefile(spec_cls) or "<spec>"
        path = os.path.relpath(path)
    except Exception:
        path = "<spec>"
    line = 1
    try:
        src_lines, start = inspect.getsourcelines(spec_cls)
        line = start
        if field_name is not None:
            cls_node = ast.parse(textwrap.dedent("".join(src_lines))).body[0]
            for stmt in cls_node.body:
                if (isinstance(stmt, ast.AnnAssign)
                        and isinstance(stmt.target, ast.Name)
                        and stmt.target.id == field_name):
                    line = start + stmt.lineno - 1
                    break
    except Exception:
        pass
    return path, line


def _field_default(f: dataclasses.Field):
    if f.default is not dataclasses.MISSING:
        return f.default
    if f.default_factory is not dataclasses.MISSING:  # type: ignore[misc]
        return f.default_factory()  # type: ignore[misc]
    return dataclasses.MISSING


def check_hash_compat(spec_cls=None, *, golden: str | None = GOLDEN_RUN_ID) -> list[Finding]:
    """H001: every post-baseline default-valued field is hash-optional."""
    if spec_cls is None:
        from repro.experiments.spec import ExperimentSpec as spec_cls

    out: list[Finding] = []
    hash_optional = dict(getattr(spec_cls, "_HASH_OPTIONAL", {}))
    hash_optional_model = dict(getattr(spec_cls, "_HASH_OPTIONAL_MODEL", {}))
    fields = {f.name: f for f in dataclasses.fields(spec_cls)}

    for name, f in sorted(fields.items()):
        if name in _SPEC_BASELINE or name in _SPEC_NONHASH:
            continue
        if name not in hash_optional:
            path, line = _spec_anchor(spec_cls, name)
            out.append(Finding(
                rule="H001", path=path, line=line,
                message=f"spec field {name!r} has a default but no "
                        "_HASH_OPTIONAL entry — adding it rewrites every "
                        "pre-existing store's run ids",
                hint=f"add {{{name!r}: <default>}} to "
                     f"{spec_cls.__name__}._HASH_OPTIONAL",
            ))
            continue
        default = _field_default(f)
        if default is dataclasses.MISSING or default != hash_optional[name]:
            path, line = _spec_anchor(spec_cls, name)
            out.append(Finding(
                rule="H001", path=path, line=line,
                message=f"_HASH_OPTIONAL[{name!r}] == "
                        f"{hash_optional[name]!r} but the dataclass default "
                        f"is {default!r} — default-valued specs would stop "
                        "dropping the field from the hash",
                hint="keep the registered default in lockstep with the "
                     "field default",
            ))

    for name in sorted(hash_optional):
        if name not in fields:
            path, line = _spec_anchor(spec_cls)
            out.append(Finding(
                rule="H001", path=path, line=line,
                message=f"stale _HASH_OPTIONAL entry {name!r}: no such "
                        "spec field",
                hint="remove the entry (removing a *field* needs a store "
                     "migration, not just this edit)",
            ))
        elif name in _SPEC_BASELINE:
            path, line = _spec_anchor(spec_cls, name)
            out.append(Finding(
                rule="H001", path=path, line=line,
                message=f"baseline field {name!r} listed in _HASH_OPTIONAL "
                        "— default-valued runs of it would change their "
                        "pre-existing run ids",
                hint="only fields added after the store format shipped may "
                     "be hash-optional",
            ))

    try:
        probe = spec_cls(topology=_PROBE_TOPOLOGY)
        path, line = _spec_anchor(spec_cls)
        for key, default in sorted(hash_optional_model.items()):
            with_key = spec_cls(topology=_PROBE_TOPOLOGY, model={key: default})
            if with_key.run_id != probe.run_id:
                out.append(Finding(
                    rule="H001", path=path, line=line,
                    message=f"model key {key!r} at its registered default "
                            f"({default!r}) changes run_id — canonical() is "
                            "not dropping it",
                    hint="drop default-valued _HASH_OPTIONAL_MODEL keys in "
                         "canonical() before hashing",
                ))
        if golden is not None and probe.run_id != golden:
            out.append(Finding(
                rule="H001", path=path, line=line,
                message=f"run-id drift: {_PROBE_TOPOLOGY!r} default spec "
                        f"hashes to {probe.run_id!r}, pinned "
                        f"{golden!r} — every pre-existing store just lost "
                        "resume/skip-completed",
                hint="register new default-valued fields in _HASH_OPTIONAL "
                     "instead of letting them into the hash",
            ))
    except Exception as e:  # pragma: no cover - fixture classes may not build
        path, line = _spec_anchor(spec_cls)
        out.append(Finding(
            rule="H001", path=path, line=line,
            message=f"could not construct a probe spec to verify run-id "
                    f"stability: {e}",
            hint="spec classes must be constructible from topology alone",
        ))
    return out


# -- C001 + the capability-matrix emitter -----------------------------------

CAP_BEGIN = ("<!-- capmatrix:begin — generated from "
             "GossipEngine.capabilities(); edit decavg._BACKEND_INFO and run "
             "`python -m repro.lint --write-capmatrix` -->")
CAP_END = "<!-- capmatrix:end -->"


def _md(cell: str) -> str:
    return cell.replace("|", "\\|")


def capability_matrix_lines() -> list[str]:
    """The README backend matrix, generated from the live capability table."""
    from repro.core.decavg import GossipEngine
    from repro.train.trainer import _FUSED_BACKENDS, _LM_FUSED_BACKENDS

    caps = GossipEngine.capabilities()
    lines = [
        "| backend | requires | per-round cost | wire (halo) | fused | "
        "faults | notes |",
        "|---|---|---|---|---|---|---|",
    ]
    for b in GossipEngine.BACKENDS:
        c = caps[b]
        if b in _LM_FUSED_BACKENDS:
            fused = "✓ mlp+lm"
        elif b in _FUSED_BACKENDS:
            fused = "✓ mlp"
        else:
            fused = "—"
        lines.append(
            f"| `{b}` | {_md(c['requires'])} | {_md(c['cost'])} | "
            f"{_md(c.get('wire', '—'))} | {fused} | "
            f"{'✓' if c['faults'] else '—'} | {_md(c.get('notes', ''))} |"
        )
    return lines


def _code_anchor(obj, needle: str) -> tuple[str, int]:
    try:
        path = os.path.relpath(inspect.getsourcefile(obj))
        src = inspect.getsource(inspect.getmodule(obj))
        for i, line in enumerate(src.splitlines(), start=1):
            if needle in line:
                return path, i
        return path, 1
    except Exception:
        return "<module>", 1


def check_capability_matrix(readme_text: str | None = None, *,
                            readme_path: str = "README.md",
                            expected: list[str] | None = None) -> list[Finding]:
    """C001: README matrix block == emitter output; fused tuples consistent."""
    out: list[Finding] = []

    from repro.core import decavg
    from repro.train import trainer

    caps = decavg.GossipEngine.capabilities()
    fused_caps = {b for b, c in caps.items() if c["fused"]}
    if set(trainer._FUSED_BACKENDS) != fused_caps:
        path, line = _code_anchor(trainer, "_FUSED_BACKENDS =")
        out.append(Finding(
            rule="C001", path=path, line=line,
            message=f"_FUSED_BACKENDS {sorted(trainer._FUSED_BACKENDS)} != "
                    f"fused-capable backends {sorted(fused_caps)} from "
                    "capabilities()",
            hint="the fused flag in decavg._BACKEND_INFO is the source of "
                 "truth; mirror it",
        ))
    if not set(trainer._LM_FUSED_BACKENDS) <= set(trainer._FUSED_BACKENDS):
        path, line = _code_anchor(trainer, "_LM_FUSED_BACKENDS =")
        out.append(Finding(
            rule="C001", path=path, line=line,
            message="_LM_FUSED_BACKENDS is not a subset of _FUSED_BACKENDS",
            hint="lm fused staging rides the mlp program staging; keep the "
                 "sets nested",
        ))
    if set(caps) != set(decavg.GossipEngine.BACKENDS):
        path, line = _code_anchor(decavg, "_BACKEND_INFO =")
        out.append(Finding(
            rule="C001", path=path, line=line,
            message="_BACKEND_INFO keys != GossipEngine.BACKENDS",
            hint="every dispatchable backend needs a capability row",
        ))

    if readme_text is None:
        try:
            with open(readme_path, encoding="utf-8") as fh:
                readme_text = fh.read()
        except OSError as e:
            return out + [Finding(
                rule="C001", path=readme_path, line=1,
                message=f"cannot read README for the capability matrix: {e}",
                hint="run from the repo root or pass --root",
            )]

    lines = readme_text.splitlines()
    begin = next((i for i, l in enumerate(lines)
                  if l.strip().startswith("<!-- capmatrix:begin")), None)
    end = next((i for i, l in enumerate(lines) if l.strip() == CAP_END), None)
    if begin is None or end is None or end <= begin:
        out.append(Finding(
            rule="C001", path=readme_path, line=1,
            message="capmatrix markers not found — the backend matrix is "
                    "not under generation",
            hint="fence the table with the capmatrix:begin/end comments and "
                 "run `python -m repro.lint --write-capmatrix`",
        ))
        return out

    block = [l.rstrip() for l in lines[begin + 1:end] if l.strip()]
    want = expected if expected is not None else capability_matrix_lines()
    for j, (got, exp) in enumerate(zip(block, want)):
        if got != exp:
            out.append(Finding(
                rule="C001", path=readme_path, line=begin + 2 + j,
                message="capability matrix drifted from "
                        f"GossipEngine.capabilities(): expected {exp!r}",
                hint="regenerate: python -m repro.lint --write-capmatrix",
            ))
            break
    else:
        if len(block) != len(want):
            out.append(Finding(
                rule="C001", path=readme_path, line=begin + 1,
                message=f"capability matrix has {len(block)} rows, emitter "
                        f"produces {len(want)}",
                hint="regenerate: python -m repro.lint --write-capmatrix",
            ))
    return out


def write_capmatrix(readme_path: str = "README.md") -> bool:
    """Rewrite the fenced README matrix from the emitter. True if changed."""
    with open(readme_path, encoding="utf-8") as fh:
        lines = fh.read().splitlines()
    begin = next((i for i, l in enumerate(lines)
                  if l.strip().startswith("<!-- capmatrix:begin")), None)
    end = next((i for i, l in enumerate(lines) if l.strip() == CAP_END), None)
    if begin is None or end is None or end <= begin:
        raise SystemExit(
            f"{readme_path}: capmatrix:begin/end markers not found; add them "
            "around the backend matrix first"
        )
    new = lines[:begin] + [CAP_BEGIN] + capability_matrix_lines() + lines[end:]
    if new == lines:
        return False
    with open(readme_path, "w", encoding="utf-8") as fh:
        fh.write("\n".join(new) + "\n")
    return True
