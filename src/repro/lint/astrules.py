"""AST rules: pure-syntax checks over one parsed module.

Each rule is a function ``check(tree, path, lines) -> list[Finding]`` and is
registered in ``AST_RULES``. Rules here never import jax/numpy — they must be
cheap enough to run over the whole tree on every CI push. The runtime
cross-checks (H001/C001) live in ``repro.lint.contracts``.

Rule ids and the bug class each one pins:

- **J001 jit-in-loop** — ``jax.jit`` constructed inside a ``for``/``while``
  body. Every loop iteration builds a fresh jit wrapper with an empty compile
  cache, so the function re-traces per iteration (the PR 5 loop-path
  re-jit-per-period bug). Hoist the jit, or cache wrappers in a bounded dict.
- **J002 donation-alias** — an argument listed in ``donate_argnums`` is
  reachable in the function's return value without ever being rebound,
  including through no-op views (``astype`` to the same dtype, ``reshape``,
  ``.T``, ``jnp.asarray``). Donation hands the input buffer to XLA for reuse;
  returning a view of it aliases an output to freed storage (the PR 5
  compress-init bug).
- **D001 unseeded-rng** — ``np.random.default_rng()`` with no seed, global
  ``np.random.*`` state, or stdlib ``random``. Content-hash run ids promise
  that a spec determines its results; any unseeded draw in ``src/`` breaks
  resume/skip-completed semantics silently.
- **D002 wallclock-in-run-path** — ``time.time()`` / ``datetime.now()``
  outside the allowlisted timing sites. Wall clock in a compute path is
  either nondeterminism (if it feeds results) or a benchmark that belongs
  behind ``time.perf_counter()``.
- **P001 pallas-tile-shape** — a ``pl.BlockSpec`` block shape whose trailing
  (lane) dim is not a multiple of 128 or whose second-to-last (sublane) dim
  is not a multiple of 8, where both dims are statically known. Misaligned
  tiles force relayouts on TPU; intentionally-unaligned interpret-only
  kernels suppress with a pragma.
"""

from __future__ import annotations

import ast

from repro.lint.findings import Finding

__all__ = ["AST_RULES"]


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` attribute chain as a string, or None if not a plain chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _jax_aliases(tree: ast.Module) -> tuple[set[str], set[str]]:
    """(names that mean jax.jit, names that mean functools.partial)."""
    jit = {"jax.jit"}
    partial = {"functools.partial"}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax" and a.asname:
                    jit.add(f"{a.asname}.jit")
                if a.name == "functools" and a.asname:
                    partial.add(f"{a.asname}.partial")
        elif isinstance(node, ast.ImportFrom):
            if node.module == "jax":
                for a in node.names:
                    if a.name == "jit":
                        jit.add(a.asname or "jit")
            if node.module == "functools":
                for a in node.names:
                    if a.name == "partial":
                        partial.add(a.asname or "partial")
    return jit, partial


# -- J001 -------------------------------------------------------------------

def check_jit_in_loop(tree: ast.Module, path: str, lines: list[str]) -> list[Finding]:
    jit_names, _ = _jax_aliases(tree)
    out: list[Finding] = []

    class V(ast.NodeVisitor):
        def __init__(self):
            self.loop_depth = 0

        def _loop(self, node):
            self.loop_depth += 1
            self.generic_visit(node)
            self.loop_depth -= 1

        visit_For = visit_While = visit_AsyncFor = _loop

        def _scope(self, node):
            # A def/lambda inside a loop body runs later, with its own cache
            # discipline — reset the counter rather than flagging its body.
            saved, self.loop_depth = self.loop_depth, 0
            self.generic_visit(node)
            self.loop_depth = saved

        visit_FunctionDef = visit_AsyncFunctionDef = visit_Lambda = _scope

        def visit_Call(self, node: ast.Call):
            if self.loop_depth and _dotted(node.func) in jit_names:
                out.append(Finding(
                    rule="J001", path=path, line=node.lineno,
                    message="jax.jit constructed inside a loop body: each "
                            "iteration gets a fresh wrapper and re-traces",
                    hint="hoist the jit out of the loop, or memoize wrappers "
                         "in a bounded cache keyed on the loop variable",
                ))
            self.generic_visit(node)

    V().visit(tree)
    return out


# -- J002 -------------------------------------------------------------------

# obj.method(...) calls that can return a view of obj (no copy guaranteed).
_VIEW_CALL_METHODS = {
    "astype", "reshape", "ravel", "view", "transpose", "swapaxes", "squeeze",
}
# obj.attr views.
_VIEW_ATTRS = {"T", "mT", "real", "imag", "at"}
# free functions f(x, ...) that can return x or a view of it.
_VIEW_FUNCS = {
    "jnp.asarray", "np.asarray", "numpy.asarray", "jax.numpy.asarray",
    "jnp.reshape", "jnp.ravel", "jnp.transpose", "jnp.squeeze",
}


def _alias_reach(node: ast.AST) -> set[str]:
    """Names whose buffer the expression's value may alias (no-copy paths)."""
    if isinstance(node, ast.Name):
        return {node.id}
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out: set[str] = set()
        for e in node.elts:
            out |= _alias_reach(e)
        return out
    if isinstance(node, ast.Dict):
        out = set()
        for e in list(node.keys) + list(node.values):
            if e is not None:
                out |= _alias_reach(e)
        return out
    if isinstance(node, ast.Starred):
        return _alias_reach(node.value)
    if isinstance(node, ast.IfExp):
        return _alias_reach(node.body) | _alias_reach(node.orelse)
    if isinstance(node, ast.Attribute) and node.attr in _VIEW_ATTRS:
        return _alias_reach(node.value)
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr in _VIEW_CALL_METHODS:
            return _alias_reach(f.value)
        if _dotted(f) in _VIEW_FUNCS and node.args:
            return _alias_reach(node.args[0])
    return set()


def _own_body_walk(fn: ast.FunctionDef):
    """Walk a function body without descending into nested defs/lambdas."""
    stack: list[ast.AST] = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _assigned_names(fn: ast.FunctionDef) -> set[str]:
    names: set[str] = set()

    def targets(t):
        if isinstance(t, ast.Name):
            names.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                targets(e)
        elif isinstance(t, ast.Starred):
            targets(t.value)

    for node in _own_body_walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                targets(t)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign, ast.For,
                               ast.AsyncFor)):
            targets(node.target)
        elif isinstance(node, ast.NamedExpr):
            targets(node.target)
        elif isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if item.optional_vars is not None:
                    targets(item.optional_vars)
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                targets(t)
    return names


def _positional_params(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in list(a.posonlyargs) + list(a.args)]


def _donated_literal(kw_value: ast.AST) -> list[int] | None:
    if isinstance(kw_value, ast.Constant) and isinstance(kw_value.value, int):
        return [kw_value.value]
    if isinstance(kw_value, (ast.Tuple, ast.List)):
        out = []
        for e in kw_value.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, int)):
                return None
            out.append(e.value)
        return out
    return None


def _is_staticmethod(fn: ast.FunctionDef) -> bool:
    return any(isinstance(d, ast.Name) and d.id == "staticmethod"
               for d in fn.decorator_list)


def check_donation_alias(tree: ast.Module, path: str, lines: list[str]) -> list[Finding]:
    jit_names, partial_names = _jax_aliases(tree)
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node

    def enclosing(node, kinds):
        n = parents.get(node)
        while n is not None and not isinstance(n, kinds):
            n = parents.get(n)
        return n

    def resolve_name(call: ast.Call, name: str) -> ast.FunctionDef | None:
        """Find ``def name`` in a scope lexically enclosing ``call``."""
        scope = call
        while scope is not None:
            scope = enclosing(scope, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef, ast.Module))
            if scope is None:
                return None
            for stmt in getattr(scope, "body", []):
                if isinstance(stmt, ast.FunctionDef) and stmt.name == name:
                    return stmt
            if isinstance(scope, ast.Module):
                return None

    # (target def, donated indices, offset into def params, report line)
    sites: list[tuple[ast.FunctionDef, list[int], int, int]] = []

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _dotted(node.func) in jit_names:
            donated = next((_donated_literal(kw.value) for kw in node.keywords
                            if kw.arg == "donate_argnums"), None)
            if not donated or not node.args:
                continue
            target = node.args[0]
            if isinstance(target, ast.Name):
                fn = resolve_name(node, target.id)
                if fn is not None:
                    sites.append((fn, donated, 0, node.lineno))
            elif (isinstance(target, ast.Attribute)
                  and isinstance(target.value, ast.Name)
                  and target.value.id in ("self", "cls")):
                cls = enclosing(node, ast.ClassDef)
                if cls is not None:
                    for stmt in cls.body:
                        if (isinstance(stmt, ast.FunctionDef)
                                and stmt.name == target.attr):
                            # a bound method hides self, so jit argnum i is
                            # def param i+1 — unless it's a staticmethod
                            off = 0 if _is_staticmethod(stmt) else 1
                            sites.append((stmt, donated, off, node.lineno))
                            break
        elif isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if (isinstance(dec, ast.Call)
                        and _dotted(dec.func) in partial_names
                        and dec.args and _dotted(dec.args[0]) in jit_names):
                    donated = next((_donated_literal(kw.value)
                                    for kw in dec.keywords
                                    if kw.arg == "donate_argnums"), None)
                    if donated:
                        sites.append((node, donated, 0, node.lineno))

    out: list[Finding] = []
    seen: set[tuple[int, str]] = set()
    for fn, donated, off, _site_line in sites:
        params = _positional_params(fn)
        assigned = _assigned_names(fn)
        watch = {}
        for i in donated:
            j = i + off
            if 0 <= j < len(params) and params[j] not in assigned:
                watch[params[j]] = i
        if not watch:
            continue
        for node in _own_body_walk(fn):
            if isinstance(node, ast.Return) and node.value is not None:
                for name in _alias_reach(node.value) & set(watch):
                    key = (node.lineno, name)
                    if key in seen:
                        continue
                    seen.add(key)
                    out.append(Finding(
                        rule="J002", path=path, line=node.lineno,
                        message=f"donated arg {name!r} (donate_argnums="
                                f"{watch[name]}) reaches the return value "
                                "without being rebound — a no-op view aliases "
                                "the donated buffer into an output",
                        hint="copy before returning (jnp.array(x, copy=True)) "
                             "or rebind the name to the new value",
                    ))
    return out


# -- D001 -------------------------------------------------------------------

_GLOBAL_STATE_FNS = {
    "seed", "rand", "randn", "randint", "random", "random_sample", "sample",
    "ranf", "choice", "shuffle", "permutation", "uniform", "normal",
    "standard_normal", "binomial", "poisson", "beta", "gamma", "exponential",
    "get_state", "set_state", "bytes",
}


def check_unseeded_rng(tree: ast.Module, path: str, lines: list[str]) -> list[Finding]:
    out: list[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "random":
                    out.append(Finding(
                        rule="D001", path=path, line=node.lineno,
                        message="stdlib random uses hidden process-global "
                                "state; draws are unseeded per spec",
                        hint="use np.random.default_rng(seed) streams derived "
                             "from the spec seed",
                    ))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "random":
                out.append(Finding(
                    rule="D001", path=path, line=node.lineno,
                    message="stdlib random uses hidden process-global state",
                    hint="use np.random.default_rng(seed) streams derived "
                         "from the spec seed",
                ))
        elif isinstance(node, ast.Call):
            chain = _dotted(node.func)
            if chain is None:
                continue
            leaf = chain.rsplit(".", 1)[-1]
            if leaf == "default_rng" and not node.args and not node.keywords:
                out.append(Finding(
                    rule="D001", path=path, line=node.lineno,
                    message="default_rng() with no seed draws from OS "
                            "entropy — results stop being a function of the "
                            "spec",
                    hint="pass a seed (or a (seed, stream) tuple) derived "
                         "from the spec",
                ))
            elif (chain.startswith(("np.random.", "numpy.random."))
                  and leaf in _GLOBAL_STATE_FNS):
                out.append(Finding(
                    rule="D001", path=path, line=node.lineno,
                    message=f"np.random.{leaf} mutates/reads numpy's global "
                            "RNG state — any import-order change reshuffles "
                            "results",
                    hint="use an explicit np.random.default_rng(seed) "
                         "Generator instead",
                ))
    return out


# -- D002 -------------------------------------------------------------------

_WALLCLOCK = {
    "time.time", "time.time_ns",
    "datetime.now", "datetime.utcnow", "datetime.today", "date.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}

# Path fragments where wall clock is the product, not a hazard.
_D002_ALLOW_PATHS = ("benchmarks/", "tests/", "examples/")


def check_wallclock(tree: ast.Module, path: str, lines: list[str]) -> list[Finding]:
    norm = path.replace("\\", "/")
    if any(frag in norm for frag in _D002_ALLOW_PATHS):
        return []
    out: list[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _dotted(node.func) in _WALLCLOCK:
            out.append(Finding(
                rule="D002", path=path, line=node.lineno,
                message=f"wall clock ({_dotted(node.func)}) in a run path: "
                        "nondeterministic if it feeds results, wrong clock "
                        "if it measures elapsed time",
                hint="use time.perf_counter() for durations; for intentional "
                     "timestamps add `# lint: allow[D002] — reason`",
            ))
    return out


# -- P001 -------------------------------------------------------------------

_SUBLANE, _LANE = 8, 128


def _module_int_consts(tree: ast.Module) -> dict[str, int]:
    consts: dict[str, int] = {}
    for stmt in tree.body:
        if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
                and isinstance(stmt.value, ast.Constant)
                and isinstance(stmt.value.value, int)):
            consts[stmt.targets[0].id] = stmt.value.value
    return consts


def check_pallas_tile_shape(tree: ast.Module, path: str, lines: list[str]) -> list[Finding]:
    consts = _module_int_consts(tree)

    def dim(e: ast.AST) -> int | None:
        if isinstance(e, ast.Constant) and isinstance(e.value, int):
            return e.value
        if isinstance(e, ast.Name):
            return consts.get(e.id)
        return None

    out: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _dotted(node.func)
        if chain is None or chain.rsplit(".", 1)[-1] != "BlockSpec":
            continue
        if not node.args or not isinstance(node.args[0], ast.Tuple):
            continue
        dims = [dim(e) for e in node.args[0].elts]
        if len(dims) < 2:
            continue
        shape = tuple("?" if d is None else d for d in dims)
        last, sub = dims[-1], dims[-2]
        if last is not None and last % _LANE:
            out.append(Finding(
                rule="P001", path=path, line=node.lineno,
                message=f"BlockSpec block shape {shape}: lane (last) dim "
                        f"{last} is not a multiple of {_LANE}",
                hint=f"pad the trailing block dim to {_LANE}, or suppress for "
                     "an interpret-only kernel",
            ))
        if sub is not None and sub % _SUBLANE:
            out.append(Finding(
                rule="P001", path=path, line=node.lineno,
                message=f"BlockSpec block shape {shape}: sublane "
                        f"(second-to-last) dim {sub} is not a multiple of "
                        f"{_SUBLANE}",
                hint=f"pad the sublane block dim to {_SUBLANE}, or suppress "
                     "for an interpret-only kernel",
            ))
    return out


AST_RULES = (
    check_jit_in_loop,
    check_donation_alias,
    check_unseeded_rng,
    check_wallclock,
    check_pallas_tile_shape,
)
