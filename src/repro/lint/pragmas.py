"""Suppression pragmas: ``# lint: allow[RULE] — reason``.

A pragma suppresses findings for the listed rule ids on its own line and on
the line directly below (so it can trail the flagged statement or sit on its
own line above it). The trailing reason is mandatory — a pragma is a claim
that a flagged site is intentional, and the claim has to say why; a bare
``# lint: allow[D002]`` is itself a finding (**L001 bare-pragma**) and does
not suppress anything.
"""

from __future__ import annotations

import re

from repro.lint.findings import Finding

__all__ = ["collect_pragmas", "suppress"]

_PRAGMA_RE = re.compile(r"#\s*lint:\s*allow\[([A-Za-z0-9_,\s]+)\](.*)")
# Separator punctuation between the closing bracket and the reason text.
_SEP = " \t-—–:"


def collect_pragmas(lines: list[str], path: str) -> tuple[dict[int, set[str]], list[Finding]]:
    """-> ({line_no: allowed rule ids}, L001 findings for bare pragmas)."""
    allow: dict[int, set[str]] = {}
    findings: list[Finding] = []
    for i, line in enumerate(lines, start=1):
        m = _PRAGMA_RE.search(line)
        if not m:
            continue
        rules = {r.strip().upper() for r in m.group(1).split(",") if r.strip()}
        reason = m.group(2).strip(_SEP)
        if len(reason) < 3:
            findings.append(Finding(
                rule="L001", path=path, line=i,
                message="allow[...] pragma without a reason — suppression "
                        "must say why the site is intentional (and this "
                        "pragma suppresses nothing until it does)",
                hint="append ` — <why this site is exempt>` to the pragma",
            ))
            continue
        allow[i] = rules
    return allow, findings


def suppress(findings: list[Finding], allow: dict[int, set[str]]) -> list[Finding]:
    """Drop findings covered by a pragma on their line or the line above."""
    if not allow:
        return findings
    out = []
    for f in findings:
        covered = (f.rule in allow.get(f.line, ()) or
                   f.rule in allow.get(f.line - 1, ()))
        if not covered:
            out.append(f)
    return out
