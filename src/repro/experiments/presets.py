"""Sweep presets: the paper's matrix at three scales.

- ``smoke``: minutes on CPU — 3 topology families, hub/edge splits on BA,
  1 seed. The CI gate and the acceptance check for the harness itself.
- ``paper``: the reproduction matrix (N=100; ER / BA / SBM x iid / hub /
  edge / community x 3 seeds) — the source of the Figure 3 / Table 1
  walkthrough in the README.
- ``large_n``: the ROADMAP scaling item — ws / torus / caveman at N=1024
  on the sparse backend with chunked segment-sum, plus BA at N=4096 on the
  ``sparse_sharded`` backend (per-shard CSR row ranges + halo gathers over
  a mesh of all local devices — the node-sharded sparse path). Few rounds:
  this preset measures spread + wall-clock at scale, not final accuracy.
  Both backends route through ``run_fused``, so each run — including the
  node-sharded N=4096 one, ring halo exchange and all — executes as a
  single compiled program per eval chunk.
- ``large_n_smoke``: tiny-N stand-in for ``large_n`` (same backends, CI
  minutes); the smoke-sweep job gates on its sparse_sharded run staying on
  the fused path.
- ``churn_smoke``: the fault subsystem's CI gate — hub-targeted vs
  leaf-targeted mid-run churn on a small BA graph; analysis must reproduce
  hub-kill >= leaf-kill damage on ``g2_acc_spread``.
- ``lm_smoke``: the LLM-cohort CI gate — tiny transformers, n=4 ring vs
  star vs gossip_every=0 isolation, 2 seeds; gossiped runs must beat
  isolation on ``g2_token_spread`` (analysis.qualitative_checks:
  lm_gossip_spreads). All runs ride the fused lm scan.
"""

from __future__ import annotations

from repro.experiments.spec import ExperimentSpec, expand_grid

__all__ = ["PRESETS", "get_preset"]


def _smoke() -> list[ExperimentSpec]:
    base = {
        "rounds": 10,
        "eval_every": 1,
        "lr": 0.05,
        "momentum": 0.9,
        "batch_size": 8,
        "backend": "dense",
        "data": {"train_per_class": 300, "test_per_class": 50},
        "tag": "smoke",
    }
    specs = expand_grid(
        base,
        topology=["ba:n=16,m=2"],
        partitioner=["hub_focused", "edge_focused"],
        seed=[0],
    )
    specs += expand_grid(
        base,
        topology=["er:n=16,p=0.35", "ws:n=16,k=4,beta=0.2"],
        partitioner=["hub_focused"],
        seed=[0],
    )
    return specs


def _paper() -> list[ExperimentSpec]:
    base = {
        "rounds": 40,
        "eval_every": 2,
        "lr": 0.05,
        "momentum": 0.9,
        "batch_size": 32,
        "backend": "dense",
        "tag": "paper",
    }
    specs = expand_grid(
        base,
        topology=["er:n=100", "ba:n=100,m=2"],
        partitioner=["iid", "hub_focused", "edge_focused"],
        seed=[0, 1, 2],
    )
    specs += expand_grid(
        base,
        topology=["sbm:n=100,blocks=4,p_in=0.5,p_out=0.01"],
        partitioner=["community"],
        seed=[0, 1, 2],
    )
    return specs


def _large_n() -> list[ExperimentSpec]:
    # Narrow member MLPs + sparse gossip with chunked segment-sum sizing:
    # this preset measures spread + wall-clock at scale, so every node still
    # needs >= 1 image per G1 class (train_per_class >= n).
    base = {
        "rounds": 5,
        "eval_every": 1,
        "lr": 0.05,
        "momentum": 0.9,
        "batch_size": 8,
        "backend": "sparse",
        "data": {"train_per_class": 2048, "test_per_class": 100},
        # sparse_p_chunk="auto" bounds the O(nnz*P) gather transient — at
        # n=4096/ba(m=2) the hidden=[64] first layer is otherwise a ~4 GB
        # intermediate per mix.
        "model": {"kind": "mlp", "hidden": [64], "sparse_p_chunk": "auto"},
        "tag": "large_n",
    }
    specs = expand_grid(
        base,
        topology=[
            "ws:n=1024,k=8,beta=0.1",
            "torus:rows=32,cols=32",
            "caveman:cliques=128,size=8",
        ],
        partitioner=["hub_focused", "edge_focused"],
        seed=[0],
    )
    # N=4096 rides the sparse_sharded backend: the engine builds a 1-D mesh
    # over all local devices and shards the CSR's node axis across it
    # (O(E*P/S) work per device; single-device runs degrade gracefully).
    specs += expand_grid(
        {**base, "backend": "sparse_sharded",
         "data": {"train_per_class": 5000, "test_per_class": 100}},
        topology=["ba:n=4096,m=2"],
        partitioner=["hub_focused"],
        seed=[0],
    )
    return specs


def _large_n_smoke() -> list[ExperimentSpec]:
    # Tiny-N stand-in for the large_n preset shapes, runnable in CI minutes:
    # same backends (sparse with chunking, sparse_sharded over the local
    # device mesh) and a @rewire schedule so the fused MixingProgram stages
    # multiple periods. The CI smoke-sweep job asserts the sparse_sharded
    # run's final record has fused=True — the single-compiled-program path
    # cannot silently regress to the per-round loop.
    base = {
        "rounds": 4,
        "eval_every": 2,
        "lr": 0.05,
        "momentum": 0.9,
        "batch_size": 8,
        "backend": "sparse",
        "data": {"train_per_class": 64, "test_per_class": 20},
        "model": {"kind": "mlp", "hidden": [32], "sparse_p_chunk": "auto"},
        "tag": "large_n_smoke",
    }
    specs = expand_grid(
        base,
        topology=["ws:n=32,k=4,beta=0.1"],
        partitioner=["hub_focused"],
        seed=[0],
    )
    specs += expand_grid(
        {**base, "backend": "sparse_sharded"},
        topology=["ba:n=32,m=2@rewire=2"],
        partitioner=["hub_focused"],
        seed=[0],
    )
    return specs


def _churn_smoke() -> list[ExperimentSpec]:
    # The fault subsystem's CI gate: one BA graph, hub-focused G2 data, and
    # a deterministic mid-run kill (p_leave=1, p_join=0) of the top-degree
    # quarter vs the bottom-degree quarter of nodes. Killing the hubs that
    # hold AND route G2 knowledge must damage ``g2_acc_spread`` at least as
    # much as killing leaves — the paper's centrality result under churn
    # (analysis.qualitative_checks: hub_kill_hurts_more). Both runs take the
    # fused path, so the masks ride the single lax.scan end to end.
    base = {
        "rounds": 16,
        "eval_every": 2,
        "lr": 0.05,
        "momentum": 0.9,
        "batch_size": 8,
        "backend": "dense",
        "data": {"train_per_class": 300, "test_per_class": 50},
        "tag": "churn_smoke",
    }
    return expand_grid(
        base,
        topology=["ba:n=16,m=2"],
        partitioner=["hub_focused"],
        faults=[
            "churn:p_leave=1.0,p_join=0.0,frac=0.25,start=8@targeted=hubs",
            "churn:p_leave=1.0,p_join=0.0,frac=0.25,start=8@targeted=leaves",
        ],
        seed=[0, 1],
    )


def _lm_smoke() -> list[ExperimentSpec]:
    # The LLM-cohort CI gate: reduced transformer members on domain-skewed
    # token streams (data/tokens.py), ring vs star gossip vs gossip_every=0
    # isolation over 2 seeds. The gate (analysis.qualitative_checks:
    # lm_gossip_spreads) asserts gossiped cohorts end with higher
    # g2_token_spread — each node's mean true-token probability on *other*
    # nodes' domain tokens — than isolated ones: domain knowledge moved over
    # the edges. All runs take the fused lm scan. compress is pinned off:
    # CHOCO top-k at these tiny horizons injects more reference error than
    # the 60 rounds can average away, which would mask the spread signal.
    base = {
        "rounds": 60,
        "eval_every": 30,
        "lr": 1e-3,
        "backend": "dense",
        "model": {
            "kind": "lm", "nodes": 4, "batch": 2, "seq": 32, "compress": None,
        },
        "tag": "lm_smoke",
    }
    specs = expand_grid(
        base,
        topology=["ring:n=4", "star:n=4"],
        seed=[0, 1],
    )
    specs += expand_grid(
        {**base, "gossip_every": 0},
        topology=["ring:n=4"],
        seed=[0, 1],
    )
    return specs


PRESETS = {
    "smoke": _smoke,
    "paper": _paper,
    "large_n": _large_n,
    "large_n_smoke": _large_n_smoke,
    "churn_smoke": _churn_smoke,
    "lm_smoke": _lm_smoke,
}


def get_preset(name: str) -> list[ExperimentSpec]:
    if name not in PRESETS:
        raise ValueError(f"unknown preset {name!r}; one of {sorted(PRESETS)}")
    return PRESETS[name]()
