"""Serve-eval: does topology-aware routing beat topology-blind serving?

Trains a small LM cohort with gossip on a hub topology, checkpoints it,
reloads it through the serving stack (params-only restore -> CohortRouter),
and replays a stream of domain-tagged queries under each routing policy:

- ``best``          — coverage-table argmax (the topology-aware router)
- ``round_robin``   — topology-blind baseline every serving system has
- ``best_foreign``  — "best" with the query's domain OWNER excluded: the
  owner is busy/offline, so the router must know who ELSE absorbed that
  domain through gossip. On a star that is the hub — the paper's hub/leaf
  knowledge asymmetry showing up as a serving-quality delta.

Serve accuracy is the trainer's ``domain_acc`` quantity (mean true-next-token
probability of the routed node's model on the query), measured on held-out
query streams (``query_round=1``; the router's coverage table is built on
stream 0 — the router never sees the eval queries).

Run via ``benchmarks/bench_serve.py`` (writes BENCH_serve.json; CI-guarded:
best > round_robin) or standalone::

    python -m repro.experiments.serve_eval --store results/serve_eval.jsonl
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Any

import jax.numpy as jnp
import numpy as np

__all__ = ["run_serve_eval"]


def run_serve_eval(
    *,
    topology: str = "star:n=6",
    nodes: int = 6,
    rounds: int = 200,
    batch: int = 2,
    seq: int = 32,
    arch: str = "llama3.2-1b",
    seed: int = 0,
    lr: float = 3e-3,
    gossip_every: int = 8,
    domain_frac: float = 0.6,
    queries_per_domain: int = 4,
    store_path: str | None = None,
    ckpt_path: str | None = None,
    verbose: bool = False,
) -> dict[str, Any]:
    """Train -> checkpoint -> route -> score. Returns the summary record."""
    from repro.configs import base as cfgbase
    from repro.data import tokens as tok
    from repro.serve.router import CohortRouter, _coverage
    from repro.train.trainer import LMCohortTrainer

    cfg = cfgbase.get(arch)
    cfg = dataclasses.replace(cfg.reduced(), param_dtype="float32", optimizer=cfg.optimizer)

    # Sparse gossip (every 8 rounds by default) keeps nodes specialized —
    # every-round DecAvg on a star converges the cohort to consensus, and a
    # homogeneous cohort has nothing for a router to exploit. At this cadence
    # the coverage table shows the paper's structure: diagonal dominance
    # (own-domain mastery) + a hub row that dominates FOREIGN domains.
    trainer = LMCohortTrainer(
        topology, cfg, nodes=nodes, batch=batch, seq=seq, lr=lr,
        backend="dense", compress=None, seed=seed, gossip_every=gossip_every,
        data_kwargs={"domain_frac": domain_frac},
    )
    run = trainer.run_fused if trainer.supports_fused else trainer.run
    run(rounds, eval_every=rounds, verbose=verbose)

    tmp = None
    if ckpt_path is None:
        tmp = tempfile.mkdtemp(prefix="serve_eval_")
        ckpt_path = os.path.join(tmp, "cohort.npz")
    trainer.save(ckpt_path, step=rounds)

    # Serving side: params-only load + coverage table (query stream 0).
    router = CohortRouter.from_checkpoint(ckpt_path, cfg, nodes=nodes, seed=seed)

    # Held-out query stream (query_round=1) and its exact (node, domain)
    # accuracy table — every policy is scored from the same measurements.
    qt, ql = zip(
        *(
            tok.domain_query_batch(
                j, queries_per_domain, seq, cfg.vocab_size, seed=seed, query_round=1
            )
            for j in range(nodes)
        )
    )
    acc = np.asarray(
        _coverage(router.params, cfg, jnp.asarray(np.stack(qt)), jnp.asarray(np.stack(ql)))
    )  # acc[node, domain] on HELD-OUT queries

    # Replay a shuffled query stream (domains arrive in arbitrary order, as
    # they would from real traffic — a domain-ordered replay would hand
    # round-robin an accidental perfect alignment). Classification uses the
    # query tokens themselves; scoring uses the measured accuracy table.
    rng = np.random.default_rng(seed + 1)
    stream = rng.permutation(np.repeat(np.arange(nodes), queries_per_domain))
    picks: dict[str, list[int]] = {"best": [], "round_robin": [], "best_foreign": []}
    scores: dict[str, list[float]] = {k: [] for k in picks}
    for i, j in enumerate(stream):
        q = qt[j][i % queries_per_domain]
        for pol, kw in (
            ("best", {"route": "best"}),
            ("round_robin", {"route": "round_robin"}),
            ("best_foreign", {"route": "best", "exclude": (int(j),)}),
        ):
            n = router.route(q, **kw)
            picks[pol].append(n)
            scores[pol].append(float(acc[n, j]))
    serve_acc = {pol: float(np.mean(s)) for pol, s in scores.items()}
    # On a star (node 0 = hub), how often does owner-excluded routing pick
    # the hub? The paper's "hubs absorb G2" claim, read off the router.
    hub_share = float(np.mean([n == 0 for n in picks["best_foreign"]]))

    summary = {
        "kind": "serve_eval",
        "topology": topology,
        "nodes": nodes,
        "rounds": rounds,
        "arch": cfg.arch_id,
        "seed": seed,
        "serve_acc": {k: round(v, 6) for k, v in serve_acc.items()},
        "routed": picks,
        "hub_share_foreign": hub_share,
        "g2_token_spread": trainer.domain_metrics().get("g2_token_spread"),
        "checks": {
            "router_beats_round_robin": serve_acc["best"] > serve_acc["round_robin"],
        },
    }
    if store_path:
        from repro.experiments.store import ResultsStore

        store = ResultsStore(store_path)
        run_id = f"serve_eval-{topology}-s{seed}"
        store.run_start(run_id, {"kind": "serve_eval", "topology": topology,
                                 "nodes": nodes, "rounds": rounds, "seed": seed})
        store.run_end(run_id, "completed", final=summary)
    return summary


def main(argv=None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--topology", default="star:n=6")
    ap.add_argument("--nodes", type=int, default=6)
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store", default=None)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    summary = run_serve_eval(
        topology=args.topology, nodes=args.nodes, rounds=args.rounds,
        seed=args.seed, store_path=args.store, verbose=args.verbose,
    )
    print(json.dumps(summary, indent=2, default=str))
    return 0 if summary["checks"]["router_beats_round_robin"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
