"""Sweep CLI: run a preset (or a spec-grid JSON file) through the runner.

    python -m repro.experiments.sweep --preset smoke
    python -m repro.experiments.sweep --preset paper --processes 4
    python -m repro.experiments.sweep --specs my_grid.json --store results/my.jsonl

Re-running the same command is idempotent: completed runs (matched by the
spec content hash) are skipped; pass --fresh to re-run everything. After the
runs, the analysis join prints the headline tables and writes the
machine-readable summary (--bench-out, default BENCH_sweep.json).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.experiments import analysis, presets, runner
from repro.experiments.spec import ExperimentSpec
from repro.experiments.store import ResultsStore


def _load_specs(args: argparse.Namespace) -> list[ExperimentSpec]:
    if args.specs:
        with open(args.specs) as f:
            return [ExperimentSpec.from_json(d) for d in json.load(f)]
    return presets.get_preset(args.preset)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.experiments.sweep", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--preset", default="smoke", choices=sorted(presets.PRESETS),
                    help="experiment matrix to run (default: smoke)")
    ap.add_argument("--specs", default="",
                    help="JSON file with a list of ExperimentSpec dicts "
                         "(overrides --preset)")
    ap.add_argument("--store", default="",
                    help="results JSONL path (default: results/sweep_<preset>.jsonl)")
    ap.add_argument("--processes", type=int, default=1,
                    help="fan specs out over N worker processes")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore completed runs in the store (no resume)")
    ap.add_argument("--bench-out", default="BENCH_sweep.json",
                    help="machine-readable summary path ('' to skip)")
    ap.add_argument("--list", action="store_true",
                    help="print the expanded run list and exit")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    specs = _load_specs(args)
    if args.list:
        for s in specs:
            print(f"{s.run_id}  {s.topology}  {s.partitioner}  seed={s.seed}")
        return 0

    # Custom spec files get their own store + label, never the preset's.
    matrix_name = (
        os.path.splitext(os.path.basename(args.specs))[0] if args.specs
        else args.preset
    )
    store_path = args.store or f"results/sweep_{matrix_name}.jsonl"
    verbose = not args.quiet
    summary = runner.run_sweep(
        specs, store_path, resume=not args.fresh,
        processes=args.processes, verbose=verbose,
    )
    print(
        f"sweep done: {summary['ran']} ran, {summary['skipped']} skipped "
        f"(resume), {len(summary['failed'])} failed -> {summary['store']}"
    )
    for rid in summary["failed"]:
        print(f"  FAILED: {rid}")

    store = ResultsStore(store_path)
    rows = analysis.summarize(store)
    if verbose:
        print()
        print(analysis.render_tables(rows))
    if args.bench_out:
        bench = analysis.write_bench(
            store, args.bench_out, rows=rows, extra={"preset": matrix_name}
        )
        print(f"\nwrote {args.bench_out} ({bench['runs']} runs)")
    return 1 if summary["failed"] else 0


if __name__ == "__main__":
    sys.exit(main())
