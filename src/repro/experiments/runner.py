"""Executes ExperimentSpecs and streams per-round records to a ResultsStore.

Two executors, dispatched on ``spec.model["kind"]``:

- ``mlp`` (default): the paper-faithful path — synthetic MNIST-like data,
  graph-aware partitioners, ``DecentralizedTrainer``. Streams per round:
  per-node accuracy stats, G1/G2 class-group accuracy (overall, on the focus
  nodes holding G2 data, and on the *spread* nodes that never saw G2 — the
  paper's knowledge-spread quantity), consensus distance ||theta_i - theta_bar||
  and wall-clock. Runs through the fused single-``lax.scan`` trainer path
  (``run_fused``) whenever the resolved backend supports it; set
  ``model={"fused": False}`` to force the per-round Python loop.
- ``lm``: LLM cohorts via ``LMCohortTrainer`` — transformer members on
  domain-skewed token streams, AdamW/SGD + LR schedule, per-round
  ``domain_acc`` / ``g2_token_spread`` knowledge-spread metrics. Takes the
  same fused single-scan path by default (``model={"fused": False}`` opts
  out), defaults CHOCO ``compress=`` on for multi-megabyte members, and
  checkpoints ``(params, opt, step)`` with ``model={"resume": True}``
  restoring bit-identically. ``launch/train.py`` is a thin CLI wrapper
  building one such spec.

``run_sweep`` adds skip-completed resume (a spec whose run_id already has a
completed ``run_end`` in the store is skipped) and optional multi-process
fan-out over specs: each worker writes a private JSONL shard which the parent
merges into the main store, so the store never sees interleaved writers.
"""

from __future__ import annotations

import os
import time
import traceback
from typing import Any, Callable

import numpy as np

from repro.experiments.spec import ExperimentSpec
from repro.experiments.store import ResultsStore

__all__ = ["run_spec", "run_sweep", "build_partition", "default_class_groups"]

Emit = Callable[[dict[str, Any]], None]


# ---------------------------------------------------------------------------
# mlp executor (the paper's reproduction path)
# ---------------------------------------------------------------------------


def default_class_groups(num_classes: int) -> np.ndarray:
    """Paper split: lower half of the classes is G1 (everyone), upper half G2."""
    g = np.zeros(num_classes, dtype=np.int32)
    g[num_classes // 2 :] = 1
    return g


def build_partition(spec: ExperimentSpec, g, labels: np.ndarray) -> list[np.ndarray]:
    """Dispatch spec.partitioner over core/partition.py with the realized graph."""
    from repro.core import partition as P

    kw = dict(spec.partitioner_params)
    n = g.num_nodes
    if spec.partitioner == "iid":
        return P.iid(labels, n, seed=spec.seed, **kw)
    if spec.partitioner == "hub_focused":
        return P.hub_focused(labels, g, seed=spec.seed, **kw)
    if spec.partitioner == "edge_focused":
        return P.edge_focused(labels, g, seed=spec.seed, **kw)
    if spec.partitioner == "community":
        return P.community(labels, g, seed=spec.seed, **kw)
    if spec.partitioner == "dirichlet":
        kw.setdefault("beta", 0.5)
        return P.dirichlet(labels, n, seed=spec.seed, **kw)
    raise ValueError(f"unknown partitioner {spec.partitioner!r}")


def _graph_record(g, w: np.ndarray) -> dict[str, Any]:
    """graph_summary + spectral gap of the realized W (exact up to N=1024)."""
    from repro.core import mixing, topology

    rec = topology.graph_summary(g)
    rec["spectral_gap"] = (
        mixing.spectral_gap(np.asarray(w)) if g.num_nodes <= 1024 else None
    )
    return rec


_MAX_GRAPH_PERIODS = 32


def _graph_records(engine, rounds: int) -> dict[str, Any]:
    """Graph summaries for every schedule period the run realized.

    A ``@regen``/``@rewire`` run visits several graphs; summarizing only
    ``graph_at(0)`` would report period-0 modularity/spectral-gap as if they
    described the whole run. Returns ``graph`` (the period-0 record, labeled
    with ``period=0``) plus, for multi-period runs, ``graph_periods``
    (per-period records) and ``graph_mean`` (numeric fields averaged over
    the recorded periods — the value the analysis join regresses against).

    Each record costs a W rebuild plus (at N <= 1024) an O(N^3) spectral-gap
    eigensolve, so runs realizing more than ``_MAX_GRAPH_PERIODS`` periods
    (e.g. ``@regen=1`` over hundreds of rounds) are summarized on an evenly
    spaced sample of periods — ``graph_num_periods`` always reports the true
    count, and ``graph_periods_sampled`` flags the subsetting.
    """
    first_round: dict[int, int] = {}
    for r in range(max(int(rounds), 1)):
        p = engine.schedule.period_of(r)
        first_round.setdefault(p, r)
    periods = sorted(first_round)
    num_periods = len(periods)
    sampled = num_periods > _MAX_GRAPH_PERIODS
    if sampled:
        pick = np.linspace(0, num_periods - 1, _MAX_GRAPH_PERIODS).round()
        periods = [periods[int(i)] for i in np.unique(pick)]
    recs = []
    for p in periods:
        rec = _graph_record(engine.graph_at(first_round[p]), np.asarray(engine.w))
        rec["period"] = p
        recs.append(rec)
    out: dict[str, Any] = {"graph": recs[0], "graph_num_periods": num_periods}
    if len(recs) > 1:
        out["graph_periods"] = recs
        if sampled:
            out["graph_periods_sampled"] = True
        out["graph_mean"] = {
            k: float(np.mean([r[k] for r in recs]))
            for k, v in recs[0].items()
            if k != "period"
            and isinstance(v, (int, float)) and not isinstance(v, bool)
            and all(isinstance(r.get(k), (int, float)) for r in recs)
        }
    return out


def _run_mlp(spec: ExperimentSpec, emit: Emit, verbose: bool) -> dict[str, Any]:
    from repro.core import topology
    from repro.data.loader import NodeLoader
    from repro.data.synthetic import make_mnist_like
    from repro.train import metrics as M
    from repro.train.trainer import DecentralizedTrainer

    ds = make_mnist_like(**spec.data)
    schedule = topology.make_schedule(spec.topology, seed=spec.seed)
    g0 = schedule.graph_at(0)
    parts = build_partition(spec, g0, ds.y_train)

    from repro.core.partition import partition_summary

    num_classes = ds.num_classes
    groups = default_class_groups(num_classes)
    summ = partition_summary(ds.y_train, parts)
    g2_cols = np.flatnonzero(groups == 1)
    holds_g2 = summ[:, g2_cols].sum(axis=1) > 0
    focus_nodes = np.flatnonzero(holds_g2)
    spread_nodes = np.flatnonzero(~holds_g2)

    loader = NodeLoader(
        ds.x_train, ds.y_train, parts, batch_size=spec.batch_size, seed=spec.seed + 1
    )
    extra: dict[str, Any] = {}
    if "hidden" in spec.model:
        # Narrower member MLPs for large-N sweeps (the paper's 512-256-128
        # stack x 4096 nodes is GBs of node-stacked params).
        from repro.models.mlp import init_mlp

        hidden = tuple(spec.model["hidden"])
        in_dim = int(spec.model.get("in_dim", ds.x_train.shape[1]))
        extra["init_fn"] = lambda k: init_mlp(
            k, in_dim=in_dim, hidden=hidden, num_classes=num_classes
        )
    trainer = DecentralizedTrainer(
        schedule,
        loader,
        lr=spec.lr,
        momentum=spec.momentum,
        local_epochs=spec.local_epochs,
        mix_impl=spec.backend,
        matrix=spec.matrix,
        sparse_p_chunk=spec.model.get("sparse_p_chunk"),
        gossip_every=spec.gossip_every,
        compress=spec.model.get("compress"),
        faults=spec.faults,
        same_init=spec.same_init,
        seed=spec.seed,
        num_classes=num_classes,
        class_groups=groups,
        **extra,
    )
    fault_trace = None
    if trainer.faulted:
        fault_trace = trainer.engine.fault_trace
        fault_trace.ensure(spec.rounds)
    last: dict[str, Any] = {}
    curve: list[tuple[int, float | None]] = []  # (round, g2_acc_spread) evals

    def on_round(m) -> None:
        rec: dict[str, Any] = {
            "round": m.round,
            "mean_acc": m.mean_acc,
            "std_acc": m.std_acc,
            "min_acc": float(m.per_node_acc.min()),
            "max_acc": float(m.per_node_acc.max()),
            "g1_acc": float(m.group_acc[:, 0].mean()),
            "g2_acc": float(m.group_acc[:, 1].mean()),
            "g2_acc_focus": (
                float(m.group_acc[focus_nodes, 1].mean()) if len(focus_nodes) else None
            ),
            "g2_acc_spread": (
                float(m.group_acc[spread_nodes, 1].mean()) if len(spread_nodes) else None
            ),
            "consensus_mean": float(m.consensus.mean()),
            "consensus_max": float(m.consensus.max()),
            "wall_s": round(m.wall_s, 4),
        }
        if fault_trace is not None:
            rec["alive_count"] = int(fault_trace.alive(m.round).sum())
        curve.append((m.round, rec["g2_acc_spread"]))
        last.clear()
        last.update(rec)
        emit(rec)
        if verbose:
            print(
                f"    round {m.round:4d}  acc {m.mean_acc:.4f}  "
                f"g2_spread {rec['g2_acc_spread']}  cons {rec['consensus_mean']:.3g}"
            )

    # Fused single-scan path by default for the backends that support it
    # (dense/sparse/sparse_pallas/sparse_sharded after "auto" resolution):
    # one device dispatch per eval instead of one per round — for
    # sparse_sharded the ring halo exchange runs inside the scan, so the
    # whole run is one compiled SPMD program per chunk. model={"fused":
    # False} opts a spec out (debugging, or backends the MixingProgram
    # can't stage).
    use_fused = bool(spec.model.get("fused", True)) and trainer.supports_fused
    run = trainer.run_fused if use_fused else trainer.run
    run(
        spec.rounds,
        eval_every=spec.eval_every,
        x_test=ds.x_test,
        y_test=ds.y_test,
        on_round=on_round,
    )

    final: dict[str, Any] = {
        **last,
        # Per-period summaries, computed after the run so @regen/@rewire
        # records cover every realized graph, not just graph_at(0).
        **_graph_records(trainer.engine, spec.rounds),
        "num_focus_nodes": int(len(focus_nodes)),
        "num_spread_nodes": int(len(spread_nodes)),
        # Routing provenance, CI-gated: the large_n smoke asserts its
        # sparse_sharded run actually took the fused path.
        "backend": trainer.mix_impl,
        "fused": use_fused,
    }
    if fault_trace is not None:
        from repro.core import faults as faults_mod

        alive_counts = [
            int(fault_trace.alive(r).sum()) for r in range(spec.rounds)
        ]
        events = faults_mod.churn_rounds(alive_counts, trainer.num_nodes)
        final["faults"] = spec.faults
        final["alive_min"] = min(alive_counts)
        final["alive_final"] = alive_counts[-1]
        final["churn_rounds"] = events
        final["recovery_rounds"] = (
            faults_mod.recovery_rounds(
                [r for r, _ in curve], [a for _, a in curve], events[0]
            )
            if events
            else None
        )
    # Community runs additionally record the paper's Table-1 confusion view.
    if trainer.graph.blocks is not None and trainer.graph.num_nodes <= 256:
        from repro.train.metrics import community_confusion

        cms = trainer.confusion(ds.x_test, ds.y_test)
        blocks = trainer.graph.blocks
        num_comms = int(blocks.max()) + 1
        comm_cm = np.asarray(
            community_confusion(cms, np.asarray(blocks), num_comms)
        )
        off_diag = comm_cm.copy()
        for b in range(num_comms):
            np.fill_diagonal(off_diag[b], 0.0)
        final["community_confusion_offdiag"] = [
            float(off_diag[b].sum()) for b in range(num_comms)
        ]
        if comm_cm.size <= 1000:
            final["community_confusion"] = comm_cm.round(4).tolist()
    return final


# ---------------------------------------------------------------------------
# lm executor (LLM-cohort loop; launch/train.py wraps this)
# ---------------------------------------------------------------------------


def _run_lm(spec: ExperimentSpec, emit: Emit, verbose: bool) -> dict[str, Any]:
    import dataclasses as _dc

    from repro.configs import base as cfgbase
    from repro.train.trainer import LMCohortTrainer

    m = spec.model
    cfg = cfgbase.get(m.get("arch", "llama3.2-1b"))
    if not m.get("full_scale", False):
        cfg = _dc.replace(cfg.reduced(), param_dtype="float32", optimizer=cfg.optimizer)
    n = int(m.get("nodes", 4))

    trainer = LMCohortTrainer(
        spec.topology,
        cfg,
        nodes=n,
        batch=int(m.get("batch", 4)),
        seq=int(m.get("seq", 128)),
        lr=spec.lr,
        schedule=m.get("schedule", "cosine"),
        backend=spec.backend,
        matrix=spec.matrix,
        gossip_every=spec.gossip_every,
        compress=m.get("compress", "auto"),
        faults=spec.faults,
        seed=spec.seed,
    )
    if verbose:
        print(
            f"arch={cfg.arch_id} members={trainer.member_params/1e6:.1f}M x {n} nodes "
            f"topology={trainer.graph.name} backend={trainer.mix_impl} "
            f"optimizer={cfg.optimizer} schedule={m.get('schedule', 'cosine')} "
            f"compress={trainer.compress}"
        )

    ckpt_every, ckpt_path = int(m.get("ckpt_every", 0)), m.get("ckpt_path", "")
    if m.get("resume") and ckpt_path:
        start = trainer.restore(ckpt_path)
        if verbose:
            print(f"resumed from {ckpt_path} at round {start}")

    last: dict[str, Any] = {}

    def on_round(rec: dict[str, Any]) -> None:
        last.clear()
        last.update(rec)
        emit(rec)

    # Fused MixingProgram-staged scan by default, mirroring _run_mlp:
    # one dispatch per eval/checkpoint boundary with the chunk's token slab
    # staged on device. model={"fused": False} opts out; backends outside
    # _LM_FUSED_BACKENDS (e.g. sparse_sharded) fall back to the loop.
    use_fused = bool(m.get("fused", True)) and trainer.supports_fused
    run = trainer.run_fused if use_fused else trainer.run
    run(
        spec.rounds,
        eval_every=spec.eval_every,
        on_round=on_round,
        ckpt_every=ckpt_every,
        ckpt_path=ckpt_path,
        verbose=verbose,
    )
    cons = trainer.consensus()
    final: dict[str, Any] = {
        **last,
        # (0,) for an empty pytree — no nodes, so no distance to report
        "consensus_mean": float(cons.mean()) if cons.size else 0.0,
        "consensus_max": float(cons.max()) if cons.size else 0.0,
        **_graph_records(trainer.engine, spec.rounds),
        "members_m": round(trainer.member_params / 1e6, 2),
        "backend": trainer.mix_impl,
        "fused": use_fused,
        "compress": trainer.compress,
    }
    if trainer.faulted:
        trace = trainer.engine.fault_trace
        alive_counts = [int(trace.alive(r).sum()) for r in range(spec.rounds)]
        final["faults"] = spec.faults
        final["alive_min"] = min(alive_counts)
        final["alive_final"] = alive_counts[-1]
    return final


_EXECUTORS = {"mlp": _run_mlp, "lm": _run_lm}


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def run_spec(
    spec: ExperimentSpec,
    store: ResultsStore,
    *,
    verbose: bool = False,
    raise_on_error: bool = True,
) -> dict[str, Any]:
    """Execute one spec, streaming records to ``store``. Returns the final
    summary (also written as the ``run_end`` record)."""
    rid = spec.run_id
    store.run_start(rid, spec.to_json())
    executor = _EXECUTORS[spec.model.get("kind", "mlp")]
    t0 = time.perf_counter()
    try:
        final = executor(spec, lambda rec: store.round(rid, rec), verbose)
    except Exception as e:  # noqa: BLE001 — sweep must survive one bad spec
        store.run_end(rid, "failed", error=f"{type(e).__name__}: {e}")
        if raise_on_error:
            raise
        if verbose:
            traceback.print_exc()
        return {"status": "failed", "run_id": rid, "error": str(e)}
    store.run_end(rid, "completed", wall_s=round(time.perf_counter() - t0, 4),
                  final=final)
    return {"status": "completed", "run_id": rid, "final": final}


def _worker(args: tuple[dict[str, Any], str, bool]) -> str:
    """Multi-process entry: run one spec into a private JSONL shard."""
    spec_json, shard_path, verbose = args
    spec = ExperimentSpec.from_json(spec_json)
    run_spec(spec, ResultsStore(shard_path), verbose=verbose, raise_on_error=False)
    return shard_path


def _merge_shard(store: ResultsStore, shard: str) -> None:
    with open(shard) as f:
        store.append_lines(f)
    os.remove(shard)


def _salvage_shards(
    store: ResultsStore, shard_dir: str, verbose: bool, *, min_age_s: float = 0.0
) -> int:
    """Merge + remove shard files a dead worker (or killed parent) left in
    ``shard_dir``, then drop the directory.

    Salvaged partial shards lack their ``run_end`` line, so resume re-runs
    them; complete shards whose merge was interrupted count as completed and
    are skipped. Called before a sweep (stale shards from a previous crash,
    with ``min_age_s`` so a *concurrent* sweep's in-flight shards are left
    alone) and after this sweep's own pool has shut down (age 0: its workers
    are gone, every surviving file is quiescent)."""
    if not os.path.isdir(shard_dir):
        return 0
    import glob

    salvaged = 0
    for shard in sorted(glob.glob(os.path.join(shard_dir, "*.jsonl"))):
        try:
            if min_age_s and time.time() - os.path.getmtime(shard) < min_age_s:  # lint: allow[D002] — shard age vs file mtime needs the wall clock
                continue  # likely still being written by a live sweep
            _merge_shard(store, shard)
            salvaged += 1
        except FileNotFoundError:
            continue  # another sweep salvaged it between glob and merge
    try:
        os.rmdir(shard_dir)
    except OSError:
        pass  # a concurrent sweep may still be writing here; leave it
    if verbose and salvaged:
        print(f"salvaged {salvaged} stale shard(s) from {shard_dir}")
    return salvaged


def run_sweep(
    specs: list[ExperimentSpec],
    store_path: str,
    *,
    resume: bool = True,
    processes: int = 1,
    verbose: bool = False,
) -> dict[str, Any]:
    """Run a list of specs against one results store.

    With ``resume`` (default), specs whose run_id already has a completed
    run_end are skipped — re-running a finished sweep is a no-op. With
    ``processes > 1``, specs fan out over a spawn-context process pool; each
    worker writes a private shard merged into the main store on completion.
    """
    store = ResultsStore(store_path)
    shard_dir = store_path + ".shards"
    # A previous sweep's crash; the age floor spares a concurrent sweep's
    # in-flight shards (they are fsynced per record, so a genuinely stale
    # file stops aging the moment its writer dies).
    _salvage_shards(store, shard_dir, verbose, min_age_s=60.0)
    done = store.completed() if resume else set()
    todo = [s for s in specs if s.run_id not in done]
    skipped = len(specs) - len(todo)
    if verbose and skipped:
        print(f"resume: skipping {skipped} completed run(s)")

    statuses: list[dict[str, Any]] = []
    if processes <= 1 or len(todo) <= 1:
        for i, spec in enumerate(todo):
            if verbose:
                print(f"[{i + 1}/{len(todo)}] {spec.run_id}  ({spec.topology} "
                      f"x {spec.partitioner})")
            statuses.append(
                run_spec(spec, store, verbose=verbose, raise_on_error=False)
            )
    else:
        import concurrent.futures as cf
        import multiprocessing as mp

        ctx = mp.get_context("spawn")
        os.makedirs(shard_dir, exist_ok=True)
        jobs = [
            (s.to_json(), os.path.join(shard_dir, f"{s.run_id}.jsonl"), verbose)
            for s in todo
        ]
        # ProcessPoolExecutor, not mp.Pool: a worker killed mid-run (OOM,
        # signal) raises BrokenProcessPool on the victim's future, whereas
        # Pool.imap_unordered silently respawns the worker and blocks on the
        # lost result forever — the sweep must fail that run, not deadlock.
        try:
            with cf.ProcessPoolExecutor(
                max_workers=min(processes, len(jobs)), mp_context=ctx
            ) as pool:
                futs = [pool.submit(_worker, j) for j in jobs]
                for fut in cf.as_completed(futs):
                    try:
                        _merge_shard(store, fut.result())
                    except Exception as e:  # noqa: BLE001 — keep draining;
                        # a broken pool fails the remaining futures fast and
                        # each shows up as a failed (re-runnable) run below.
                        if verbose:
                            print(f"worker failed: {type(e).__name__}: {e}")
        finally:
            # Salvage whatever OUR workers left behind (a killed worker's
            # partial shard) — only this sweep's own filenames; a concurrent
            # sweep's in-flight shards in the shared dir are not ours to take.
            for _, shard, _ in jobs:
                try:
                    _merge_shard(store, shard)
                except FileNotFoundError:
                    pass  # merged in the loop above
            try:
                os.rmdir(shard_dir)
            except OSError:
                pass  # non-empty: a concurrent sweep is still writing here
        finals = store.finals()
        statuses = [
            {"status": "completed" if s.run_id in finals else "failed",
             "run_id": s.run_id}
            for s in todo
        ]

    failed = [s["run_id"] for s in statuses if s["status"] != "completed"]
    return {
        "total": len(specs),
        "ran": len(todo),
        "skipped": skipped,
        "failed": failed,
        "store": store.path,
    }
