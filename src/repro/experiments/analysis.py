"""Knowledge-spread analytics: join graph properties with training curves.

Consumes a ResultsStore written by runner.py and produces the paper's
headline views:

- per-run summary rows (topology family, partitioner, seed, realized-graph
  properties, spectral gap, final/best accuracies, consensus trajectory);
- the hub-vs-leaf table (paper Fig. 3): for each topology family, how well
  G2 knowledge held only by hubs vs. only by leaves spreads to the nodes
  that never saw it (``g2_acc_spread``);
- the community-confusion view (paper Table 1) for runs on block graphs;
- ``BENCH_sweep.json`` — the machine-readable artifact CI uploads.

Everything is plain dict/list (no pandas in the container).
"""

from __future__ import annotations

import json
from typing import Any

import numpy as np

from repro.experiments.store import ResultsStore

__all__ = [
    "summarize",
    "hub_vs_leaf_table",
    "qualitative_checks",
    "write_bench",
    "render_tables",
]


def _auc(xs: list[float]) -> float | None:
    """Mean of a curve — a rounds-robust 'how fast did it get there' scalar."""
    vals = [x for x in xs if x is not None]
    return float(np.mean(vals)) if vals else None


def summarize(store: ResultsStore) -> list[dict[str, Any]]:
    """One row per completed run: spec axes + graph properties + curve stats.

    One ``store.load()`` pass: runs whose latest attempt is incomplete or
    failed are excluded (same contract as ``ResultsStore.completed``).
    """
    from repro.experiments.spec import family_of

    runs = store.load()
    rows: list[dict[str, Any]] = []
    for rid in sorted(runs):
        run = runs[rid]
        if not ResultsStore._is_completed(run):
            continue
        spec, end, curve = run["spec"], run["end"], run["rounds"]
        final = end.get("final", {})
        graph = final.get("graph", {})
        # Time-varying runs carry per-period summaries; regress against the
        # period mean, not the period-0 snapshot (which only describes the
        # first graph the schedule realized).
        gmean = final.get("graph_mean") or {}

        def gv(key: str) -> Any:
            return gmean.get(key, graph.get(key))

        row: dict[str, Any] = {
            "run_id": rid,
            "family": family_of(spec.get("topology", "?")),
            "topology": spec.get("topology"),
            "partitioner": spec.get("partitioner"),
            "backend": spec.get("backend"),
            "gossip_every": spec.get("gossip_every", 1),
            "kind": (spec.get("model") or {}).get("kind", "mlp"),
            "seed": spec.get("seed"),
            "rounds": len(curve),
            "wall_s": end.get("wall_s"),
            # graph side (period means for @regen/@rewire runs)
            "nodes": graph.get("nodes"),
            "edges": gv("edges"),
            "degree_mean": gv("degree_mean"),
            "degree_std": gv("degree_std"),
            "modularity": gv("modularity"),
            "clustering": gv("clustering"),
            "spectral_gap": gv("spectral_gap"),
            "topology_periods": final.get("graph_num_periods", 1),
            # training side (last round record)
            "final_acc": final.get("mean_acc"),
            "final_g1_acc": final.get("g1_acc"),
            "final_g2_acc": final.get("g2_acc"),
            # lm runs report spread as g2_token_spread (mean true-token
            # probability on foreign-domain tokens); the join treats the two
            # as one quantity so hub-vs-leaf tables work for both kinds.
            "final_g2_spread": final.get(
                "g2_acc_spread", final.get("g2_token_spread")
            ),
            "final_consensus": final.get("consensus_mean"),
            "final_loss": final.get("loss"),
            # curve stats
            "auc_acc": _auc([r.get("mean_acc") for r in curve]),
            "auc_g2_spread": _auc(
                [
                    r.get("g2_acc_spread", r.get("g2_token_spread"))
                    for r in curve
                ]
            ),
            # fault side (None for fault-free runs)
            "faults": spec.get("faults"),
            "alive_min": final.get("alive_min"),
            "recovery_rounds": final.get("recovery_rounds"),
        }
        if "community_confusion_offdiag" in final:
            row["community_confusion_offdiag"] = final["community_confusion_offdiag"]
        rows.append(row)
    return rows


def hub_vs_leaf_table(rows: list[dict[str, Any]]) -> dict[str, dict[str, Any]]:
    """Per topology family: G2 spread under hub_focused vs edge_focused splits,
    averaged over seeds. The paper's qualitative claim is hub > edge."""
    table: dict[str, dict[str, Any]] = {}
    for split in ("hub_focused", "edge_focused"):
        for r in rows:
            if r["partitioner"] != split or r.get("final_g2_spread") is None:
                continue
            fam = table.setdefault(r["family"], {})
            fam.setdefault(split, []).append(r["final_g2_spread"])
            fam.setdefault(f"{split}_auc", []).append(r.get("auc_g2_spread"))
    out: dict[str, dict[str, Any]] = {}
    for fam, cols in table.items():
        row = {k: _auc(v) for k, v in cols.items()}
        if row.get("hub_focused") is not None and row.get("edge_focused") is not None:
            row["hub_minus_edge"] = row["hub_focused"] - row["edge_focused"]
        out[fam] = row
    return out


def qualitative_checks(rows: list[dict[str, Any]]) -> dict[str, Any]:
    """The paper's qualitative orderings, as machine-checkable booleans.

    - hub_beats_edge: on every family with both splits, knowledge held by
      hubs spreads to non-holders better than knowledge held by leaves
      (compared on curve AUC, which is robust to both curves saturating).
    - gossip_learns_g2: under hub_focused splits, the nodes that never saw
      a G2 example end clearly above chance (1/10) on G2 — knowledge moved
      over the edges, not the data.
    - hub_kill_hurts_more: across faulted runs, killing hubs damages G2
      spread at least as much as killing leaves (hub-targeted churn's
      ``auc_g2_spread`` <= leaf-targeted churn's) — the paper's hub-vs-leaf
      centrality result, stress-tested under churn. None when the sweep has
      no targeted-churn pair.
    - lm_gossip_spreads: across lm runs, gossiped cohorts end with higher
      ``g2_token_spread`` (mean true-token probability on *other* nodes'
      domain tokens) than ``gossip_every=0`` isolation — domain knowledge
      moved over the edges, the paper's spread question on the token task.
      None when the sweep lacks either side of the comparison.
    """
    hub_edge = hub_vs_leaf_table(rows)
    per_family = {
        fam: bool(
            (cols.get("hub_focused_auc") or 0.0)
            > (cols.get("edge_focused_auc") or 0.0)
        )
        for fam, cols in hub_edge.items()
        if cols.get("hub_focused") is not None and cols.get("edge_focused") is not None
    }
    hub_spread = [
        r["final_g2_spread"]
        for r in rows
        if r.get("final_g2_spread") is not None and r["partitioner"] == "hub_focused"
    ]
    def targeted_auc(target: str) -> float | None:
        vals = [
            r.get("auc_g2_spread")
            for r in rows
            if r.get("faults") and f"targeted={target}" in r["faults"]
            and r.get("auc_g2_spread") is not None
        ]
        return float(np.mean(vals)) if vals else None

    hub_kill, leaf_kill = targeted_auc("hubs"), targeted_auc("leaves")

    def lm_spread(gossiped: bool) -> float | None:
        vals = [
            r["final_g2_spread"]
            for r in rows
            if r.get("kind") == "lm" and r.get("final_g2_spread") is not None
            and (r.get("gossip_every", 1) >= 1) == gossiped
        ]
        return float(np.mean(vals)) if vals else None

    lm_gossip, lm_isolated = lm_spread(True), lm_spread(False)
    return {
        "hub_beats_edge": all(per_family.values()) if per_family else None,
        "hub_beats_edge_by_family": per_family,
        "gossip_learns_g2": (float(np.mean(hub_spread)) > 0.13) if hub_spread else None,
        "hub_kill_hurts_more": (
            None if hub_kill is None or leaf_kill is None
            else bool(hub_kill <= leaf_kill)
        ),
        "hub_kill_auc_g2_spread": hub_kill,
        "leaf_kill_auc_g2_spread": leaf_kill,
        "lm_gossip_spreads": (
            None if lm_gossip is None or lm_isolated is None
            else bool(lm_gossip > lm_isolated)
        ),
        "lm_gossip_g2_token_spread": lm_gossip,
        "lm_isolated_g2_token_spread": lm_isolated,
    }


def write_bench(
    store: ResultsStore,
    out_path: str,
    *,
    rows: list[dict[str, Any]] | None = None,
    extra: dict | None = None,
) -> dict:
    """Write the sweep's machine-readable summary (BENCH_sweep.json).
    Pass ``rows`` to reuse an existing ``summarize(store)`` result."""
    if rows is None:
        rows = summarize(store)
    bench = {
        "bench": "topology_sweep",
        "store": store.path,
        "runs": len(rows),
        "summary": rows,
        "hub_vs_leaf": hub_vs_leaf_table(rows),
        "checks": qualitative_checks(rows),
        **(extra or {}),
    }
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)
    return bench


def render_tables(rows: list[dict[str, Any]]) -> str:
    """Human-readable headline tables for the CLI."""
    lines: list[str] = []
    if not rows:
        return "(no completed runs)"
    lines.append("run summary:")
    hdr = ("family", "partitioner", "seed", "final_acc", "final_g2_spread",
           "final_consensus", "spectral_gap")
    lines.append("  " + "  ".join(f"{h:>16s}" for h in hdr))
    for r in rows:
        vals = []
        for h in hdr:
            v = r.get(h)
            vals.append(f"{v:16.4f}" if isinstance(v, float) else f"{str(v):>16s}")
        lines.append("  " + "  ".join(vals))
    he = hub_vs_leaf_table(rows)
    if he:
        lines.append("\nhub vs leaf G2 spread (final / AUC):")
        for fam, cols in sorted(he.items()):
            hub, edge = cols.get("hub_focused"), cols.get("edge_focused")
            ha, ea = cols.get("hub_focused_auc"), cols.get("edge_focused_auc")
            if hub is None or edge is None:
                continue
            lines.append(
                f"  {fam:>10s}: hub {hub:.4f}/{ha:.4f}  edge {edge:.4f}/{ea:.4f}  "
                f"delta {cols['hub_minus_edge']:+.4f}"
            )
    checks = qualitative_checks(rows)
    lines.append(f"\nchecks: {json.dumps(checks)}")
    return "\n".join(lines)
