"""Declarative experiment specs with grid expansion and stable run ids.

An ``ExperimentSpec`` pins everything one training run depends on: the
topology registry spec string, the data partitioner, the gossip backend and
matrix, the optimizer hyperparameters and the seed. Specs round-trip through
JSON, and ``run_id`` is a content hash of the canonical JSON — the same spec
always maps to the same id, which is what gives the results store its
skip-completed / resume semantics.

The paper's matrix is a cartesian product (topology family x split x seed);
``expand_grid`` builds it from a base dict plus per-axis value lists::

    specs = expand_grid(
        {"rounds": 40, "lr": 0.05},
        topology=["er:n=100", "ba:n=100,m=2"],
        partitioner=["hub_focused", "edge_focused"],
        seed=[0, 1, 2],
    )
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from typing import Any, Iterable

__all__ = ["ExperimentSpec", "expand_grid", "family_of", "PARTITIONERS"]


def family_of(topology: str) -> str:
    """Topology family name: the part of a spec string before ':' / '@'."""
    return topology.split("@", 1)[0].split(":", 1)[0].strip().lower()

# Names runner.py can dispatch (core/partition.py partitioners).
PARTITIONERS = ("iid", "hub_focused", "edge_focused", "community", "dirichlet")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One fully-determined training run.

    Attributes:
      topology: registry spec string (``"ba:n=100,m=2"``; may carry an
        ``@regen=``/``@rewire=`` schedule suffix).
      partitioner: one of PARTITIONERS; graph-aware splits (hub/edge/
        community) use the realized period-0 graph.
      partitioner_params: extra kwargs for the partitioner (e.g.
        ``{"beta": 0.5}`` for dirichlet, ``{"frac": 0.2}`` for focused).
      backend: GossipEngine backend name or "auto".
      matrix: mixing matrix kind ("decavg" | "uniform" | "mh").
      rounds: communication rounds (for LM specs: train steps).
      eval_every: evaluate / stream a record every k rounds.
      data: overrides for data.synthetic.make_mnist_like (train_per_class...).
      model: model config; ``{"kind": "mlp", ...}`` (default) runs the
        paper-faithful DecentralizedTrainer (optional ``hidden=[...]`` for
        narrower members, ``sparse_p_chunk=int|"auto"`` to bound the sparse
        gather transient at large N, ``fused=False`` to opt out of the fused
        single-``lax.scan`` run path, ``compress=float`` for top-k gossip
        delta compression), ``{"kind": "lm", "arch": ...}`` runs the
        LLM-cohort loop (launch/train.py is a thin wrapper over it).
      faults: fault-injection spec string (core/faults.py grammar, e.g.
        ``"churn:p_leave=0.05,p_join=0.5@targeted=hubs"``), or None for a
        fault-free run. Expanded deterministically from ``seed``.
      tag: freeform grouping label — excluded from the run id.
    """

    topology: str
    partitioner: str = "iid"
    partitioner_params: dict[str, Any] = dataclasses.field(default_factory=dict)
    backend: str = "auto"
    matrix: str = "decavg"
    rounds: int = 10
    eval_every: int = 1
    lr: float = 0.05
    momentum: float = 0.9
    local_epochs: int = 1
    batch_size: int = 32
    gossip_every: int = 1
    same_init: bool = True
    seed: int = 0
    data: dict[str, Any] = dataclasses.field(default_factory=dict)
    model: dict[str, Any] = dataclasses.field(default_factory=dict)
    faults: str | None = None
    tag: str = ""

    def __post_init__(self):
        if self.partitioner not in PARTITIONERS:
            raise ValueError(
                f"unknown partitioner {self.partitioner!r}; one of {PARTITIONERS}"
            )
        if self.faults is not None:
            from repro.core.faults import parse_faults

            parse_faults(self.faults)  # fail fast on a malformed spec
        if self.rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        if self.eval_every < 1:
            raise ValueError(f"eval_every must be >= 1, got {self.eval_every}")
        kind = self.model.get("kind", "mlp")
        if kind not in ("mlp", "lm"):
            raise ValueError(f"unknown model kind {kind!r}; 'mlp' or 'lm'")

    # -- identity -----------------------------------------------------------

    # Fields added after the store format shipped: dropped from the content
    # hash while they hold their default, so every pre-existing JSONL store's
    # run ids — and their skip-completed semantics — survive the schema
    # growing. A non-default value (an actual fault spec) still hashes.
    # Lint rule H001 (repro.lint.contracts) enforces the discipline: every
    # post-baseline field with a default MUST be registered here with that
    # default, and the golden ring:n=8 run id must not move.
    _HASH_OPTIONAL = {"faults": None}

    # Same treatment for keys added to the ``model`` dict after the fact
    # (the dict hashes as a whole, so a new default-valued key would shift
    # every pre-existing run id). ``resume`` is always stripped: restoring a
    # checkpoint is an execution detail of the same run, not a new identity.
    _HASH_OPTIONAL_MODEL = {"compress": "auto", "fused": True}

    def canonical(self) -> dict[str, Any]:
        """Identity-bearing fields as a plain dict (tag excluded;
        later-generation fields excluded while at their default)."""
        d = dataclasses.asdict(self)
        d.pop("tag")
        for name, default in self._HASH_OPTIONAL.items():
            if d.get(name) == default:
                d.pop(name, None)
        model = dict(d.get("model") or {})
        model.pop("resume", None)
        for name, default in self._HASH_OPTIONAL_MODEL.items():
            if model.get(name, default) == default:
                model.pop(name, None)
        d["model"] = model
        return d

    @property
    def family(self) -> str:
        """Topology family name (the part before ':' / '@')."""
        return family_of(self.topology)

    @property
    def run_id(self) -> str:
        """Stable, human-scannable id: family-partitioner-s<seed>-<hash8>."""
        blob = json.dumps(self.canonical(), sort_keys=True, separators=(",", ":"))
        h = hashlib.sha256(blob.encode()).hexdigest()[:8]
        return f"{self.family}-{self.partitioner}-s{self.seed}-{h}"

    # -- JSON round-trip ----------------------------------------------------

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "ExperimentSpec":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(f"unknown ExperimentSpec fields {sorted(unknown)}")
        return cls(**d)


def expand_grid(base: dict[str, Any], **axes: Iterable[Any]) -> list[ExperimentSpec]:
    """Cartesian product of ``axes`` value lists over a ``base`` spec dict.

    Each axis key must be an ExperimentSpec field; axis values win over
    ``base``. Returns specs in deterministic (itertools.product) order.
    """
    keys = sorted(axes)
    specs: list[ExperimentSpec] = []
    for combo in itertools.product(*(list(axes[k]) for k in keys)):
        d = dict(base)
        d.update(zip(keys, combo))
        specs.append(ExperimentSpec.from_json(d))
    ids = [s.run_id for s in specs]
    if len(set(ids)) != len(ids):
        raise ValueError("grid expansion produced duplicate run ids")
    return specs
