"""Experiment harness: declarative sweep specs, a resumable JSONL runner and
knowledge-spread analytics (the paper's topology x split x seed matrix)."""

from repro.experiments.spec import ExperimentSpec, expand_grid  # noqa: F401
from repro.experiments.store import ResultsStore  # noqa: F401
