"""Append-only JSONL results store with resume semantics.

One sweep writes one JSONL file; every line is a self-describing record:

  {"kind": "run_start", "run_id": ..., "spec": {...}, "time": ...}
  {"kind": "round", "run_id": ..., "round": 0, "mean_acc": ..., ...}
  {"kind": "run_end", "run_id": ..., "status": "completed", "final": {...}}

Append-only makes the store crash-safe: a killed run simply lacks its
``run_end`` line and is re-executed on resume (its stale ``round`` records
are superseded — readers only consider records after the *latest*
``run_start`` of each run id). A truncated trailing line (power loss mid
write) is skipped on read.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Iterable

__all__ = ["ResultsStore"]


class ResultsStore:
    def __init__(self, path: str):
        self.path = str(path)
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)

    # -- writing ------------------------------------------------------------

    def append(self, record: dict[str, Any]) -> None:
        line = json.dumps(record, sort_keys=True, separators=(",", ":"))
        with open(self.path, "a") as f:
            f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())

    def append_lines(self, lines: Iterable[str]) -> None:
        """Merge pre-serialized JSONL lines (multi-process shard merge)."""
        with open(self.path, "a") as f:
            for line in lines:
                line = line.strip()
                if line:
                    f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())

    def run_start(self, run_id: str, spec: dict[str, Any]) -> None:
        self.append({"kind": "run_start", "run_id": run_id, "spec": spec,
                     "time": time.time()})  # lint: allow[D002] — provenance timestamp in the store record, not part of any result

    def round(self, run_id: str, record: dict[str, Any]) -> None:
        self.append({"kind": "round", "run_id": run_id, **record})

    def run_end(self, run_id: str, status: str, **extra: Any) -> None:
        self.append({"kind": "run_end", "run_id": run_id, "status": status,
                     "time": time.time(), **extra})  # lint: allow[D002] — provenance timestamp in the store record, not part of any result

    # -- reading ------------------------------------------------------------

    def records(self) -> list[dict[str, Any]]:
        if not os.path.exists(self.path):
            return []
        out: list[dict[str, Any]] = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    continue  # truncated trailing line from a crashed writer
        return out

    def load(self) -> dict[str, dict[str, Any]]:
        """One-pass view of the store keyed by run_id, latest attempt only.

        Returns ``{run_id: {"spec": ..., "rounds": [...], "end": run_end |
        None}}``. A newer ``run_start`` supersedes everything from earlier
        attempts of the same run — including an earlier *completed*
        ``run_end`` — so all readers (resume, curves, analysis joins) agree
        on which attempt a run's data comes from.
        """
        runs: dict[str, dict[str, Any]] = {}
        for r in self.records():
            rid = r.get("run_id")
            kind = r.get("kind")
            if rid is None:
                continue
            if kind == "run_start":
                runs[rid] = {"spec": r.get("spec", {}), "rounds": [], "end": None}
            elif rid in runs:
                if kind == "round":
                    runs[rid]["rounds"].append(r)
                elif kind == "run_end":
                    runs[rid]["end"] = r
        for run in runs.values():
            run["rounds"].sort(key=lambda r: r.get("round", 0))
        return runs

    @staticmethod
    def _is_completed(run: dict[str, Any]) -> bool:
        return run["end"] is not None and run["end"].get("status") == "completed"

    def completed(self) -> set[str]:
        """Run ids whose *latest* attempt has a completed ``run_end``."""
        return {rid for rid, run in self.load().items() if self._is_completed(run)}

    def specs(self) -> dict[str, dict[str, Any]]:
        """run_id -> spec dict from the latest run_start of each run."""
        return {rid: run["spec"] for rid, run in self.load().items()}

    def curves(self, run_id: str) -> list[dict[str, Any]]:
        """Round records of ``run_id``'s latest attempt, in round order."""
        run = self.load().get(run_id)
        return run["rounds"] if run else []

    def finals(self) -> dict[str, dict[str, Any]]:
        """run_id -> the latest attempt's run_end, completed attempts only."""
        return {
            rid: run["end"]
            for rid, run in self.load().items()
            if self._is_completed(run)
        }
