"""Loss functions (f32 accumulation regardless of activation dtype)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross-entropy. logits (..., C), labels (...) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def lm_loss(logits: jax.Array, labels: jax.Array, *, ignore: int = -1) -> jax.Array:
    """Next-token CE with an ignore index; logits (B, S, V), labels (B, S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    safe = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
