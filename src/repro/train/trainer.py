"""Paper-faithful decentralized trainer (DecAvg over a graph of nodes).

One *communication round* (paper §3):
  1. every node runs local SGD-with-momentum epochs on its own data,
  2. every node replaces its weights by the Eq. 1 neighborhood average.

All nodes advance in lockstep as node-stacked pytrees — local training is a
``vmap`` over the node axis, the gossip is a GossipEngine round
(core/decavg.py: XLA einsum, Pallas kernel, or sparse CSR). Momentum is
node-local and is *not* averaged (the paper gossips model weights only).

The topology may be a built ``Graph``, a registry spec string
(``"ba:n=100,m=2"``, with ``n`` defaulted from the loader), or a
``TopologySchedule`` — time-varying graphs rebuild the mixing matrix (and
re-jit the round) at each schedule period.

This trainer is the 100-node MNIST-scale reproduction engine; the LLM-cohort
path with sharded nodes lives in launch/train.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decavg
from repro.core.topology import Graph, TopologySchedule
from repro.data.loader import NodeLoader
from repro.models.mlp import init_mlp, mlp_forward
from repro.optim import sgd
from repro.train.losses import softmax_xent
from repro.train.metrics import (
    accuracy,
    confusion_matrix,
    consensus_distance,
    group_accuracy,
)

PyTree = Any


@dataclasses.dataclass
class RoundMetrics:
    round: int
    per_node_acc: np.ndarray  # (N,)
    mean_acc: float
    std_acc: float
    # Knowledge-spread extras (filled when the trainer has class_groups /
    # when eval runs; None otherwise so legacy consumers are unaffected).
    group_acc: np.ndarray | None = None  # (N, G) per-node per-group accuracy
    consensus: np.ndarray | None = None  # (N,) ||theta_i - theta_bar||
    wall_s: float = 0.0  # cumulative wall-clock since run() started


class DecentralizedTrainer:
    """DecAvg over an arbitrary model family (default: the paper's MLP)."""

    def __init__(
        self,
        graph: Graph | TopologySchedule | str,
        loader: NodeLoader,
        *,
        lr: float = 1e-3,
        momentum: float = 0.5,
        local_epochs: int = 1,
        mix_impl: str = "dense",  # a GossipEngine backend ("dense"|"pallas"|...) or "auto"
        matrix: str = "decavg",  # mixing matrix kind ("decavg"|"uniform"|"mh")
        sparse_p_chunk=None,  # int | "auto": bound the sparse gather transient
        gossip_every: int = 1,  # mix on rounds r % k == 0; 0 = isolated (no gossip)
        same_init: bool = True,
        seed: int = 0,
        init_fn: Callable[..., PyTree] | None = None,
        forward_fn: Callable[[PyTree, jax.Array], jax.Array] | None = None,
        in_dim: int = 784,
        num_classes: int = 10,
        class_groups: Sequence[int] | np.ndarray | None = None,
    ):
        self.loader = loader
        self.engine = decavg.GossipEngine(
            graph, data_sizes=loader.sizes.astype(np.float64), backend=mix_impl,
            matrix=matrix, sparse_p_chunk=sparse_p_chunk,
            gossip_every=gossip_every, seed=seed, n=len(loader.sizes),
        )
        if mix_impl == "auto":
            mix_impl = self.engine.backend
        self.graph = self.engine.graph
        self.lr, self.mu = lr, momentum
        self.local_epochs = local_epochs
        self.num_nodes = self.engine.num_nodes
        self.num_classes = num_classes
        # class_groups maps class id -> group id (e.g. G1/G2 = 0/1); when set,
        # eval rounds also report per-node per-group accuracy.
        self.class_groups = (
            None if class_groups is None else jnp.asarray(np.asarray(class_groups), jnp.int32)
        )
        self.num_groups = (
            0 if self.class_groups is None else int(np.asarray(class_groups).max()) + 1
        )
        init_fn = init_fn or (lambda k: init_mlp(k, in_dim=in_dim, num_classes=num_classes))
        self.forward = forward_fn or mlp_forward

        self.w = self.engine.w
        # _mix reads the engine's current-period state; tests may still
        # override self.w directly (dense path) and re-jit.
        if mix_impl == "dense":
            self._mix = decavg.mix_dense
        elif mix_impl == "pallas":
            self._mix = decavg.mix_pallas
        else:
            self._mix = lambda w, p: self.engine.mix(p, backend=mix_impl)

        key = jax.random.PRNGKey(seed)
        if same_init:
            p0 = init_fn(key)
            self.params = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.num_nodes,) + x.shape).copy(), p0
            )
        else:
            keys = jax.random.split(key, self.num_nodes)
            self.params = jax.vmap(init_fn)(keys)
        self.opt_state = sgd.init(self.params)
        self._round_jit = jax.jit(self._round)
        self._local_jit = jax.jit(self._local_steps)  # non-gossip rounds
        self._eval_jit = jax.jit(self._eval)
        self._group_eval_jit = jax.jit(self._group_eval)
        self._consensus_jit = jax.jit(consensus_distance)

    # -- jitted bodies ------------------------------------------------------

    def _local_steps(self, params, opt_state, xs, ys):
        """xs: (steps, N, B, D); one vmapped SGD step per element of steps."""

        def one_step(carry, batch):
            params, opt = carry
            x, y = batch  # (N, B, D), (N, B)

            def node_loss(p, xb, yb):
                return softmax_xent(self.forward(p, xb), yb)

            grads = jax.vmap(jax.grad(node_loss))(params, x, y)
            # sgd.update broadcasts fine over the stacked node axis.
            params, opt = sgd.update(grads, opt, params, lr=self.lr, mu=self.mu)
            return (params, opt), None

        (params, opt_state), _ = jax.lax.scan(one_step, (params, opt_state), (xs, ys))
        return params, opt_state

    def _round(self, params, opt_state, xs, ys):
        params, opt_state = self._local_steps(params, opt_state, xs, ys)
        params = self._mix(self.w, params)
        return params, opt_state

    def _eval(self, params, x_test, y_test):
        def node_metrics(p):
            logits = self.forward(p, x_test)
            return accuracy(logits, y_test), confusion_matrix(
                logits, y_test, self.num_classes
            )

        return jax.vmap(node_metrics)(params)

    def _group_eval(self, params, x_test, y_test):
        """Per-node (accuracy, per-group accuracy); used when class_groups set."""

        def node_metrics(p):
            logits = self.forward(p, x_test)
            return accuracy(logits, y_test), group_accuracy(
                logits, y_test, self.class_groups, self.num_groups
            )

        return jax.vmap(node_metrics)(params)

    # -- public API ---------------------------------------------------------

    def eval_round(self, r: int, x_test, y_test, t0: float) -> RoundMetrics:
        """One evaluation pass over the current params as a RoundMetrics."""
        x_test, y_test = jnp.asarray(x_test), jnp.asarray(y_test)
        group_acc = None
        if self.class_groups is not None:
            accs, gaccs = self._group_eval_jit(self.params, x_test, y_test)
            group_acc = np.asarray(gaccs)
        else:
            accs, _ = self._eval_jit(self.params, x_test, y_test)
        accs = np.asarray(accs)
        cons = np.asarray(self._consensus_jit(self.params))
        return RoundMetrics(
            r, accs, float(accs.mean()), float(accs.std()),
            group_acc=group_acc, consensus=cons, wall_s=time.perf_counter() - t0,
        )

    def run(
        self,
        rounds: int,
        *,
        eval_every: int = 1,
        x_test: np.ndarray | None = None,
        y_test: np.ndarray | None = None,
        gossip_first: bool = False,
        verbose: bool = False,
        on_round: Callable[[RoundMetrics], None] | None = None,
    ) -> list[RoundMetrics]:
        """Run communication rounds; returns per-round metrics history.

        ``on_round`` fires after every evaluated round (the experiment
        harness streams each RoundMetrics to its results store instead of
        waiting for the full history).
        """
        history: list[RoundMetrics] = []
        steps = self.loader.steps_per_epoch() * self.local_epochs
        t0 = time.perf_counter()
        if gossip_first:
            self.params = self._mix(self.w, self.params)
        for r in range(rounds):
            if self.engine.schedule.is_time_varying and self.engine.refresh(r):
                # New schedule period: fresh W, re-jit the round closure.
                self.w = self.engine.w
                self.graph = self.engine.graph
                self._round_jit = jax.jit(self._round)
            xs, ys = self.loader.sample_round(steps)
            step = (
                self._round_jit if self.engine.is_gossip_round(r) else self._local_jit
            )
            self.params, self.opt_state = step(
                self.params, self.opt_state, jnp.asarray(xs), jnp.asarray(ys)
            )
            if x_test is not None and (r % eval_every == 0 or r == rounds - 1):
                m = self.eval_round(r, x_test, y_test, t0)
                history.append(m)
                if on_round is not None:
                    on_round(m)
                if verbose:
                    accs = m.per_node_acc
                    print(
                        f"round {r:4d}  acc mean {accs.mean():.4f} "
                        f"std {accs.std():.4f} min {accs.min():.4f} max {accs.max():.4f}"
                    )
        return history

    def confusion(self, x_test: np.ndarray, y_test: np.ndarray) -> np.ndarray:
        _, cms = self._eval_jit(self.params, jnp.asarray(x_test), jnp.asarray(y_test))
        return np.asarray(cms)
