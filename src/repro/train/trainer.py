"""Paper-faithful decentralized trainer (DecAvg over a graph of nodes).

One *communication round* (paper §3):
  1. every node runs local SGD-with-momentum epochs on its own data,
  2. every node replaces its weights by the Eq. 1 neighborhood average.

All nodes advance in lockstep as node-stacked pytrees — local training is a
``vmap`` over the node axis, the gossip is a GossipEngine round
(core/decavg.py: XLA einsum, Pallas kernel, or sparse CSR). Momentum is
node-local and is *not* averaged (the paper gossips model weights only).

The topology may be a built ``Graph``, a registry spec string
(``"ba:n=100,m=2"``, with ``n`` defaulted from the loader), or a
``TopologySchedule``.

Two execution paths over the same numerics:

- ``run``: one Python iteration per round. The mixing operand (dense W or
  CSR) is a *traced argument* of the round closure, so ``@regen``/``@rewire``
  schedule periods reuse one compiled program instead of re-jitting (backends
  that mix through engine-held static state fall back to a per-period cache
  of jitted closures). Batches come from the loader's round-keyed sampler.
- ``run_fused``: the whole run is ``lax.scan`` chunks of ``eval_every``
  rounds inside one jit — the engine's ``MixingProgram`` stages every
  schedule period up front, the loader's dataset is staged on device and
  batch indices are generated *inside* the scan, and stacked round metrics
  stream to ``on_round`` between chunks. Same seed => same params/metrics as
  ``run`` (tests pin allclose at 1e-6; sparse and sparse_sharded are
  bit-identical); dense, sparse, sparse_pallas and sparse_sharded backends —
  sharded runs put the whole scan under one ``shard_map`` so each device
  trains its node slab and only the halo exchange crosses devices. The
  Python loop remains the fallback for verbose/debug and the other backends.

``compress=`` (top-k fraction) turns on CHOCO-style gossip compression
(core/compress.py): each gossip round every node transmits the top-k entries
of ``params - reference``, peers mix the shared *reference* models, and
``params += W @ ref - ref`` — at ``k_frac=1`` this is exactly DecAvg, at
small k it cuts wire volume to k·|params| while reference tracking keeps the
residual re-entering next round's selection.

``DecentralizedTrainer`` is the 100-node MNIST-scale reproduction engine;
``LMCohortTrainer`` (below) gives the LLM-cohort path — transformer members
on domain-skewed token streams — the same two execution paths over one
``GossipEngine``: a per-round Python loop and a fused ``MixingProgram``
``lax.scan`` with AdamW + the LR schedule inside the scan body.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import compress as compress_mod
from repro.core import decavg
from repro.core.topology import Graph, TopologySchedule
from repro.data.loader import NodeLoader, round_batch_indices
from repro.models.mlp import init_mlp, mlp_forward
from repro.optim import sgd
from repro.train.losses import softmax_xent
from repro.train.metrics import (
    accuracy,
    confusion_matrix,
    consensus_distance,
    group_accuracy,
)

PyTree = Any

# Backends whose mixing operand (dense W / CSR pytree) rides through the
# round closure as a traced argument: one compiled program serves every
# schedule period. The rest (engine-held static state: ELL layouts, meshes,
# edge colorings) re-trace per period via the per-period jit cache.
_OPERAND_BACKENDS = ("dense", "pallas", "sparse")

# Backends run_fused supports: those whose per-period operators stack into a
# MixingProgram (core/decavg.py) selectable by index inside a lax.scan —
# dense W, padded CSR, blocked-ELL tiles, and per-shard ShardedCSR metadata
# (whose ring/allgather halo exchange runs inside the scan under shard_map).
# Must mirror the ``fused`` flags in decavg._BACKEND_INFO (lint rule C001).
_FUSED_BACKENDS = ("dense", "sparse", "sparse_pallas", "sparse_sharded")

# Per-round threefry dispatch inside a lax.scan costs ~0.5 ms on CPU — a
# fixed floor the fused path can hoist: one vmapped draw over the whole
# chunk's rounds yields bit-identical indices (random primitives commute
# with vmap) as scan xs. Hoisting is gated by the index-tensor element
# count so a large-N thousands-of-rounds chunk falls back to in-scan
# generation instead of staging a multi-GB (L, steps, N, B) tensor.
_IDX_HOIST_MAX_ELEMS = 1 << 24  # 64 MB of int32


@dataclasses.dataclass
class RoundMetrics:
    round: int
    per_node_acc: np.ndarray  # (N,)
    mean_acc: float
    std_acc: float
    # Knowledge-spread extras (filled when the trainer has class_groups /
    # when eval runs; None otherwise so legacy consumers are unaffected).
    group_acc: np.ndarray | None = None  # (N, G) per-node per-group accuracy
    consensus: np.ndarray | None = None  # (N,) ||theta_i - theta_bar||
    wall_s: float = 0.0  # cumulative wall-clock since run() started


class DecentralizedTrainer:
    """DecAvg over an arbitrary model family (default: the paper's MLP)."""

    def __init__(
        self,
        graph: Graph | TopologySchedule | str,
        loader: NodeLoader,
        *,
        lr: float = 1e-3,
        momentum: float = 0.5,
        local_epochs: int = 1,
        mix_impl: str = "dense",  # a GossipEngine backend ("dense"|"pallas"|...) or "auto"
        matrix: str = "decavg",  # mixing matrix kind ("decavg"|"uniform"|"mh")
        sparse_p_chunk=None,  # int | "auto": bound the sparse gather transient
        gossip_every: int = 1,  # mix on rounds r % k == 0; 0 = isolated (no gossip)
        compress: float | None = None,  # top-k fraction for gossip compression
        faults: str | None = None,  # fault spec (core/faults.py), e.g. "churn:p_leave=0.1"
        same_init: bool = True,
        seed: int = 0,
        init_fn: Callable[..., PyTree] | None = None,
        forward_fn: Callable[[PyTree, jax.Array], jax.Array] | None = None,
        in_dim: int = 784,
        num_classes: int = 10,
        class_groups: Sequence[int] | np.ndarray | None = None,
    ):
        self.loader = loader
        self.engine = decavg.GossipEngine(
            graph, data_sizes=loader.sizes.astype(np.float64), backend=mix_impl,
            matrix=matrix, sparse_p_chunk=sparse_p_chunk,
            gossip_every=gossip_every, faults=faults, seed=seed,
            n=len(loader.sizes),
        )
        if mix_impl == "auto":
            mix_impl = self.engine.backend
        self.mix_impl = mix_impl
        self.faulted = self.engine.faults is not None
        if self.faulted and compress is not None:
            raise ValueError(
                "faults do not compose with compress= gossip: the CHOCO "
                "reference update assumes every published model is current"
            )
        self.graph = self.engine.graph
        self.lr, self.mu = lr, momentum
        self.local_epochs = local_epochs
        self.num_nodes = self.engine.num_nodes
        self.num_classes = num_classes
        if compress is not None and not 0.0 < float(compress) <= 1.0:
            raise ValueError(f"compress (top-k fraction) must be in (0, 1], got {compress}")
        self.compress = None if compress is None else float(compress)
        # class_groups maps class id -> group id (e.g. G1/G2 = 0/1); when set,
        # eval rounds also report per-node per-group accuracy.
        self.class_groups = (
            None if class_groups is None else jnp.asarray(np.asarray(class_groups), jnp.int32)
        )
        self.num_groups = (
            0 if self.class_groups is None else int(np.asarray(class_groups).max()) + 1
        )
        init_fn = init_fn or (lambda k: init_mlp(k, in_dim=in_dim, num_classes=num_classes))
        self.forward = forward_fn or mlp_forward

        self.w = self.engine.w
        # _mix(op, params): op is the current-period mixing operand (dense W
        # or CSR); engine-held backends ignore it and read engine state at
        # trace time. Tests may still override self.w directly (dense path).
        if mix_impl == "dense":
            self._mix = decavg.mix_dense
        elif mix_impl == "pallas":
            self._mix = lambda w, p: decavg.mix_pallas(
                w, p, interpret=self.engine.interpret
            )
        elif mix_impl == "sparse":
            self._mix = self._mix_sparse
        else:
            self._mix = lambda op, p: self.engine.mix(p, backend=mix_impl)

        key = jax.random.PRNGKey(seed)
        if same_init:
            p0 = init_fn(key)
            self.params = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.num_nodes,) + x.shape).copy(), p0
            )
        else:
            keys = jax.random.split(key, self.num_nodes)
            self.params = jax.vmap(init_fn)(keys)
        self.opt_state = sgd.init(self.params)
        self.cstate = (
            None if self.compress is None else compress_mod.init(self.params)
        )
        # donate_argnums on params/opt_state (and compress reference): the
        # node-stacked pytrees are the footprint at N=4096 — without donation
        # every round double-buffers them.
        self._round_jit = jax.jit(self._round, donate_argnums=(1, 2, 3))
        self._local_jit = jax.jit(self._local_steps, donate_argnums=(0, 1))
        self._eval_jit = jax.jit(self._eval)
        self._group_eval_jit = jax.jit(self._group_eval)
        self._consensus_jit = jax.jit(consensus_distance)
        # Per-period cache for the engine-held backends (see _jit_for_period);
        # the init-time jit serves period 0 so repeat runs never recompile it.
        self._round_jit_cache: dict[int, Any] = {0: self._round_jit}
        self._fused_chunk_jit = jax.jit(
            self._fused_chunk,
            static_argnames=("length", "do_eval"),
            donate_argnums=(2, 3, 4, 5),
        )
        if self.faulted:
            trace = self.engine.fault_trace
            self._fault_delay = jnp.asarray(trace.delay)
            self._has_hist = trace.delay_max > 0
            self._round_faulted_jit = jax.jit(
                self._round_faulted, donate_argnums=(4, 5, 6)
            )
            self._local_faulted_jit = jax.jit(
                self._local_faulted, donate_argnums=(2, 3, 4)
            )

    # -- jitted bodies ------------------------------------------------------

    def _mix_sparse(self, csr, params):
        from repro.core import sparse

        p_chunk = self.engine.sparse_p_chunk
        if p_chunk == "auto":
            p_chunk = sparse.auto_p_chunk(csr.nnz)  # nnz is static under trace
        return sparse.mix_sparse(csr, params, p_chunk=p_chunk)

    def _mix_op(self):
        """The current-period mixing operand passed into the round closure."""
        return self.engine.csr if self.mix_impl == "sparse" else self.w

    def _local_steps(self, params, opt_state, xs, ys):
        """xs: (steps, N, B, D); one vmapped SGD step per element of steps."""

        def one_step(carry, batch):
            params, opt = carry
            x, y = batch  # (N, B, D), (N, B)

            def node_loss(p, xb, yb):
                return softmax_xent(self.forward(p, xb), yb)

            grads = jax.vmap(jax.grad(node_loss))(params, x, y)
            # sgd.update broadcasts fine over the stacked node axis.
            params, opt = sgd.update(grads, opt, params, lr=self.lr, mu=self.mu)
            return (params, opt), None

        (params, opt_state), _ = jax.lax.scan(one_step, (params, opt_state), (xs, ys))
        return params, opt_state

    def _gossip(self, mix, params, cstate):
        """One gossip exchange via ``mix`` (a params->params mixing closure).

        Without compression this is plain DecAvg. With it, the CHOCO update:
        each node publishes the top-k of ``params - reference`` (advancing
        the shared reference), peers average *references*, and the node keeps
        its residual: ``params += W @ ref - ref``. k_frac=1 reduces exactly
        to ``params = W @ params``.
        """
        if self.compress is None:
            return mix(params), cstate
        _, cstate = jax.vmap(
            functools.partial(compress_mod.compress, k_frac=self.compress)
        )(params, cstate)
        ref = cstate.reference
        mixed = mix(ref)
        params = jax.tree.map(
            lambda p, m, r: (p.astype(jnp.float32) + (m - r)).astype(p.dtype),
            params, mixed, ref,
        )
        return params, cstate

    def _round(self, op, params, opt_state, cstate, xs, ys):
        params, opt_state = self._local_steps(params, opt_state, xs, ys)
        params, cstate = self._gossip(
            functools.partial(self._mix, op), params, cstate
        )
        return params, opt_state, cstate

    # -- faulted rounds (core/faults.py semantics) ---------------------------

    def _mix_op_faulted(self):
        """The traced mixing operand for the faulted round: every
        fault-capable backend is operand-style here (``ShardedCSR`` is a
        registered pytree), so one compiled round serves all periods."""
        if self.mix_impl == "dense":
            return self.w
        if self.mix_impl == "sparse":
            return self.engine.csr
        return self.engine.sharded_csr()

    def _fault_keep(self, r: int) -> np.ndarray:
        """Round ``r``'s entry-keep mask in the backend's operand layout."""
        trace = self.engine.fault_trace
        if self.mix_impl == "dense":
            return trace.dense_keep(r)
        if self.mix_impl == "sparse":
            csr = self.engine.csr
            return trace.entry_keep(
                r, np.asarray(csr.rows), np.asarray(csr.indices),
                np.asarray(csr.values),
            )
        shcsr = self.engine.sharded_csr()
        blk = shcsr.rows_per_shard
        rows_g = np.asarray(shcsr.rows) + np.arange(shcsr.shards)[:, None] * blk
        cols_g = np.take_along_axis(
            np.asarray(shcsr.halo), np.asarray(shcsr.cols), axis=1
        )
        return trace.entry_keep(r, rows_g, cols_g, np.asarray(shcsr.values))

    def _mix_faulted(self, op, keep, alive, cur, pub):
        from repro.core import faults as faults_mod

        if self.mix_impl == "dense":
            return faults_mod.mix_faulted_dense(op, keep, alive, cur, pub)
        if self.mix_impl == "sparse":
            return faults_mod.mix_faulted_csr(
                op.rows, op.indices, op.values, keep, alive,
                self.num_nodes, cur, pub,
            )
        return decavg.mix_sharded_sparse_faulted(
            op, cur, cur if pub is None else pub, keep, alive,
            mesh=self.engine.mesh, node_axis=self.engine.node_axis,
            halo_schedule=self.engine.halo_schedule,
        )

    def _round_faulted(self, op, keep, alive, r, params, opt_state, hist, xs, ys):
        """One faulted gossip round: train, freeze dead nodes back to their
        pre-round state (params AND momentum — exactly equivalent to never
        training them), advance the straggler ring buffer, mix the published
        snapshots over the surviving renormalized W."""
        from repro.core import faults as faults_mod

        p_in, o_in = params, opt_state
        params, opt_state = self._local_steps(params, opt_state, xs, ys)
        params = faults_mod.where_alive(alive, params, p_in)
        opt_state = faults_mod.where_alive(alive, opt_state, o_in)
        pub = None
        if self._has_hist:
            pub, hist = faults_mod.push_and_publish(
                params, hist, r, self._fault_delay
            )
        params = self._mix_faulted(op, keep, alive, params, pub)
        return params, opt_state, hist

    def _local_faulted(self, r, alive, params, opt_state, hist, xs, ys):
        """A faulted non-gossip round: train + freeze + history push (a
        straggler's clock advances whether or not the round gossips)."""
        from repro.core import faults as faults_mod

        p_in, o_in = params, opt_state
        params, opt_state = self._local_steps(params, opt_state, xs, ys)
        params = faults_mod.where_alive(alive, params, p_in)
        opt_state = faults_mod.where_alive(alive, opt_state, o_in)
        if self._has_hist:
            _, hist = faults_mod.push_and_publish(
                params, hist, r, self._fault_delay
            )
        return params, opt_state, hist

    def _eval(self, params, x_test, y_test):
        def node_metrics(p):
            logits = self.forward(p, x_test)
            return accuracy(logits, y_test), confusion_matrix(
                logits, y_test, self.num_classes
            )

        return jax.vmap(node_metrics)(params)

    def _group_eval(self, params, x_test, y_test):
        """Per-node (accuracy, per-group accuracy); used when class_groups set."""

        def node_metrics(p):
            logits = self.forward(p, x_test)
            return accuracy(logits, y_test), group_accuracy(
                logits, y_test, self.class_groups, self.num_groups
            )

        return jax.vmap(node_metrics)(params)

    def _fused_chunk(
        self, program, data, params, opt_state, cstate, hist, start,
        x_test, y_test, *, length: int, do_eval: bool,
    ):
        """``length`` rounds as one lax.scan, plus (optionally) one eval.

        ``program`` is the engine's MixingProgram (all schedule periods
        staged), ``data`` the loader's DeviceData; batch indices are
        generated inside the scan from ``(data.key, round)`` — the same
        draws the Python loop makes on the host. ``hist`` is the straggler
        ring buffer for faulted programs (``()`` when unused) and rides the
        scan carry, so a faulty run — dead-node freezes, renormalized
        mixing, stale snapshots and all — stays one compiled program.
        """
        steps = self.loader.steps_per_epoch() * self.local_epochs
        if program.kind == "sparse_sharded":
            params, opt_state, cstate, hist = self._scan_rounds_sharded(
                program, data, params, opt_state, cstate, hist, start,
                length=length, steps=steps,
            )
            if not do_eval:
                return params, opt_state, cstate, hist, None
            if self.class_groups is not None:
                accs, gaccs = self._group_eval(params, x_test, y_test)
            else:
                accs, _ = self._eval(params, x_test, y_test)
                gaccs = None
            cons = consensus_distance(params)
            return params, opt_state, cstate, hist, (accs, gaccs, cons)
        node = jnp.arange(self.num_nodes)
        hoist = (
            length * steps * self.num_nodes * self.loader.batch
            <= _IDX_HOIST_MAX_ELEMS
        )

        def one_round(carry, x):
            params, opt, cstate, hist = carry
            if hoist:
                r, idx = x
            else:
                r = x
                idx = round_batch_indices(
                    data.key, r, steps, self.loader.batch, data.sizes
                )

            def one_step(c, idx_s):
                p, o = c
                rows = data.parts[node[:, None], idx_s]  # (N, B) bank rows
                x = data.x[rows]
                y = data.y[rows]

                def node_loss(pp, xb, yb):
                    return softmax_xent(self.forward(pp, xb), yb)

                grads = jax.vmap(jax.grad(node_loss))(p, x, y)
                p, o = sgd.update(grads, o, p, lr=self.lr, mu=self.mu)
                return (p, o), None

            p_in, o_in = params, opt
            (params, opt), _ = jax.lax.scan(one_step, (params, opt), idx)
            if self.faulted:
                from repro.core import faults as faults_mod

                alive = program.f_alive[r]
                params = faults_mod.where_alive(alive, params, p_in)
                opt = faults_mod.where_alive(alive, opt, o_in)
                pub = None
                if self._has_hist:
                    pub, hist = faults_mod.push_and_publish(
                        params, hist, r, program.f_delay
                    )
                params = program.mix_at(params, r, pub)
            elif self.compress is None:
                params = program.mix_at(params, r)
            else:
                # Compression state must advance only on gossip rounds (the
                # loop path's non-gossip rounds never touch it).
                def do(args):
                    p, cs = args
                    return self._gossip(lambda q: program.apply(q, r), p, cs)

                if program.cadence == "always":
                    params, cstate = do((params, cstate))
                elif program.cadence == "mask":
                    params, cstate = jax.lax.cond(
                        program.gossip_mask[r], do, lambda a: a, (params, cstate)
                    )
            return (params, opt, cstate, hist), None

        rs = start + jnp.arange(length)
        if hoist:
            idx_all = jax.vmap(
                lambda r: round_batch_indices(
                    data.key, r, steps, self.loader.batch, data.sizes
                )
            )(rs)
            xs = (rs, idx_all)
        else:
            xs = rs
        (params, opt_state, cstate, hist), _ = jax.lax.scan(
            one_round, (params, opt_state, cstate, hist), xs
        )
        if not do_eval:
            return params, opt_state, cstate, hist, None
        if self.class_groups is not None:
            accs, gaccs = self._group_eval(params, x_test, y_test)
        else:
            accs, _ = self._eval(params, x_test, y_test)
            gaccs = None
        cons = consensus_distance(params)
        return params, opt_state, cstate, hist, (accs, gaccs, cons)

    def _scan_rounds_sharded(
        self, program, data, params, opt_state, cstate, hist, start,
        *, length, steps,
    ):
        """``length`` rounds with the node axis sharded END TO END.

        ONE ``shard_map`` wraps the whole ``lax.scan``: each device trains
        its N/S-node slab and the only cross-device traffic per round is the
        mix's halo exchange (``program.apply_local``). The alternative — a
        shard_map per mix *inside* the scan — turns the chunk into an SPMD
        program whose train step runs replicated on every device and whose
        carry is resharded at each iteration boundary: measured ~5x slower
        than the Python loop at N=256 over 8 host devices, where this layout
        is faster than the loop. Numerics are unchanged: the per-node train
        step is elementwise over nodes, batch indices are the same
        replicated draws sliced per slab, and the mix body is the same code
        the loop path runs.
        """
        from repro.core.decavg import _shard_map

        axes = (
            (program.node_axis,) if isinstance(program.node_axis, str)
            else tuple(program.node_axis)
        )
        blk = self.num_nodes // program.shards
        batch = self.loader.batch

        hoist = length * steps * self.num_nodes * batch <= _IDX_HOIST_MAX_ELEMS

        def local_scan(program, data, start, params, opt, cstate, hist):
            sidx = jax.lax.axis_index(axes)
            gnode = sidx * blk + jnp.arange(blk)  # slab's global node ids
            if self.faulted:
                from repro.core import faults as faults_mod

                # Static per-node staleness, pre-sliced to this slab once.
                delay_s = jax.lax.dynamic_slice_in_dim(
                    program.f_delay, sidx * blk, blk
                )

            def one_round(carry, x):
                params, opt, cstate, hist = carry
                if hoist:
                    r, idx = x
                else:
                    # The full (steps, N, B) index tensor is integer-only
                    # and tiny; every device computes it replicated
                    # (identical to the host/loop draws) and slices its own
                    # slab's rows.
                    r = x
                    idx = round_batch_indices(
                        data.key, r, steps, batch, data.sizes
                    )
                    idx = jax.lax.dynamic_slice_in_dim(
                        idx, sidx * blk, blk, axis=1
                    )

                def one_step(c, idx_s):
                    p, o = c
                    rows = data.parts[gnode[:, None], idx_s]  # (blk, B)
                    x = data.x[rows]
                    y = data.y[rows]

                    def node_loss(pp, xb, yb):
                        return softmax_xent(self.forward(pp, xb), yb)

                    grads = jax.vmap(jax.grad(node_loss))(p, x, y)
                    p, o = sgd.update(grads, o, p, lr=self.lr, mu=self.mu)
                    return (p, o), None

                p_in, o_in = params, opt
                (params, opt), _ = jax.lax.scan(one_step, (params, opt), idx)
                if self.faulted:
                    # Slab view of the global masks; mixing still sees the
                    # full alive vector via mix_at_local's own slicing.
                    alive_s = jax.lax.dynamic_slice_in_dim(
                        program.f_alive[r], sidx * blk, blk
                    )
                    params = faults_mod.where_alive(alive_s, params, p_in)
                    opt = faults_mod.where_alive(alive_s, opt, o_in)
                    pub = None
                    if self._has_hist:
                        pub, hist = faults_mod.push_and_publish(
                            params, hist, r, delay_s
                        )
                    params = program.mix_at_local(params, r, pub)
                elif self.compress is None:
                    params = program.mix_at_local(params, r)
                else:
                    def do(args):
                        p, cs = args
                        return self._gossip(
                            lambda q: program.apply_local(q, r), p, cs
                        )

                    if program.cadence == "always":
                        params, cstate = do((params, cstate))
                    elif program.cadence == "mask":
                        params, cstate = jax.lax.cond(
                            program.gossip_mask[r], do, lambda a: a,
                            (params, cstate),
                        )
                return (params, opt, cstate, hist), None

            rs = start + jnp.arange(length)
            if hoist:
                # One vmapped draw for the whole chunk (bit-identical to
                # the per-round draws), pre-sliced to this device's slab so
                # the staged xs tensor is 1/S the replicated size.
                idx_all = jax.vmap(
                    lambda r: round_batch_indices(
                        data.key, r, steps, batch, data.sizes
                    )
                )(rs)
                idx_all = jax.lax.dynamic_slice_in_dim(
                    idx_all, sidx * blk, blk, axis=2
                )
                xs = (rs, idx_all)
            else:
                xs = rs
            (params, opt, cstate, hist), _ = jax.lax.scan(
                one_round, (params, opt, cstate, hist), xs
            )
            return params, opt, cstate, hist

        def node_specs(tree):
            return jax.tree.map(
                lambda l: P(axes, *([None] * (l.ndim - 1))), tree
            )

        pspec = node_specs(params)
        ospec = node_specs(opt_state)
        cspec = node_specs(cstate)
        hspec = node_specs(hist)
        return _shard_map(
            local_scan, mesh=program.mesh,
            in_specs=(P(), P(), P(), pspec, ospec, cspec, hspec),
            out_specs=(pspec, ospec, cspec, hspec),
        )(program, data, start, params, opt_state, cstate, hist)

    def _jit_for_period(self, period: int):
        """The round step for a new schedule period.

        Operand backends reuse the one compiled program (the new W/CSR is
        just a new argument value; a different per-period nnz re-traces by
        shape, cached). Engine-held backends (sparse_pallas, sharded,
        permute, ...) bake period state in at trace time, so they get one
        jitted closure per period, cached across repeat visits/runs.
        """
        if self.mix_impl in _OPERAND_BACKENDS:
            return self._round_jit
        jitted = self._round_jit_cache.get(period)
        if jitted is None:
            # NOT jax.jit(self._round): equal bound methods share one pjit
            # cache entry, so a "fresh" jit after a period change would
            # silently reuse the executable traced with the previous
            # period's engine state. A nested function is a distinct cache
            # key, forcing the retrace that bakes in the new period.
            def _round_fn(op, params, opt_state, cstate, xs, ys):
                return self._round(op, params, opt_state, cstate, xs, ys)

            jitted = jax.jit(_round_fn, donate_argnums=(1, 2, 3))
            if len(self._round_jit_cache) >= 64:
                # Bound compiled-program memory on long @regen runs (same cap
                # as the engine's coloring cache); re-entering an evicted
                # period just pays one re-jit.
                self._round_jit_cache.pop(next(iter(self._round_jit_cache)))
            self._round_jit_cache[period] = jitted
        return jitted

    # -- public API ---------------------------------------------------------

    @property
    def supports_fused(self) -> bool:
        """True when ``run_fused`` can execute this trainer's backend."""
        return self.mix_impl in _FUSED_BACKENDS

    def eval_round(self, r: int, x_test, y_test, t0: float) -> RoundMetrics:
        """One evaluation pass over the current params as a RoundMetrics."""
        x_test, y_test = jnp.asarray(x_test), jnp.asarray(y_test)
        group_acc = None
        if self.class_groups is not None:
            accs, gaccs = self._group_eval_jit(self.params, x_test, y_test)
            group_acc = np.asarray(gaccs)
        else:
            accs, _ = self._eval_jit(self.params, x_test, y_test)
        accs = np.asarray(accs)
        cons = np.asarray(self._consensus_jit(self.params))
        return RoundMetrics(
            r, accs, float(accs.mean()), float(accs.std()),
            group_acc=group_acc, consensus=cons, wall_s=time.perf_counter() - t0,
        )

    @staticmethod
    def _eval_rounds(rounds: int, eval_every: int) -> list[int]:
        """Rounds after which both run paths evaluate/stream metrics."""
        return [r for r in range(rounds) if r % eval_every == 0 or r == rounds - 1]

    def run(
        self,
        rounds: int,
        *,
        eval_every: int = 1,
        x_test: np.ndarray | None = None,
        y_test: np.ndarray | None = None,
        gossip_first: bool = False,
        verbose: bool = False,
        on_round: Callable[[RoundMetrics], None] | None = None,
    ) -> list[RoundMetrics]:
        """Run communication rounds; returns per-round metrics history.

        ``on_round`` fires after every evaluated round (the experiment
        harness streams each RoundMetrics to its results store instead of
        waiting for the full history).
        """
        history: list[RoundMetrics] = []
        steps = self.loader.steps_per_epoch() * self.local_epochs
        t0 = time.perf_counter()
        if gossip_first:
            if self.faulted:
                raise ValueError(
                    "gossip_first does not compose with faults= (there is no "
                    "round index for the pre-round mix to draw masks from)"
                )
            self.params = self._mix(self._mix_op(), self.params)
        round_jit = self._round_jit
        hist = ()
        if self.faulted:
            from repro.core import faults as faults_mod

            trace = self.engine.fault_trace
            trace.ensure(rounds)
            if self._has_hist:
                hist = faults_mod.init_history(self.params, trace.delay_max + 1)
        for r in range(rounds):
            if self.engine.schedule.is_time_varying and self.engine.refresh(r):
                # New schedule period: fresh W/CSR; one compiled program for
                # operand backends, per-period cached closures for the rest.
                self.w = self.engine.w
                self.graph = self.engine.graph
                round_jit = self._jit_for_period(self.engine.schedule.period_of(r))
            xs, ys = self.loader.sample_round(steps, round=r)
            if self.faulted:
                alive = jnp.asarray(trace.alive(r))
                if self.engine.is_gossip_round(r):
                    self.params, self.opt_state, hist = self._round_faulted_jit(
                        self._mix_op_faulted(), jnp.asarray(self._fault_keep(r)),
                        alive, jnp.int32(r), self.params, self.opt_state, hist,
                        jnp.asarray(xs), jnp.asarray(ys),
                    )
                else:
                    self.params, self.opt_state, hist = self._local_faulted_jit(
                        jnp.int32(r), alive, self.params, self.opt_state, hist,
                        jnp.asarray(xs), jnp.asarray(ys),
                    )
            elif self.engine.is_gossip_round(r):
                self.params, self.opt_state, self.cstate = round_jit(
                    self._mix_op(), self.params, self.opt_state, self.cstate,
                    jnp.asarray(xs), jnp.asarray(ys),
                )
            else:
                self.params, self.opt_state = self._local_jit(
                    self.params, self.opt_state, jnp.asarray(xs), jnp.asarray(ys)
                )
            if x_test is not None and (r % eval_every == 0 or r == rounds - 1):
                m = self.eval_round(r, x_test, y_test, t0)
                history.append(m)
                if on_round is not None:
                    on_round(m)
                if verbose:
                    accs = m.per_node_acc
                    print(
                        f"round {r:4d}  acc mean {accs.mean():.4f} "
                        f"std {accs.std():.4f} min {accs.min():.4f} max {accs.max():.4f}"
                    )
        return history

    def run_fused(
        self,
        rounds: int,
        *,
        eval_every: int = 1,
        x_test: np.ndarray | None = None,
        y_test: np.ndarray | None = None,
        gossip_first: bool = False,
        verbose: bool = False,
        on_round: Callable[[RoundMetrics], None] | None = None,
    ) -> list[RoundMetrics]:
        """``run`` compiled into lax.scan chunks — one dispatch per eval.

        The whole multi-round program runs on device: every schedule period
        is staged up front (``GossipEngine.program``), batches are sampled
        inside the scan from the staged dataset, and ``gossip_every`` is a
        select in the scan body. Rounds are scanned in chunks that end at
        the eval rounds (``r % eval_every == 0`` plus the final round —
        exactly ``run``'s cadence), and each chunk's RoundMetrics streams to
        ``on_round`` before the next chunk launches, so consumers see the
        same callback sequence as the Python loop. Without ``x_test`` the
        entire run is a single scan.

        Same seed => same params and metrics as ``run`` (allclose at f32
        1e-6, bit-identical for the sparse/sparse_sharded backends whose
        loop and fused paths share one CSR construction; pinned by
        tests/test_fused.py and tests/test_fused_sharded.py). Supported for
        the dense, sparse, sparse_pallas and sparse_sharded backends;
        others raise (use ``run``). For sparse_sharded the halo exchange
        (ring ppermutes or allgather) runs inside the scan body, so the
        whole multi-host run is one compiled SPMD program per chunk.
        """
        if not self.supports_fused:
            raise ValueError(
                f"run_fused supports backends {_FUSED_BACKENDS}, not "
                f"{self.mix_impl!r}; use run()"
            )
        if rounds < 1:
            return []
        program = self.engine.program(rounds, kind=self.mix_impl)
        data = self.loader.device_data()
        hist = ()
        if self.faulted and self._has_hist:
            from repro.core import faults as faults_mod

            hist = faults_mod.init_history(self.params, program.delay_max + 1)
        if program.kind == "sparse_sharded":
            # Commit the node-stacked state to its in-scan layout (node axis
            # sharded over the mesh) before the first chunk: the fused chunk
            # both consumes and produces this layout, so without the upfront
            # put the first call compiles for replicated inputs and the
            # second call recompiles for sharded ones.
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as _P

            axes = (
                (program.node_axis,) if isinstance(program.node_axis, str)
                else tuple(program.node_axis)
            )

            def _put(tree):
                return jax.tree.map(
                    lambda l: jax.device_put(
                        l,
                        NamedSharding(
                            program.mesh, _P(axes, *([None] * (l.ndim - 1)))
                        ),
                    ),
                    tree,
                )

            self.params = _put(self.params)
            self.opt_state = _put(self.opt_state)
            self.cstate = _put(self.cstate)
            hist = _put(hist)
        t0 = time.perf_counter()
        if gossip_first:
            if self.faulted:
                raise ValueError(
                    "gossip_first does not compose with faults= (there is no "
                    "round index for the pre-round mix to draw masks from)"
                )
            self.params = self._mix(self._mix_op(), self.params)
        do_eval = x_test is not None
        if do_eval:
            x_t, y_t = jnp.asarray(x_test), jnp.asarray(y_test)
            ends = self._eval_rounds(rounds, eval_every)
        else:
            x_t = y_t = None
            ends = [rounds - 1]
        history: list[RoundMetrics] = []
        prev = -1
        for end in ends:
            start, length = prev + 1, end - prev
            prev = end
            (
                self.params, self.opt_state, self.cstate, hist, metrics,
            ) = self._fused_chunk_jit(
                program, data, self.params, self.opt_state, self.cstate, hist,
                jnp.int32(start), x_t, y_t, length=length, do_eval=do_eval,
            )
            if not do_eval:
                continue
            accs, gaccs, cons = metrics
            accs = np.asarray(accs)
            m = RoundMetrics(
                end, accs, float(accs.mean()), float(accs.std()),
                group_acc=None if gaccs is None else np.asarray(gaccs),
                consensus=np.asarray(cons), wall_s=time.perf_counter() - t0,
            )
            history.append(m)
            if on_round is not None:
                on_round(m)
            if verbose:
                print(
                    f"round {end:4d}  acc mean {accs.mean():.4f} "
                    f"std {accs.std():.4f} min {accs.min():.4f} max {accs.max():.4f}"
                )
        return history

    def confusion(self, x_test: np.ndarray, y_test: np.ndarray) -> np.ndarray:
        _, cms = self._eval_jit(self.params, jnp.asarray(x_test), jnp.asarray(y_test))
        return np.asarray(cms)


# ---------------------------------------------------------------------------
# LLM cohorts (model kind "lm"; experiments/runner.py dispatches here)
# ---------------------------------------------------------------------------

# Backends the fused lm scan supports: the program-stageable single-host
# kinds. sparse_sharded's shard_map'd scan is mlp-specific today (the lm
# runner falls back to the loop for it). Must stay a subset of
# _FUSED_BACKENDS (lint rule C001).
_LM_FUSED_BACKENDS = ("dense", "sparse", "sparse_pallas")

# compress="auto" threshold: members whose gossiped pytree exceeds this many
# bytes default to CHOCO top-k gossip so wire volume stays sane (~1 MB — a
# reduced 1B-class member is ~6 MB f32, the tiny test transformers ~100 KB).
_COMPRESS_AUTO_BYTES = 1 << 20
_COMPRESS_AUTO_K = 0.1


class LMCohortTrainer:
    """DecAvg over a cohort of transformer LMs on domain-skewed token streams.

    The lm analogue of ``DecentralizedTrainer``: node-stacked transformer
    params, per-round next-token training (AdamW or SGD under an LR
    schedule), gossip through one ``GossipEngine``. Token batches are a pure
    function of ``(seed, node, round)`` (data/tokens.py), so the two
    execution paths draw bit-identical data:

    - ``run``: one Python iteration per round (jitted train step + eager
      ``engine.mix``) — the debug/fallback path, and the only path for
      backends the MixingProgram can't stage.
    - ``run_fused``: ``lax.scan`` chunks with the schedule's LR, the
      optimizer update, fault freezes and the staged mixing program all
      inside the scan body; each chunk's token slab is staged on device as
      the scan's xs (O(chunk) rounds of tokens live at once). Chunks end at
      eval and checkpoint rounds. Same seed => same params/loss as ``run``
      (tests pin allclose at 1e-6).

    ``compress="auto"`` (default) turns on CHOCO top-k gossip when the
    member pytree exceeds ~1 MB (``_COMPRESS_AUTO_BYTES``); pass a float for
    an explicit k fraction or ``None`` to force raw DecAvg. Faults never
    compose with compression — "auto" resolves to off for faulted runs, an
    explicit fraction raises.

    With ``faults=`` set, dead nodes are frozen bit-exactly — params AND
    optimizer moments (``where_alive_stacked``; AdamW's shared step count
    passes through) — in both paths, matching ``DecentralizedTrainer``'s
    PR 7 contract. Checkpoints save ``(params, opt[, cstate])`` plus the
    step, and ``restore`` resumes bit-identically (round-keyed batches +
    restored moments + the schedule being a pure function of the round).
    """

    def __init__(
        self,
        topology: Graph | TopologySchedule | str,
        cfg,
        *,
        nodes: int,
        batch: int = 4,
        seq: int = 128,
        lr: float = 3e-4,
        schedule: str = "cosine",
        backend: str = "auto",
        matrix: str = "decavg",
        gossip_every: int = 1,
        compress: float | str | None = "auto",
        faults: str | None = None,
        seed: int = 0,
        data_kwargs: dict | None = None,
    ):
        from repro.launch import steps as ST
        from repro.models import transformer as TF
        from repro.optim import adamw

        self.cfg = cfg
        self.num_nodes = int(nodes)
        self.batch, self.seq = int(batch), int(seq)
        self.lr, self.schedule_name, self.seed = lr, schedule, seed
        self.data_kwargs = dict(data_kwargs or {})
        self.engine = decavg.GossipEngine(
            topology, backend=backend, matrix=matrix, gossip_every=gossip_every,
            faults=faults, seed=seed, n=self.num_nodes,
        )
        if self.engine.num_nodes != self.num_nodes:
            raise ValueError(
                f"topology spec pins n={self.engine.num_nodes} but nodes is "
                f"{self.num_nodes}"
            )
        self.mix_impl = self.engine.backend
        self.graph = self.engine.graph
        self.faulted = self.engine.faults is not None

        key = jax.random.PRNGKey(seed)
        per_node = TF.init_params(key, cfg)
        self.member_params = TF.param_count(per_node)
        self.member_bytes = int(
            sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(per_node))
        )
        self.compress = self._resolve_compress(compress)
        self.params = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.num_nodes,) + x.shape).copy(),
            per_node,
        )
        use_adamw = cfg.optimizer == "adamw"
        from repro.optim import sgd as _sgd  # noqa: F401 (module-level import above)

        self.opt_state = adamw.init(self.params) if use_adamw else sgd.init(self.params)
        self.cstate = (
            None if self.compress is None else compress_mod.init(self.params)
        )
        self.start_round = 0  # advanced by restore()
        self._loss_fn = ST.node_loss_fn(cfg)
        self._opt_update = adamw.update if use_adamw else sgd.update
        self._sched = None  # built per run (total_steps = that run's rounds)
        self._eval_data = None
        self._train_jit = jax.jit(self._train, donate_argnums=(0, 1))
        self._train_faulted_jit = jax.jit(self._train_faulted, donate_argnums=(1, 2))
        self._compress_jit = jax.jit(self._compress_refs, donate_argnums=(1,))
        self._choco_apply_jit = jax.jit(self._choco_apply, donate_argnums=(0,))
        self._domain_eval_jit = jax.jit(self._domain_eval)
        self._consensus_jit = jax.jit(consensus_distance)
        self._fused_chunk_jit = jax.jit(
            self._fused_chunk, donate_argnums=(1, 2, 3, 4)
        )
        if self.faulted:
            self._has_hist = self.engine.fault_trace.delay_max > 0

    def _resolve_compress(self, compress) -> float | None:
        if compress == "auto":
            if self.faulted or self.member_bytes <= _COMPRESS_AUTO_BYTES:
                return None
            return _COMPRESS_AUTO_K
        if compress is None or compress is False:
            return None
        k = float(compress)
        if not 0.0 < k <= 1.0:
            raise ValueError(
                f"compress (top-k fraction) must be in (0, 1], got {compress}"
            )
        if self.faulted:
            raise ValueError(
                "faults do not compose with compress= gossip: the CHOCO "
                "reference update assumes every published model is current"
            )
        return k

    # -- jitted bodies ------------------------------------------------------

    def _train(self, params, opt, toks, labels, lr):
        losses, grads = jax.vmap(jax.value_and_grad(self._loss_fn))(
            params, {"tokens": toks, "labels": labels}
        )
        params, opt = self._opt_update(grads, opt, params, lr=lr)
        return params, opt, losses.mean()

    def _train_faulted(self, alive, params, opt, toks, labels, lr):
        """Train + freeze: dead nodes keep pre-round params AND moments
        bit-exactly (equivalent to never training them this round)."""
        from repro.core import faults as faults_mod

        p_in, o_in = params, opt
        params, opt, loss = self._train(params, opt, toks, labels, lr)
        params = faults_mod.where_alive(alive, params, p_in)
        opt = faults_mod.where_alive_stacked(alive, opt, o_in)
        return params, opt, loss

    def _compress_refs(self, params, cstate):
        _, cstate = jax.vmap(
            functools.partial(compress_mod.compress, k_frac=self.compress)
        )(params, cstate)
        return cstate

    @staticmethod
    def _choco_apply(params, mixed, ref):
        return jax.tree.map(
            lambda p, m, r: (p.astype(jnp.float32) + (m - r)).astype(p.dtype),
            params, mixed, ref,
        )

    def _choco_step(self, mix, params, cstate):
        """One CHOCO gossip exchange (cf. DecentralizedTrainer._gossip)."""
        cstate = self._compress_refs(params, cstate)
        ref = cstate.reference
        mixed = mix(ref)
        return self._choco_apply(params, mixed, ref), cstate

    def _domain_eval(self, params, toks, labels):
        """Per-node mean true-token probability on the held-out foreign-domain
        eval batch — ``domain_acc``: expected next-token accuracy under
        sampling decode, the quantity that rises as other nodes' domain
        knowledge reaches this member through gossip."""
        from repro.models import transformer as TF

        def node_eval(p, tk, lb):
            logits, _ = TF.forward(p, self.cfg, tk, remat=False)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            ll = jnp.take_along_axis(logp, lb[..., None], axis=-1)[..., 0]
            return jnp.exp(ll).mean()

        return jax.vmap(node_eval)(params, toks, labels)

    def _fused_chunk(self, program, params, opt, cstate, hist, start, toks, labels):
        """One scan over ``toks.shape[0]`` rounds: grads + optimizer + LR
        schedule + (fault freeze | staged mix | CHOCO gossip) per step.
        Returns the carried state and the per-round mean losses."""
        from repro.core import faults as faults_mod

        def one_round(carry, x):
            params, opt, cstate, hist = carry
            r, tk, lb = x
            lr = self._sched(r)
            p_in, o_in = params, opt
            losses, grads = jax.vmap(jax.value_and_grad(self._loss_fn))(
                params, {"tokens": tk, "labels": lb}
            )
            params, opt = self._opt_update(grads, opt, params, lr=lr)
            if self.faulted:
                alive = program.f_alive[r]
                params = faults_mod.where_alive(alive, params, p_in)
                opt = faults_mod.where_alive_stacked(alive, opt, o_in)
                pub = None
                if self._has_hist:
                    pub, hist = faults_mod.push_and_publish(
                        params, hist, r, program.f_delay
                    )
                params = program.mix_at(params, r, pub)
            elif self.compress is None:
                params = program.mix_at(params, r)
            else:
                # Compression state advances only on gossip rounds (the loop
                # path's non-gossip rounds never touch it).
                def do(args):
                    p, cs = args
                    return self._choco_step(lambda q: program.apply(q, r), p, cs)

                if program.cadence == "always":
                    params, cstate = do((params, cstate))
                elif program.cadence == "mask":
                    params, cstate = jax.lax.cond(
                        program.gossip_mask[r], do, lambda a: a, (params, cstate)
                    )
            return (params, opt, cstate, hist), losses.mean()

        rs = start + jnp.arange(toks.shape[0])
        (params, opt, cstate, hist), losses = jax.lax.scan(
            one_round, (params, opt, cstate, hist), (rs, toks, labels)
        )
        return (params, opt, cstate, hist), losses

    # -- metrics / checkpoint ------------------------------------------------

    def consensus(self) -> np.ndarray:
        return np.asarray(self._consensus_jit(self.params))

    def domain_metrics(self) -> dict:
        """G2-style knowledge-spread metrics on the token task: per-node
        ``domain_acc`` on *other* nodes' domain tokens, and their cohort
        mean ``g2_token_spread`` (the store/analysis join key)."""
        if self.num_nodes < 2:
            return {}
        from repro.data import tokens as tok

        if self._eval_data is None:
            toks, labels = tok.domain_eval_batch(
                self.num_nodes, self.batch, self.seq, self.cfg.vocab_size,
                seed=self.seed,
                **{k: v for k, v in self.data_kwargs.items() if k == "domain_size"},
            )
            self._eval_data = (jnp.asarray(toks), jnp.asarray(labels))
        accs = np.asarray(self._domain_eval_jit(self.params, *self._eval_data))
        return {
            "domain_acc": [round(float(a), 6) for a in accs],
            "g2_token_spread": float(accs.mean()),
        }

    def save(self, path: str, *, step: int) -> None:
        """Checkpoint ``(params, opt[, cstate])`` + step — everything a
        bit-identical resume needs (pre-PR-8 checkpoints saved params only,
        silently restarting AdamW moments on restore)."""
        from repro.checkpoint import ckpt

        tree = {"params": self.params, "opt": self.opt_state}
        if self.cstate is not None:
            tree["cstate"] = self.cstate
        ckpt.save(path, tree, step=step)

    def restore(self, path: str) -> int:
        """Restore a ``save`` checkpoint; the next ``run``/``run_fused``
        continues from the round after the saved step, re-deriving the same
        batches and LR the uninterrupted run would have seen."""
        from repro.checkpoint import ckpt

        if self.faulted and self._has_hist:
            raise ValueError(
                "resume does not compose with straggler faults: the "
                "delayed-snapshot ring buffer is not checkpointed"
            )
        like = {"params": self.params, "opt": self.opt_state}
        if self.cstate is not None:
            like["cstate"] = self.cstate
        tree, step = ckpt.restore(path, like)
        if step is None:
            raise ValueError(f"checkpoint {path!r} carries no step")
        self.params, self.opt_state = tree["params"], tree["opt"]
        if self.cstate is not None:
            self.cstate = tree["cstate"]
        self.start_round = int(step) + 1
        return self.start_round

    @staticmethod
    def _ckpt_rounds(rounds: int, ckpt_every: int) -> set[int]:
        """Checkpoint cadence: every ``ckpt_every`` rounds AND the final
        round (pre-PR-8 the final round was skipped unless divisible)."""
        if not ckpt_every:
            return set()
        s = {r for r in range(1, rounds) if r % ckpt_every == 0}
        s.add(rounds - 1)
        return s

    @property
    def supports_fused(self) -> bool:
        """True when ``run_fused`` can execute this trainer's backend."""
        return self.mix_impl in _LM_FUSED_BACKENDS

    def _round_record(self, r: int, loss, lr, t0: float) -> dict:
        rec = {
            "round": r,
            "loss": float(loss),
            "lr": float(lr),
            "wall_s": round(time.perf_counter() - t0, 4),
            **self.domain_metrics(),
        }
        if self.faulted:
            rec["alive_count"] = int(self.engine.fault_trace.alive(r).sum())
        return rec

    def _finished_resume(self, rounds, on_round, verbose, t0) -> list[dict]:
        """A resume that restored the final checkpoint has nothing left to
        train; still emit one eval record at the restored state so the run's
        final record (loss, spread metrics, wall clock) exists."""
        from repro.data import tokens as tok

        toks, labels = tok.round_token_batch(
            self.num_nodes, rounds - 1, self.batch, self.seq,
            self.cfg.vocab_size, seed=self.seed, **self.data_kwargs,
        )
        losses = jax.vmap(self._loss_fn)(
            self.params,
            {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)},
        )
        rec = self._round_record(
            rounds - 1, losses.mean(), self._sched(rounds - 1), t0
        )
        if on_round is not None:
            on_round(rec)
        if verbose:
            print(
                f"step {rounds - 1:4d}  loss {rec['loss']:.4f}  "
                f"lr {rec['lr']:.2e}  (resume already complete)"
            )
        return [rec]

    # -- run paths ----------------------------------------------------------

    def run(
        self,
        rounds: int,
        *,
        eval_every: int = 1,
        on_round: Callable[[dict], None] | None = None,
        ckpt_every: int = 0,
        ckpt_path: str = "",
        verbose: bool = False,
    ) -> list[dict]:
        """Per-round Python loop (jitted train step + eager engine.mix)."""
        from repro.data import tokens as tok
        from repro.optim import schedules

        self._sched = schedules.get(self.schedule_name, self.lr, rounds)
        if self.start_round >= rounds:
            return self._finished_resume(
                rounds, on_round, verbose, time.perf_counter()
            )
        evals = set(DecentralizedTrainer._eval_rounds(rounds, eval_every))
        cpts = self._ckpt_rounds(rounds, ckpt_every)
        trace = None
        if self.faulted:
            trace = self.engine.fault_trace
            trace.ensure(rounds)
        history: list[dict] = []
        t0 = time.perf_counter()
        for r in range(self.start_round, rounds):
            toks, labels = tok.round_token_batch(
                self.num_nodes, r, self.batch, self.seq, self.cfg.vocab_size,
                seed=self.seed, **self.data_kwargs,
            )
            toks, labels = jnp.asarray(toks), jnp.asarray(labels)
            lr = self._sched(r)
            if self.faulted:
                alive = jnp.asarray(trace.alive(r))
                self.params, self.opt_state, loss = self._train_faulted_jit(
                    alive, self.params, self.opt_state, toks, labels, lr
                )
                # Renormalized faulted mixing + the engine's internal
                # straggler buffer (one mix per round, in order).
                self.params = self.engine.mix(self.params, round=r)
            else:
                self.params, self.opt_state, loss = self._train_jit(
                    self.params, self.opt_state, toks, labels, lr
                )
                if self.compress is None:
                    self.params = self.engine.mix(self.params, round=r)
                elif self.engine.is_gossip_round(r):
                    self.cstate = self._compress_jit(self.params, self.cstate)
                    ref = self.cstate.reference
                    mixed = self.engine.mix(ref, round=r)
                    self.params = self._choco_apply_jit(self.params, mixed, ref)
            if r in evals:
                rec = self._round_record(r, loss, lr, t0)
                history.append(rec)
                if on_round is not None:
                    on_round(rec)
                if verbose:
                    print(
                        f"step {r:4d}  loss {rec['loss']:.4f}  "
                        f"lr {rec['lr']:.2e}  ({rec['wall_s']:.0f}s)"
                    )
            if r in cpts:
                self.save(ckpt_path, step=r)
        return history

    def run_fused(
        self,
        rounds: int,
        *,
        eval_every: int = 1,
        on_round: Callable[[dict], None] | None = None,
        ckpt_every: int = 0,
        ckpt_path: str = "",
        verbose: bool = False,
    ) -> list[dict]:
        """``run`` compiled into ``lax.scan`` chunks — one dispatch per
        eval/checkpoint boundary. Each chunk's token slab is generated on
        the host for just that chunk's rounds and staged as the scan's xs
        (never the full O(rounds·N·B·S) stream)."""
        if not self.supports_fused:
            raise ValueError(
                f"run_fused supports backends {_LM_FUSED_BACKENDS}, not "
                f"{self.mix_impl!r}; use run()"
            )
        from repro.data import tokens as tok
        from repro.optim import schedules

        self._sched = schedules.get(self.schedule_name, self.lr, rounds)
        if self.start_round >= rounds:
            return self._finished_resume(
                rounds, on_round, verbose, time.perf_counter()
            )
        program = self.engine.program(rounds, kind=self.mix_impl)
        hist = ()
        if self.faulted and self._has_hist:
            from repro.core import faults as faults_mod

            hist = faults_mod.init_history(self.params, program.delay_max + 1)
        evals = set(DecentralizedTrainer._eval_rounds(rounds, eval_every))
        cpts = self._ckpt_rounds(rounds, ckpt_every)
        # Chunks end at eval AND checkpoint rounds, so fused checkpoints
        # land at exact round boundaries (bit-identical resume).
        ends = sorted(evals | cpts)
        history: list[dict] = []
        t0 = time.perf_counter()
        prev = self.start_round - 1
        for end in ends:
            if end < self.start_round:
                continue
            start, length = prev + 1, end - prev
            prev = end
            toks, labels = tok.round_token_slab(
                self.num_nodes, range(start, end + 1), self.batch, self.seq,
                self.cfg.vocab_size, seed=self.seed, **self.data_kwargs,
            )
            (
                (self.params, self.opt_state, self.cstate, hist), losses
            ) = self._fused_chunk_jit(
                program, self.params, self.opt_state, self.cstate, hist,
                jnp.int32(start), jnp.asarray(toks), jnp.asarray(labels),
            )
            if end in evals:
                rec = self._round_record(
                    end, np.asarray(losses)[-1], self._sched(end), t0
                )
                history.append(rec)
                if on_round is not None:
                    on_round(rec)
                if verbose:
                    print(
                        f"step {end:4d}  loss {rec['loss']:.4f}  "
                        f"lr {rec['lr']:.2e}  ({rec['wall_s']:.0f}s)"
                    )
            if end in cpts:
                self.save(ckpt_path, step=end)
        return history
