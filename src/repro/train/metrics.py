"""Evaluation metrics: per-node accuracy and confusion matrices (the paper's
two performance figures, §5.1)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def confusion_matrix(logits: jax.Array, labels: jax.Array, num_classes: int) -> jax.Array:
    """Row-normalized confusion matrix: row = true class, col = prediction.
    Rows with no examples are zero."""
    preds = jnp.argmax(logits, axis=-1)
    idx = labels * num_classes + preds
    counts = jnp.bincount(idx.reshape(-1), length=num_classes * num_classes)
    cm = counts.reshape(num_classes, num_classes).astype(jnp.float32)
    row = cm.sum(axis=1, keepdims=True)
    return cm / jnp.maximum(row, 1.0)


def community_confusion(
    per_node_cm: jax.Array, blocks: jax.Array, num_comms: int
) -> jax.Array:
    """Average per-node confusion matrices within each community
    (paper Table 1). per_node_cm: (N, C, C); blocks: (N,) int."""
    out = []
    for c in range(num_comms):
        mask = (blocks == c).astype(jnp.float32)
        w = mask / jnp.maximum(mask.sum(), 1.0)
        out.append(jnp.einsum("n,nij->ij", w, per_node_cm))
    return jnp.stack(out)
