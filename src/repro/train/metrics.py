"""Evaluation metrics: per-node accuracy, class-group ("knowledge spread")
accuracy, confusion matrices and consensus distance (the paper's performance
figures, §5.1, plus the quantities the experiment harness streams per round)."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def group_accuracy(
    logits: jax.Array, labels: jax.Array, class_groups: jax.Array, num_groups: int
) -> jax.Array:
    """(G,) accuracy restricted to each class group, for one node.

    ``class_groups`` maps class id -> group id. Groups with no test examples
    report 0 (they contribute nothing meaningful; callers mask if needed).
    """
    correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    g = class_groups[labels]
    num = jax.ops.segment_sum(correct, g, num_segments=num_groups)
    den = jax.ops.segment_sum(jnp.ones_like(correct), g, num_segments=num_groups)
    return num / jnp.maximum(den, 1.0)


def consensus_distance(params: PyTree) -> jax.Array:
    """(N,) per-node L2 distance to the node-mean model, ||theta_i - theta_bar||.

    The quantity the mixing matrix's spectral gap contracts per gossip round;
    the experiment harness streams its mean/max per round to relate topology
    to knowledge-spread speed. An empty pytree has no node axis to read N
    from, so it yields a (0,) array rather than raising.
    """
    total = None
    for leaf in jax.tree.leaves(params):
        f = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
        sq = jnp.sum((f - f.mean(axis=0, keepdims=True)) ** 2, axis=1)
        total = sq if total is None else total + sq
    if total is None:
        return jnp.zeros((0,), jnp.float32)
    return jnp.sqrt(total)


def confusion_matrix(logits: jax.Array, labels: jax.Array, num_classes: int) -> jax.Array:
    """Row-normalized confusion matrix: row = true class, col = prediction.
    Rows with no examples are zero."""
    preds = jnp.argmax(logits, axis=-1)
    idx = labels * num_classes + preds
    counts = jnp.bincount(idx.reshape(-1), length=num_classes * num_classes)
    cm = counts.reshape(num_classes, num_classes).astype(jnp.float32)
    row = cm.sum(axis=1, keepdims=True)
    return cm / jnp.maximum(row, 1.0)


def community_confusion(
    per_node_cm: jax.Array, blocks: jax.Array, num_comms: int
) -> jax.Array:
    """Average per-node confusion matrices within each community
    (paper Table 1). per_node_cm: (N, C, C); blocks: (N,) int."""
    out = []
    for c in range(num_comms):
        mask = (blocks == c).astype(jnp.float32)
        w = mask / jnp.maximum(mask.sum(), 1.0)
        out.append(jnp.einsum("n,nij->ij", w, per_node_cm))
    return jnp.stack(out)
