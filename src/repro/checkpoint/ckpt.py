"""Minimal dependency-free pytree checkpointing (npz + structure manifest).

Orbax is not available offline; this covers the framework's needs: periodic
save of (params, opt_state, step) for the decentralized trainer and the
examples, with exact-roundtrip restore (dtypes — including bfloat16 — and
tree structure preserved).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            out[key] = arr.view(np.uint16)
            out[f"__bf16__{key}"] = np.asarray(True)
        else:
            out[key] = arr
    return out


def save(path: str, tree: PyTree, *, step: int | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    meta = {"treedef": str(treedef), "step": step}
    np.savez(path, __meta__=json.dumps(meta), **flat)


def restore(path: str, like: PyTree) -> tuple[PyTree, int | None]:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    if not path.endswith(".npz"):
        path += ".npz"
    data = np.load(path, allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pth, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
        arr = data[key]
        if f"__bf16__{key}" in data.files:
            arr = arr.view(jnp.bfloat16)
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta.get("step")


def restore_subtree(path: str, like: PyTree, *, prefix: str) -> tuple[PyTree, int | None]:
    """Restore ONE top-level subtree (e.g. ``prefix="params"``) of a saved
    tree into the structure of ``like``.

    ``np.load`` on an npz is lazy — zip members decompress on access — so
    this never materializes the other subtrees: serving loads params from a
    trainer checkpoint without paying for the AdamW moments (which double
    the resident size of the full ``restore``).
    """
    if not path.endswith(".npz"):
        path += ".npz"
    data = np.load(path, allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pth, leaf in flat:
        key = _SEP.join(
            [prefix] + [str(getattr(p, "key", getattr(p, "idx", p))) for p in pth]
        )
        if key not in data.files:
            raise KeyError(
                f"{key!r} not in checkpoint {path} — available top-level "
                f"prefixes: {sorted({f.split(_SEP)[0] for f in data.files if not f.startswith('__')})}"
            )
        arr = data[key]
        if f"__bf16__{key}" in data.files:
            arr = arr.view(jnp.bfloat16)
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta.get("step")
