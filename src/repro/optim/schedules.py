"""LR schedules: constant, cosine, and WSD (warmup-stable-decay — the
minicpm-2b schedule, [arXiv:2404.06395])."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, total_steps: int, *, warmup: int = 0, final_frac: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * warm * cos

    return fn


def wsd(lr: float, total_steps: int, *, warmup_frac: float = 0.01, decay_frac: float = 0.1):
    """Warmup-Stable-Decay: linear warmup, long flat stage, short exponential
    decay tail (the last ``decay_frac`` of training) — per MiniCPM."""
    warmup = max(1, int(warmup_frac * total_steps))
    decay_start = int((1.0 - decay_frac) * total_steps)

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / warmup, 1.0)
        in_decay = jnp.maximum(step - decay_start, 0.0)
        span = jnp.maximum(total_steps - decay_start, 1)
        decay = jnp.power(10.0, -2.0 * in_decay / span)  # 100x down over the tail
        return lr * warm * decay

    return fn


def get(name: str, lr: float, total_steps: int):
    if name == "const":
        return constant(lr)
    if name == "cosine":
        return cosine(lr, total_steps)
    if name == "wsd":
        return wsd(lr, total_steps)
    raise ValueError(f"unknown schedule {name!r}")
