"""AdamW for the LLM-cohort training path. State dtype follows the config's
``opt_dtype`` (bf16 moments for the 480B arch, DESIGN §4)."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    mu: PyTree
    nu: PyTree
    count: jax.Array


def init(params: PyTree, *, dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def update(
    grads: PyTree,
    state: AdamWState,
    params: PyTree,
    *,
    lr: float | jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> tuple[PyTree, AdamWState]:
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    new_mu = jax.tree.map(
        lambda g, m: (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype),
        grads,
        state.mu,
    )
    new_nu = jax.tree.map(
        lambda g, v: (
            b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))
        ).astype(v.dtype),
        grads,
        state.nu,
    )

    def new_p(p, m, v):
        step = lr * (m.astype(jnp.float32) / c1) / (
            jnp.sqrt(v.astype(jnp.float32) / c2) + eps
        )
        return (p.astype(jnp.float32) * (1.0 - lr * weight_decay) - step).astype(p.dtype)

    return jax.tree.map(new_p, params, new_mu, new_nu), AdamWState(new_mu, new_nu, count)
