"""SGD with momentum (the paper's optimizer: lr=1e-3, mu=0.5) — pure pytree
functions so state vmaps/shards over the node axis like params do."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class SGDState(NamedTuple):
    momentum: PyTree


def init(params: PyTree, *, dtype=None) -> SGDState:
    return SGDState(
        momentum=jax.tree.map(
            lambda p: jnp.zeros(p.shape, dtype or jnp.float32), params
        )
    )


def update(
    grads: PyTree,
    state: SGDState,
    params: PyTree,
    *,
    lr: float | jax.Array,
    mu: float = 0.5,
    weight_decay: float = 0.0,
) -> tuple[PyTree, SGDState]:
    def new_m(g, m, p):
        gf = g.astype(m.dtype)
        if weight_decay:
            gf = gf + weight_decay * p.astype(m.dtype)
        return mu * m + gf

    new_mom = jax.tree.map(new_m, grads, state.momentum, params)

    def step(p, m):
        # Update math in the momentum dtype: with bf16 optimizer state
        # (>=100B archs) an f32 round-trip would allocate param-sized f32
        # temporaries — several GB/device at mistral-123b scale.
        ct = m.dtype
        return (p.astype(ct) - jnp.asarray(lr, ct) * m).astype(p.dtype)

    new_params = jax.tree.map(step, params, new_mom)
    return new_params, SGDState(new_mom)
