"""Serving-stack benchmark: chunked prefill speed, continuous-batching
token identity, and the topology-aware routing delta.

Three sections, one JSON (BENCH_serve.json at the repo root):

1. ``prefill`` rows — chunked full-sequence prefill
   (``transformer.prefill_forward``: one forward writes the whole KV cache)
   vs the token-at-a-time ``lax.scan`` reference (``prefill_sequential``),
   both jitted, best-of-N wall clock after a compile warm-up. The chunked
   path replaces S sequential attention dispatches with one batched forward,
   so the gap grows with prompt length; CI guards >= 5x at seq >= 128.

2. ``engine`` row — the continuous-batching ``Engine`` (staggered arrivals,
   fewer slots than requests) must emit exactly the tokens the sequential
   ``decode.generate`` emits for each prompt alone at temperature 0
   (``token_identical``, CI-guarded). Also reports engine tokens/s.

3. ``serve_eval`` row — ``experiments.serve_eval``: train a gossip cohort on
   a star, reload through the params-only checkpoint path, and replay a
   shuffled domain-query stream. CI guards serve_acc[best] >
   serve_acc[round_robin] (the topology-aware router must beat the
   topology-blind baseline).

Run:  PYTHONPATH=src python benchmarks/bench_serve.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgbase
from repro.core.machine import machine_fingerprint
from repro.models import transformer as TF
from repro.serve import decode as SD
from repro.serve.engine import Engine

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

ARCH = "llama32_1b"
BATCH = 2
DECODE_STEPS = 8  # decode tail appended after each timed prefill


def _best_of(fn, reps: int = 3) -> float:
    """Best-of-``reps`` wall clock; ``fn`` must block on its outputs."""
    fn()  # warm-up: pays the compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_prefill(cfg, params, seq: int) -> dict:
    prompt = jax.random.randint(jax.random.PRNGKey(1), (BATCH, seq), 0, cfg.vocab_size)
    cache_len = seq + DECODE_STEPS

    chunked = jax.jit(
        lambda p, t, c: SD.prefill(p, cfg, t, c, flash=False), donate_argnums=(2,)
    )
    sequential = jax.jit(
        lambda p, t, c: SD.prefill_sequential(p, cfg, t, c), donate_argnums=(2,)
    )

    def run(fn):
        def go():
            logits, _ = fn(params, prompt, TF.init_cache(cfg, BATCH, cache_len))
            jax.block_until_ready(logits)

        return go

    chunk_s = _best_of(run(chunked))
    seq_s = _best_of(run(sequential))
    row = {
        "seq": seq,
        "batch": BATCH,
        "chunked_ms": round(chunk_s * 1e3, 2),
        "sequential_ms": round(seq_s * 1e3, 2),
        "speedup": round(seq_s / chunk_s, 2),
        "prompt_tokens_per_s": round(BATCH * seq / chunk_s, 1),
    }
    print(
        f"prefill seq={seq:4d} chunked {row['chunked_ms']:8.2f} ms   "
        f"sequential {row['sequential_ms']:8.2f} ms   speedup {row['speedup']:.2f}x"
    )
    return row


def bench_engine(cfg, params) -> dict:
    """Staggered arrivals through 2 slots vs per-prompt sequential generate."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 11, 3, 8, 6)]
    max_new = [8, 6, 8, 5, 7]
    cache_len = 64

    def drive():
        eng = Engine(params, cfg, slots=2, cache_len=cache_len, flash=False)
        rids = [eng.submit(p, max_new=m) for p, m in zip(prompts[:3], max_new[:3])]
        eng.step()  # late arrivals land mid-flight
        rids += [eng.submit(p, max_new=m) for p, m in zip(prompts[3:], max_new[3:])]
        return rids, eng.run()

    rids, out = drive()  # warm-up run doubles as the correctness run
    identical = True
    for rid, p, m in zip(rids, prompts, max_new):
        want = SD.generate(
            params, cfg, jnp.asarray(p)[None], TF.init_cache(cfg, 1, cache_len),
            steps=m, key=jax.random.PRNGKey(0),
        )
        identical &= bool(np.array_equal(out[rid], np.asarray(want)[0]))

    total_toks = sum(max_new)
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        drive()
        best = min(best, time.perf_counter() - t0)
    row = {
        "slots": 2,
        "requests": len(prompts),
        "generated_tokens": total_toks,
        "token_identical": identical,
        "tokens_per_s": round(total_toks / best, 1),
    }
    print(
        f"engine  {len(prompts)} reqs / 2 slots   identical={identical}   "
        f"{row['tokens_per_s']:.1f} tok/s"
    )
    return row


def bench_serve_eval(rounds: int) -> dict:
    from repro.experiments.serve_eval import run_serve_eval

    summary = run_serve_eval(rounds=rounds)
    row = {
        "topology": summary["topology"],
        "rounds": summary["rounds"],
        "serve_acc": summary["serve_acc"],
        "hub_share_foreign": summary["hub_share_foreign"],
        "router_beats_round_robin": summary["checks"]["router_beats_round_robin"],
    }
    print(
        f"serve_eval best {row['serve_acc']['best']:.6f}   "
        f"round_robin {row['serve_acc']['round_robin']:.6f}   "
        f"beats_rr={row['router_beats_round_robin']}"
    )
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument("--eval-rounds", type=int, default=200)
    ap.add_argument(
        "--quick", action="store_true",
        help="skip the seq=256 prefill row and shorten serve_eval",
    )
    args = ap.parse_args()

    cfg = cfgbase.get(ARCH).reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)

    seqs = [32, 128] if args.quick else [32, 128, 256]
    prefill_rows = [bench_prefill(cfg, params, s) for s in seqs]
    engine_row = bench_engine(cfg, params)
    eval_row = bench_serve_eval(60 if args.quick else args.eval_rounds)

    out = {
        "bench": "serving stack: prefill / continuous batching / routing "
                 "(benchmarks/bench_serve.py)",
        "device": str(jax.devices()[0]),
        "machine": machine_fingerprint(),
        "arch": cfg.arch_id,
        "prefill": prefill_rows,
        "engine": engine_row,
        "serve_eval": eval_row,
        "checks": {
            "prefill_speedup_128": next(
                r["speedup"] for r in prefill_rows if r["seq"] == 128
            ),
            "engine_token_identical": engine_row["token_identical"],
            "router_beats_round_robin": eval_row["router_beats_round_robin"],
        },
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
