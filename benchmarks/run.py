"""Benchmark harness — one entry per paper table/figure plus kernel and
roofline benches. Prints ``name,us_per_call,derived`` CSV lines.

Default mode is quick (reduced rounds/nodes, same structure) so the harness
completes in minutes; ``--full`` reproduces the EXPERIMENTS.md configuration
(hours — run in the background). The dry-run/roofline rows are read from
results/dryrun_baseline.jsonl (produced by ``python -m repro.launch.dryrun
--all``).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(__file__))

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _csv(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


# --- paper figures ---------------------------------------------------------


def bench_fig1_3_er(full: bool) -> None:
    from paper_experiments import ExpSettings, er_experiments

    s = ExpSettings() if full else ExpSettings.quick()
    t0 = time.time()
    outs = er_experiments(s)
    us = (time.time() - t0) * 1e6 / max(len(outs), 1)
    # derived: the paper's claim — hub-focus beats edge-focus on mean accuracy
    hub = np.mean([o["final_mean_acc"] for o, _ in outs if o["extra"]["focus"] == "hub"])
    edge = np.mean([o["final_mean_acc"] for o, _ in outs if o["extra"]["focus"] == "edge"])
    _csv("fig1-3_er_accuracy", us, f"hub_mean={hub:.4f};edge_mean={edge:.4f};hub>edge={hub > edge}")


def bench_fig4_6_ba(full: bool) -> None:
    from paper_experiments import ExpSettings, ba_experiments

    s = ExpSettings() if full else ExpSettings.quick()
    t0 = time.time()
    outs = ba_experiments(s)
    us = (time.time() - t0) * 1e6 / max(len(outs), 1)
    hub = [o["final_mean_acc"] for o, _ in outs if o["extra"]["focus"] == "hub"]
    edge = np.mean([o["final_mean_acc"] for o, _ in outs if o["extra"]["focus"] == "edge"])
    spread = max(hub) - min(hub) if hub else 0.0
    _csv(
        "fig4-6_ba_accuracy", us,
        f"hub_m_spread={spread:.4f};edge_mean={edge:.4f};hub_m_insensitive={spread < 0.05}",
    )


def bench_fig7_table1_sbm(full: bool) -> None:
    from paper_experiments import ExpSettings, sbm_experiments

    s = ExpSettings() if full else ExpSettings.quick()
    t0 = time.time()
    outs = sbm_experiments(s)
    us = (time.time() - t0) * 1e6 / max(len(outs), 1)
    acc = {o[0]["extra"]["p_in"]: o[0]["final_mean_acc"] for o in outs}
    _csv(
        "fig7_table1_sbm", us,
        f"acc_pin0.5={acc.get(0.5, 0):.4f};acc_pin0.8={acc.get(0.8, 0):.4f};"
        f"loose>tight={acc.get(0.5, 0) > acc.get(0.8, 0)}",
    )


# --- kernel + core micro-benches ------------------------------------------


def bench_gossip_kernel(full: bool) -> None:
    """Pallas gossip_mix (interpret on CPU) vs XLA dense mix: correctness
    cost + per-call time. On-TPU timing is N/A in this container; the derived
    column reports max|err| vs the oracle."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    n, d = (128, 1 << 16) if full else (128, 4096)
    key = jax.random.PRNGKey(0)
    w = jax.nn.softmax(jax.random.normal(key, (n, n)), -1)
    p = jax.random.normal(jax.random.fold_in(key, 1), (n, d), jnp.float32)

    out_k = ops.gossip_mix(w, p, interpret=True)
    err = float(jnp.max(jnp.abs(out_k - ref.gossip_mix_ref(w, p))))

    f = jax.jit(lambda w, p: ref.gossip_mix_ref(w, p))
    f(w, p).block_until_ready()
    t0 = time.time()
    reps = 20
    for _ in range(reps):
        f(w, p).block_until_ready()
    us = (time.time() - t0) * 1e6 / reps
    _csv("gossip_mix_kernel", us, f"max_err_vs_ref={err:.2e};timing=xla_dense_equivalent")


def bench_decavg_round(full: bool) -> None:
    """One full DecAvg round (local steps + gossip) wall time."""
    from repro.core import partition as P, topology as T
    from repro.data.loader import NodeLoader
    from repro.data.synthetic import make_mnist_like
    from repro.train.trainer import DecentralizedTrainer

    ds = make_mnist_like(train_per_class=200, test_per_class=20, seed=0)
    g = T.make(f"er:n={100 if full else 40},p=0.05", seed=0)
    parts = P.iid(ds.y_train, g.num_nodes, seed=1)
    loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=32, seed=2)
    tr = DecentralizedTrainer(g, loader)
    tr.run(1)  # compile
    t0 = time.time()
    reps = 5
    tr.run(reps)
    us = (time.time() - t0) * 1e6 / reps
    _csv("decavg_round", us, f"nodes={g.num_nodes};params_per_node=0.57M")


# --- roofline/dry-run reader ------------------------------------------------


def bench_roofline(full: bool) -> None:
    path = os.path.join(RESULTS, "dryrun_baseline.jsonl")
    if not os.path.exists(path):
        _csv("roofline_table", 0.0, "missing:run `python -m repro.launch.dryrun --all` first")
        return
    rows = [json.loads(l) for l in open(path)]
    ok = [r for r in rows if r.get("status") == "ok"]
    doms: dict[str, int] = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    dom_str = "/".join(f"{k}:{v}" for k, v in sorted(doms.items()))
    _csv("roofline_table", 0.0, f"combinations_ok={len(ok)}of{len(rows)};dominant={dom_str}")


BENCHES = {
    "fig1-3_er": bench_fig1_3_er,
    "fig4-6_ba": bench_fig4_6_ba,
    "fig7_table1_sbm": bench_fig7_table1_sbm,
    "gossip_kernel": bench_gossip_kernel,
    "decavg_round": bench_decavg_round,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="EXPERIMENTS.md configuration")
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        fn(args.full)


if __name__ == "__main__":
    main()
