"""Fused-vs-loop training throughput: rounds/s over a full multi-round run.

The Python-loop trainer pays per round: host-side batch gather, a
device transfer, and a jit dispatch (plus a re-trace at every schedule
period before PR 5). ``run_fused`` compiles the whole run into lax.scan
chunks — one dispatch per eval (a single scan when no eval runs) with
batches sampled on device — so the gap between the two is pure
orchestration overhead, the quantity this benchmark pins.

Per row (the acceptance configs are N=100 dense / 200 rounds, and the
N=128 ring sparse_sharded row over 8 fake CPU devices in a subprocess):

  - loop_rounds_per_s / fused_rounds_per_s: whole-run throughput, timed on
    a second run after a warm-up run has paid all compiles.
  - speedup: fused / loop (CI guards >= 2x on the N=100 dense row and the
    sparse_sharded row).
  - max_abs_param_err: fused-vs-loop parameter agreement for the row's
    config (same seed, fresh trainers) — the speed claim is only worth
    reporting if the two paths still compute the same thing. Exactly 0.0
    for sparse / sparse_sharded (shared CSR staging and mix body);
    ~1e-3-scale for sparse_pallas after its row's 20 rounds, whose fused
    blocked kernel and loop scalar kernel sum tiles in different orders
    (~1e-7 per mix, compounded by the SGD rounds in between).

Emits BENCH_rounds.json at the repo root.

Baselines are machine-relative: a 2026-08 same-machine bisect of an apparent
sparse-row "regression" (2.1x -> 1.4x) found PR-era and current HEAD within
noise of each other — the historical figure came from a different runner.
When a row drifts, re-run the OLD commit on the CURRENT machine (git
worktree) before treating the delta as a code regression; CI floors (2x
dense/sharded, 1.2x sparse) are set below same-machine variance. The output
embeds a ``machine`` fingerprint (platform / CPU count / jax version) so a
committed re-baseline records where its numbers came from — never hand-edit
rows; regenerate the whole file with this script.

Run:  PYTHONPATH=src python benchmarks/bench_rounds.py [--rounds 200]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.core import partition as P
from repro.core.machine import machine_fingerprint
from repro.data.loader import NodeLoader
from repro.data.synthetic import make_mnist_like
from repro.models.mlp import init_mlp
from repro.train.trainer import DecentralizedTrainer

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_rounds.json")

# Small members on purpose: the bench isolates per-round *orchestration*
# overhead (host sampling, transfer, dispatch), so per-round compute must not
# drown it. large_n-preset-sized members (hidden=[64]) shift both paths by
# the same compute constant; the fused win converges to 1x as members grow.
DIM = 32
HIDDEN = (32,)
BATCH = 16


# The sharded row runs in a subprocess (8 fake CPU devices need XLA_FLAGS
# set before jax imports) on the paper's canonical ring topology: a regular
# graph keeps the per-shard nnz balanced, so the stacked ShardedCSR pads to
# ~uniform width and the row isolates orchestration overhead rather than
# BA hub skew. halo_schedule stays "auto" (resolves to ring here).
SHARDED_N = 128
SHARDED_SHARDS = 8
SHARDED_ROUNDS = 100


def make_trainer(
    n: int, backend: str, ds, seed: int = 0, topology: str | None = None,
    faults: str | None = None,
) -> DecentralizedTrainer:
    parts = P.iid(ds.y_train, n, seed=seed)
    loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=BATCH, seed=seed)
    return DecentralizedTrainer(
        topology or f"ba:n={n},m=2",
        loader,
        lr=0.05,
        momentum=0.9,
        mix_impl=backend,
        seed=seed,
        faults=faults,
        init_fn=lambda k: init_mlp(k, in_dim=DIM, hidden=HIDDEN, num_classes=10),
    )


def _time_run(run, rounds: int, reps: int = 3) -> float:
    """Best-of-``reps`` whole-run wall clock (after one compile warm-up).

    Best-of, not mean: transient CPU contention on shared runners only ever
    slows a run down, and it biases both paths identically.
    """
    run(rounds)  # warm-up: pays every compile in the path
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        run(rounds)
        jax.block_until_ready(jax.tree.leaves(run.__self__.params))
        best = min(best, time.perf_counter() - t0)
    return best


def _param_err(n: int, backend: str, ds, rounds: int) -> float:
    """Fused-vs-loop divergence over the SAME round count the row reports."""
    a = make_trainer(n, backend, ds)
    a.run(rounds)
    b = make_trainer(n, backend, ds)
    b.run_fused(rounds)
    return max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
        for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params))
    )


def bench_one(n: int, backend: str, rounds: int, ds) -> dict:
    loop_s = _time_run(make_trainer(n, backend, ds).run, rounds)
    fused_s = _time_run(make_trainer(n, backend, ds).run_fused, rounds)
    row = {
        "n": n,
        "backend": backend,
        "rounds": rounds,
        "loop_rounds_per_s": round(rounds / loop_s, 1),
        "fused_rounds_per_s": round(rounds / fused_s, 1),
        "speedup": round(loop_s / fused_s, 2),
        "max_abs_param_err": _param_err(n, backend, ds, rounds),
    }
    print(
        f"n={n:4d} {backend:6s} loop {row['loop_rounds_per_s']:8.1f} r/s   "
        f"fused {row['fused_rounds_per_s']:8.1f} r/s   "
        f"speedup {row['speedup']:.2f}x   err {row['max_abs_param_err']:.2e}"
    )
    return row


def _sharded_worker() -> None:
    """Runs in a subprocess with 8 fake CPU devices; prints one JSON row.

    Fused and loop reps are interleaved (fused, loop, fused, loop, ...) so
    transient load hits both paths alike, and best-of is still the
    estimator. max_abs_param_err must be exactly 0.0: both paths run the
    same ``_sharded_mix_leaf`` body on the same staged ShardedCSR.
    """
    ds = make_mnist_like(train_per_class=200, test_per_class=50, dim=DIM, seed=0)
    topo = f"ring:n={SHARDED_N}"
    rounds = SHARDED_ROUNDS
    fused = make_trainer(SHARDED_N, "sparse_sharded", ds, topology=topo)
    loop = make_trainer(SHARDED_N, "sparse_sharded", ds, topology=topo)
    shards = fused.engine.program(rounds, kind="sparse_sharded").shards
    fused.run_fused(rounds)  # pays every compile
    loop.run(rounds)
    fused_s = loop_s = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        fused.run_fused(rounds)
        jax.block_until_ready(jax.tree.leaves(fused.params))
        fused_s = min(fused_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        loop.run(rounds)
        jax.block_until_ready(jax.tree.leaves(loop.params))
        loop_s = min(loop_s, time.perf_counter() - t0)
    a = make_trainer(SHARDED_N, "sparse_sharded", ds, topology=topo)
    a.run(rounds)
    b = make_trainer(SHARDED_N, "sparse_sharded", ds, topology=topo)
    b.run_fused(rounds)
    err = max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
        for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params))
    )
    row = {
        "n": SHARDED_N,
        "backend": "sparse_sharded",
        "topology": topo,
        "shards": shards,
        "halo_schedule": "auto",
        "rounds": rounds,
        "loop_rounds_per_s": round(rounds / loop_s, 1),
        "fused_rounds_per_s": round(rounds / fused_s, 1),
        "speedup": round(loop_s / fused_s, 2),
        "max_abs_param_err": err,
    }
    print(json.dumps(row))


# The faulted fused row's fault spec: all three clause kinds active so the
# row pays every mask (per-round renormalization, dead-node where, straggler
# ring buffer) — the worst case the CI overhead guard (<= 1.4x fault-free)
# is meant to bound.
FAULT_SPEC = "churn:p_leave=0.05,p_join=0.5;straggler:frac=0.2,delay=3;drop:p_edge=0.1"


def bench_faulted(n: int, rounds: int, ds) -> dict:
    """Fused dense row under a full fault schedule, vs its fault-free twin.

    ``fault_overhead`` = fault-free fused rounds/s over faulted fused
    rounds/s (>= 1.0 means masking costs throughput; CI guards <= 1.4x).
    The two fused rates are measured INTERLEAVED (clean, faulted, clean,
    ...) rather than reusing the dense row timed minutes earlier: shared
    runners drift over a multi-minute bench run, and a rate ratio is only
    meaningful between adjacent measurements (same estimator as the
    sharded worker's fused/loop interleave).
    """
    loop_s = _time_run(
        make_trainer(n, "dense", ds, faults=FAULT_SPEC).run, rounds
    )
    faulted = make_trainer(n, "dense", ds, faults=FAULT_SPEC)
    clean = make_trainer(n, "dense", ds)
    faulted.run_fused(rounds)  # warm-up: pays every compile in each path
    clean.run_fused(rounds)
    fused_s = clean_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        clean.run_fused(rounds)
        jax.block_until_ready(jax.tree.leaves(clean.params))
        clean_s = min(clean_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        faulted.run_fused(rounds)
        jax.block_until_ready(jax.tree.leaves(faulted.params))
        fused_s = min(fused_s, time.perf_counter() - t0)
    a = make_trainer(n, "dense", ds, faults=FAULT_SPEC)
    a.run(rounds)
    b = make_trainer(n, "dense", ds, faults=FAULT_SPEC)
    b.run_fused(rounds)
    err = max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
        for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params))
    )
    row = {
        "n": n,
        "backend": "dense",
        "faults": FAULT_SPEC,
        "rounds": rounds,
        "loop_rounds_per_s": round(rounds / loop_s, 1),
        "fused_rounds_per_s": round(rounds / fused_s, 1),
        "speedup": round(loop_s / fused_s, 2),
        "fault_overhead": round(fused_s / clean_s, 3),
        "max_abs_param_err": err,
    }
    print(
        f"n={n:4d} dense+faults loop {row['loop_rounds_per_s']:8.1f} r/s   "
        f"fused {row['fused_rounds_per_s']:8.1f} r/s   "
        f"overhead {row['fault_overhead']:.3f}x   err {row['max_abs_param_err']:.2e}"
    )
    return row


def bench_sharded() -> dict:
    """The sparse_sharded row, via a subprocess with an 8-device mesh."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={SHARDED_SHARDS}"
    ).strip()
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--worker-sharded"],
        capture_output=True,
        text=True,
        env=env,
        timeout=1200,
    )
    if r.returncode != 0:
        raise RuntimeError(f"sharded bench worker failed:\n{r.stderr[-2000:]}")
    row = json.loads(r.stdout.strip().splitlines()[-1])
    print(
        f"n={row['n']:4d} {row['backend']:6s} "
        f"loop {row['loop_rounds_per_s']:8.1f} r/s   "
        f"fused {row['fused_rounds_per_s']:8.1f} r/s   "
        f"speedup {row['speedup']:.2f}x   err {row['max_abs_param_err']:.2e}"
        f"   ({row['topology']}, {row['shards']} shards)"
    )
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument(
        "--worker-sharded", action="store_true", help=argparse.SUPPRESS
    )
    args = ap.parse_args()
    if args.worker_sharded:
        _sharded_worker()
        return

    ds = make_mnist_like(train_per_class=200, test_per_class=50, dim=DIM, seed=0)
    rows = [
        # the acceptance row: N=100 dense at the full round count
        bench_one(100, "dense", args.rounds, ds),
        # informational: the sparse program at larger N, fewer rounds
        bench_one(256, "sparse", max(args.rounds // 2, 10), ds),
        # the Pallas blocked-ELL program (interpret mode on CPU, so small
        # and short — the point is the per-round dispatch gap, which the
        # interpreted kernel makes enormous in absolute terms)
        bench_one(64, "sparse_pallas", max(args.rounds // 10, 5), ds),
        # the sharded acceptance row: CI guards >= 2x and err == 0.0
        bench_sharded(),
        # full fault schedule on the dense acceptance config: CI guards
        # fault_overhead <= 1.4x the fault-free fused rate
        bench_faulted(100, args.rounds, ds),
    ]
    out = {
        "bench": "fused vs loop training rounds/s (benchmarks/bench_rounds.py)",
        "device": str(jax.devices()[0]),
        "machine": machine_fingerprint(),
        "config": {
            "topology": "ba:m=2 (rows with a 'topology' key override it)",
            "dim": DIM, "hidden": list(HIDDEN),
            "batch": BATCH, "lr": 0.05, "momentum": 0.9, "eval": "none (pure training)",
        },
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
