"""Render the dry-run JSONL into the EXPERIMENTS.md roofline tables.

Usage: PYTHONPATH=src python benchmarks/roofline_report.py [results/dryrun_baseline.jsonl]
"""

from __future__ import annotations

import json
import sys


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def main(path: str = "results/dryrun_baseline.jsonl") -> None:
    rows = [json.loads(l) for l in open(path)]
    ok = [r for r in rows if r.get("status") == "ok"]
    fails = [r for r in rows if r.get("status") != "ok"]

    print("### Single-pod (16x16 = 256 chips) roofline — all 40 (arch x shape) pairs\n")
    print("| arch | shape | compute | memory | collective | dominant | MODEL_FLOPS | useful | HBM/dev | top collective source |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "16x16":
            continue
        top = max(r["wire_by_kind"], key=r["wire_by_kind"].get) if r["wire_by_kind"] else "-"
        topv = r["wire_by_kind"].get(top, 0.0)
        print(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['model_flops']:.2e} | {r['useful_ratio']:.2f} | "
            f"{r['per_device_hbm_gb']:.1f}GB | {top} ({topv/1e9:.1f}GB) |"
        )

    print("\n### Multi-pod (2x16x16 = 512 chips) — lowering proof\n")
    print("| arch | shape | status | nodes | dominant | HBM/dev | collectives in HLO |")
    print("|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != "2x16x16":
            continue
        if r.get("status") == "ok":
            kinds = ",".join(f"{k}:{v}" for k, v in sorted(r["collective_ops"].items()))
            print(
                f"| {r['arch']} | {r['shape']} | ok | {r['num_nodes']} | "
                f"{r['dominant']} | {r['per_device_hbm_gb']:.1f}GB | {kinds} |"
            )
        else:
            print(f"| {r['arch']} | {r['shape']} | {r['status']} | | | | |")

    if fails:
        print(f"\nFAILURES: {len(fails)}")
    print(f"\nTotal: {len(ok)}/{len(rows)} ok")


if __name__ == "__main__":
    main(*sys.argv[1:])
