"""Fused-vs-loop LM cohort throughput: rounds/s over a full training run.

The lm analogue of bench_rounds.py: the Python-loop ``LMCohortTrainer.run``
pays per round a host-side token generation, a device transfer, and a jit
dispatch plus an eager ``engine.mix``; ``run_fused`` compiles the whole run
into ``lax.scan`` chunks with the chunk's token slab staged as the scan's
xs — one dispatch per eval boundary. The gap is pure orchestration
overhead, the quantity this benchmark pins (CI guards >= 1.5x on the
acceptance row).

Rows (reduced transformer members, CPU-sized):

  - the acceptance row: n=8 ring, dense backend, tiny members so per-round
    compute doesn't drown the dispatch gap;
  - an informational CHOCO row: same config with ``compress=0.25`` — the
    top-k + reference update runs inside the scan body;
  - an informational faulted row: churn masks + renormalized mixing inside
    the scan.

Each row also reports max_abs_param_err for fused-vs-loop on its exact
config (same seed, fresh trainers) — the speed claim is only worth
reporting if both paths still compute the same thing (CI guards <= 1e-6 on
the acceptance row). Agreement is measured over a short horizon
(``agreement_rounds``, default 8) separate from the timed runs: both paths
do the same math in a different operation order, so float drift compounds
round over round (~2e-5 after 40 rounds) and a long-horizon comparison
would measure chaos amplification, not an implementation gap. The tests
(tests/test_lm_fused.py) pin the same 1e-6 bound at comparable horizons.

Emits BENCH_lm_rounds.json at the repo root.

Run:  PYTHONPATH=src python benchmarks/bench_lm_rounds.py [--rounds 40]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.configs import base as cfgbase
from repro.core.machine import machine_fingerprint
from repro.train.trainer import LMCohortTrainer

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_lm_rounds.json")

# Micro transformer members on purpose (the test_system.py reduced config):
# the bench isolates per-round orchestration overhead, so member compute
# must not drown it — the fused win converges to 1x as members grow.
# batch=1 keeps the per-round forward/backward small enough that the
# dispatch gap stays the dominant term on an unloaded CPU.
N_NODES = 8
BATCH = 1
SEQ = 32


def micro_cfg():
    base = cfgbase.get("llama32_1b")
    return dataclasses.replace(
        base.reduced(),
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256,
    )


def make_trainer(cfg, **kw) -> LMCohortTrainer:
    kw.setdefault("compress", None)
    return LMCohortTrainer(
        f"ring:n={N_NODES}", cfg, nodes=N_NODES, batch=BATCH, seq=SEQ,
        lr=1e-3, seed=0, **kw,
    )


def _time_run(run, rounds: int, reps: int = 3) -> float:
    """Best-of-``reps`` whole-run wall clock (after one compile warm-up).

    Best-of, not mean: transient CPU contention on shared runners only ever
    slows a run down, and it biases both paths identically.
    """
    run(rounds, eval_every=rounds)  # warm-up: pays every compile in the path
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        run(rounds, eval_every=rounds)
        jax.block_until_ready(jax.tree.leaves(run.__self__.params))
        best = min(best, time.perf_counter() - t0)
    return best


def _param_err(cfg, rounds: int, **kw) -> float:
    a = make_trainer(cfg, **kw)
    a.run(rounds, eval_every=rounds)
    b = make_trainer(cfg, **kw)
    b.run_fused(rounds, eval_every=rounds)
    return max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
        for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params))
    )


def bench_one(
    cfg, rounds: int, label: str, agreement_rounds: int = 8, **kw
) -> dict:
    loop_s = _time_run(make_trainer(cfg, **kw).run, rounds)
    fused_s = _time_run(make_trainer(cfg, **kw).run_fused, rounds)
    row = {
        "label": label,
        "n": N_NODES,
        "backend": "dense",
        "rounds": rounds,
        "loop_rounds_per_s": round(rounds / loop_s, 1),
        "fused_rounds_per_s": round(rounds / fused_s, 1),
        "speedup": round(loop_s / fused_s, 2),
        "agreement_rounds": agreement_rounds,
        "max_abs_param_err": _param_err(cfg, agreement_rounds, **kw),
        **{k: v for k, v in kw.items() if v is not None},
    }
    print(
        f"{label:12s} loop {row['loop_rounds_per_s']:7.1f} r/s   "
        f"fused {row['fused_rounds_per_s']:7.1f} r/s   "
        f"speedup {row['speedup']:.2f}x   err {row['max_abs_param_err']:.2e}"
    )
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()

    cfg = micro_cfg()
    rows = [
        # the acceptance row: CI guards speedup >= 1.5x and err <= 1e-6
        bench_one(cfg, args.rounds, "lm"),
        # CHOCO top-k gossip inside the scan body (informational). Short
        # agreement horizon: the top-k mask is discontinuous, so once float
        # drift flips one selected index the paths diverge chaotically.
        bench_one(
            cfg, max(args.rounds // 2, 10), "lm+choco",
            agreement_rounds=6, compress=0.25,
        ),
        # churn masks + renormalized mixing inside the scan (informational)
        bench_one(
            cfg, max(args.rounds // 2, 10), "lm+faults",
            faults="churn:p_leave=0.1,p_join=0.5",
        ),
    ]
    out = {
        "bench": "fused vs loop LM cohort rounds/s (benchmarks/bench_lm_rounds.py)",
        "device": str(jax.devices()[0]),
        "machine": machine_fingerprint(),
        "config": {
            "topology": f"ring:n={N_NODES}",
            "arch": "llama32_1b reduced micro (2L/64d, vocab 256)",
            "nodes": N_NODES, "batch": BATCH, "seq": SEQ,
            "lr": 1e-3, "schedule": "cosine", "optimizer": "adamw",
            "eval": "none (pure training)",
        },
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
