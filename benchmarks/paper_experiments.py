"""Paper experiment harness: one function per paper figure/table.

Each experiment mirrors §5.1 exactly in structure (100 nodes, the paper's
topology parameters, hub-/edge-focused or community partitions, MLP +
SGD(lr=1e-3, mu=0.5)) on the synthetic MNIST-like dataset (DESIGN.md §2).
``scale`` shrinks rounds/data for smoke benches; ``--full`` runs the
EXPERIMENTS.md configuration.

Outputs CSV rows under results/paper/: per-round per-node accuracy plus the
derived quantities each claim is validated on.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.core import mixing, partition as P, topology as T
from repro.data.loader import NodeLoader
from repro.data.synthetic import make_mnist_like
from repro.train.trainer import DecentralizedTrainer

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "paper")


@dataclasses.dataclass
class ExpSettings:
    nodes: int = 100
    train_per_class: int = 2000
    test_per_class: int = 100
    rounds: int = 100
    eval_every: int = 5
    batch_size: int = 32
    lr: float = 1e-3          # paper §5.1
    momentum: float = 0.5     # paper §5.1
    local_epochs: int = 3     # paper: "a number of local training epochs"
    seed: int = 0

    @classmethod
    def quick(cls) -> "ExpSettings":
        return cls(nodes=40, train_per_class=400, test_per_class=40, rounds=12, eval_every=3)


def _dataset(s: ExpSettings):
    return make_mnist_like(
        train_per_class=s.train_per_class, test_per_class=s.test_per_class, seed=s.seed
    )


def _run(name: str, g, parts, s: ExpSettings, ds, extra: dict | None = None):
    if s.nodes != 100:  # don't clobber the full-scale (100-node) artifacts
        name = f"{name}_n{s.nodes}"
    loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=s.batch_size, seed=s.seed + 2)
    tr = DecentralizedTrainer(
        g, loader, lr=s.lr, momentum=s.momentum, seed=s.seed,
        local_epochs=s.local_epochs, mix_impl="dense",
    )
    t0 = time.time()
    hist = tr.run(s.rounds, eval_every=s.eval_every, x_test=ds.x_test, y_test=ds.y_test)
    elapsed = time.time() - t0
    os.makedirs(RESULTS_DIR, exist_ok=True)
    rows = []
    summ = P.partition_summary(ds.y_train, parts)
    g2_holder = (summ[:, 5:].sum(axis=1) > 0).astype(int)
    deg = g.degrees()
    for h in hist:
        for node in range(g.num_nodes):
            rows.append(
                dict(
                    round=h.round,
                    node=node,
                    acc=float(h.per_node_acc[node]),
                    degree=int(deg[node]),
                    holds_g2=int(g2_holder[node]),
                    block=int(g.blocks[node]) if g.blocks is not None else -1,
                )
            )
    out = {
        "name": name,
        "settings": dataclasses.asdict(s),
        "elapsed_s": round(elapsed, 1),
        "spectral_gap": mixing.spectral_gap(np.asarray(tr.w)),
        "final_mean_acc": hist[-1].mean_acc,
        "final_std_acc": hist[-1].std_acc,
        "extra": extra or {},
        "rows": rows,
    }
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(out, f)
    print(
        f"[{name}] final mean acc {hist[-1].mean_acc:.4f} std {hist[-1].std_acc:.4f} "
        f"gap {out['spectral_gap']:.4f} ({elapsed:.0f}s)"
    )
    return out, tr


def er_experiments(s: ExpSettings, *, focus_cases=("edge", "hub")):
    """Paper Fig. 1-3: ER at p in {0.03, p*=0.046, 0.05} x {edge,hub}-focused."""
    ds = _dataset(s)
    n = s.nodes
    pstar = T.er_critical_p(n)
    outs = []
    for p in (0.65 * pstar, pstar, 1.09 * pstar):  # 0.03, 0.046, 0.05 at n=100
        g = T.make(f"er:n={n},p={p}", seed=s.seed)
        for focus in focus_cases:
            part_fn = P.edge_focused if focus == "edge" else P.hub_focused
            parts = part_fn(ds.y_train, g, seed=s.seed + 1)
            name = f"er_p{p:.3f}_{focus}"
            outs.append(_run(name, g, parts, s, ds, extra={"p": p, "focus": focus}))
    return outs


def ba_experiments(s: ExpSettings, *, focus_cases=("edge", "hub")):
    """Paper Fig. 4-6: BA at m in {2, 5, 10} x {edge,hub}-focused."""
    ds = _dataset(s)
    outs = []
    for m in (2, 5, 10):
        g = T.make(f"ba:n={s.nodes},m={m}", seed=s.seed)
        for focus in focus_cases:
            part_fn = P.edge_focused if focus == "edge" else P.hub_focused
            parts = part_fn(ds.y_train, g, seed=s.seed + 1)
            name = f"ba_m{m}_{focus}"
            outs.append(_run(name, g, parts, s, ds, extra={"m": m, "focus": focus}))
    return outs


def sbm_experiments(s: ExpSettings):
    """Paper Fig. 7 + Table 1: SBM 4 communities, p_in in {0.5, 0.8}.

    Classes 8 and 9 are discarded (4 communities x 2 classes), so the test
    set is filtered to classes 0-7 — the paper's 0.25 intra-community ceiling
    is 2 of 8 classes.
    """
    ds = _dataset(s)
    keep = ds.y_test < 8
    ds = dataclasses.replace(ds, x_test=ds.x_test[keep], y_test=ds.y_test[keep])
    outs = []
    sizes = "+".join([str(s.nodes // 4)] * 4)
    for p_in in (0.5, 0.8):
        g = T.make(f"sbm:sizes={sizes},p_in={p_in},p_out=0.01", seed=s.seed)
        parts = P.community(ds.y_train, g, seed=s.seed + 1)
        name = f"sbm_pin{p_in}"
        out, tr = _run(name, g, parts, s, ds, extra={"p_in": p_in})
        # Table 1: per-community averaged confusion matrices + external links.
        cms = tr.confusion(ds.x_test, ds.y_test)
        from repro.train.metrics import community_confusion
        import jax.numpy as jnp

        comm_cm = np.asarray(
            community_confusion(jnp.asarray(cms), jnp.asarray(g.blocks), 4)
        )
        ext = T.external_edge_counts(g).tolist()
        tname = name if s.nodes == 100 else f"{name}_n{s.nodes}"
        with open(os.path.join(RESULTS_DIR, f"{tname}_table1.json"), "w") as f:
            json.dump({"confusion": comm_cm.tolist(), "external_edges": ext}, f)
        outs.append((out, comm_cm, ext))
    return outs
