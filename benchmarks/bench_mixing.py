"""Dense vs sparse gossip sweep: wall-clock + W memory at N in {128, 1024, 4096}.

One DecAvg round ``P <- W @ P`` on BA(m=2) — the paper's hub-dominated
family, whose edge count grows O(N) while dense W grows O(N^2). Reports, per
N and backend:

  - us_per_round:   median wall-clock of a jitted round (f32, D params/node)
  - w_bytes:        memory of the W representation (dense N^2 f32 vs CSR)
  - transient_bytes: the gather/output working set (nnz*D vs N*D floats)
  - max_abs_err:    backend vs dense output (allclose guard, not just speed)

Alongside the replicated paths, the node-sharded pair is timed over a 1-D
mesh of all local devices: ``sharded_dense`` (shard_map reduce-scatter
matmul) vs ``sparse_sharded`` (per-shard CSR row ranges + halo buffers),
the latter under both halo schedules (allgather and ring ppermute). The
acceptance bar is sparse_sharded no slower than sharded_dense at N=4096 —
sparse compute per device is O(nnz/S * D) vs O(N^2/S * D).

A separate ``wire`` section models the per-device receive volume of one
round for both halo schedules across the sparse topology families at a
reference shard count (default 8; the local mesh is usually S=1 where both
schedules move zero bytes). The invariant CI checks is ring <= allgather on
every family: the ring moves only the O(H) halo rows a shard references,
the allgather always moves the full node axis complement.

Emits BENCH_mixing.json at the repo root.

Run:  PYTHONPATH=src python benchmarks/bench_mixing.py [--sizes 128,1024,4096]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decavg, mixing, sparse, topology as T

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_mixing.json")


def _time(fn, *args, reps: int) -> float:
    fn(*args)["p"].block_until_ready()  # compile + warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)["p"].block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def _max_err(a, b) -> float:
    return float(jnp.max(jnp.abs(a["p"] - b["p"])))


def bench_one(n: int, d: int, reps: int, seed: int) -> dict:
    g = T.make(f"ba:n={n},m=2", seed=seed)
    w_np = mixing.decavg_matrix(g, np.ones(n))
    w = jnp.asarray(w_np, jnp.float32)
    csr = sparse.csr_from_dense(w_np)
    params = {"p": jax.random.normal(jax.random.PRNGKey(seed), (n, d), jnp.float32)}

    dense_fn = jax.jit(decavg.mix_dense)
    us_dense = _time(dense_fn, w, params, reps=reps)
    us_sparse = _time(sparse.mix_sparse, csr, params, reps=reps)
    dense_out = dense_fn(w, params)

    row = {
        "n": n,
        "d": d,
        "edges": g.num_edges,
        "nnz": csr.nnz,
        "dense": {
            "us_per_round": round(us_dense, 1),
            "w_bytes": n * n * 4,
            "transient_bytes": n * d * 4,
        },
        "sparse": {
            "us_per_round": round(us_sparse, 1),
            "w_bytes": csr.nbytes,
            "transient_bytes": csr.nnz * d * 4,
        },
        "speedup": round(us_dense / us_sparse, 2) if us_sparse else None,
        "w_compression": round(n * n * 4 / csr.nbytes, 1),
        "max_abs_err": _max_err(dense_out, sparse.mix_sparse(csr, params)),
    }

    # Node-sharded pair over all local devices (S=1 on a plain CPU host —
    # the shard_map machinery still runs, so relative cost is meaningful).
    devices = np.asarray(jax.devices())
    shards = len(devices)
    if n % shards == 0:
        mesh = jax.sharding.Mesh(devices, ("nodes",))
        shd_fn = jax.jit(
            functools.partial(decavg.mix_sharded, mesh=mesh, node_axis="nodes")
        )
        shcsr = sparse.shard_csr(csr, shards)
        us_shd = _time(shd_fn, w, params, reps=reps)
        wire = sparse.halo_wire_bytes(shcsr, d)
        schedules = {}
        for sched in ("allgather", "ring"):
            fn = jax.jit(
                functools.partial(
                    decavg.mix_sharded_sparse, mesh=mesh, node_axis="nodes",
                    halo_schedule=sched,
                )
            )
            schedules[sched] = {
                "us_per_round": round(_time(fn, shcsr, params, reps=reps), 1),
                "wire_bytes_per_device": wire[sched],
                "max_abs_err": _max_err(dense_out, fn(shcsr, params)),
            }
        auto = "ring" if wire["ring"] < wire["allgather"] else "allgather"
        us_shsp = schedules[auto]["us_per_round"]
        row["shards"] = shards
        row["sharded_dense"] = {
            "us_per_round": round(us_shd, 1),
            "w_bytes": n * n * 4,
            "wire_bytes_per_device": (n - n // shards) * d * 4,
            "max_abs_err": _max_err(dense_out, shd_fn(w, params)),
        }
        row["sparse_sharded"] = {
            "us_per_round": us_shsp,  # the auto-selected schedule's round
            "auto_schedule": auto,
            "w_bytes": shcsr.nbytes,
            "halo_width": shcsr.halo_width,
            "ring_width": shcsr.ring_width,
            "schedules": schedules,
        }
        row["sharded_speedup"] = round(us_shd / us_shsp, 2) if us_shsp else None

    print(
        f"N={n:5d}  dense {us_dense:10.1f} us / {n*n*4/2**20:7.2f} MiB W   "
        f"sparse {us_sparse:10.1f} us / {csr.nbytes/2**10:7.1f} KiB W   "
        f"speedup {row['speedup']}x  err {row['max_abs_err']:.2e}"
    )
    if "sparse_sharded" in row:
        print(
            f"        sharded_dense {row['sharded_dense']['us_per_round']:10.1f} us"
            f"   sparse_sharded {row['sparse_sharded']['us_per_round']:10.1f} us"
            f"   ({row['shards']} shard(s), speedup {row['sharded_speedup']}x)"
        )
    return row


def wire_report(n: int, d: int, shards: int, seed: int) -> list[dict]:
    """Modeled per-device wire volume (bytes received per round) of both halo
    schedules across the sparse topology families, at a reference shard count.
    Host-side only — no mixing is run, so this also covers meshes the local
    machine can't realize."""
    out = []
    for spec in (
        f"ba:n={n},m=2",
        f"ws:n={n},k=4,beta=0.1",
        f"torus:n={n}",
        f"ring:n={n}",
        f"caveman:cliques={n // 8},size=8",
    ):
        g = T.make(spec, seed=seed)
        csr = sparse.csr_from_dense(mixing.decavg_matrix(g, np.ones(g.num_nodes)))
        shcsr = sparse.shard_csr(csr, shards)
        wire = sparse.halo_wire_bytes(shcsr, d)
        out.append(
            {
                "topology": spec,
                "shards": shards,
                "halo_width": shcsr.halo_width,
                "ring_width": shcsr.ring_width,
                "allgather_bytes_per_device": wire["allgather"],
                "ring_bytes_per_device": wire["ring"],
                "ring_over_allgather": (
                    round(wire["ring"] / wire["allgather"], 4)
                    if wire["allgather"] else None
                ),
            }
        )
        print(
            f"wire {spec:28s} S={shards}  allgather "
            f"{wire['allgather']/2**10:9.1f} KiB/dev   ring "
            f"{wire['ring']/2**10:9.1f} KiB/dev   "
            f"({out[-1]['ring_over_allgather']})"
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="128,1024,4096")
    ap.add_argument("--dim", type=int, default=64,
                    help="params per node (flattened)")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--wire-n", type=int, default=4096,
                    help="N for the wire-volume model (0 to skip)")
    ap.add_argument("--wire-shards", type=int, default=8,
                    help="reference shard count for the wire-volume model")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()

    sizes = [int(s) for s in args.sizes.split(",") if s]
    rows = [bench_one(n, args.dim, args.reps, args.seed) for n in sizes]
    out = {
        "bench": "gossip_mixing_dense_vs_sparse",
        "topology": "ba:m=2",
        "dim": args.dim,
        "reps": args.reps,
        "backend": jax.default_backend(),
        "num_devices": len(jax.devices()),
        "rows": rows,
    }
    if args.wire_n:
        out["wire"] = wire_report(args.wire_n, args.dim, args.wire_shards, args.seed)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
