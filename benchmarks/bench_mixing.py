"""Dense vs sparse gossip sweep: wall-clock + W memory at N in {128, 1024, 4096}.

One DecAvg round ``P <- W @ P`` on BA(m=2) — the paper's hub-dominated
family, whose edge count grows O(N) while dense W grows O(N^2). Reports, per
N and backend:

  - us_per_round:   median wall-clock of a jitted round (f32, D params/node)
  - w_bytes:        memory of the W representation (dense N^2 f32 vs CSR)
  - transient_bytes: the gather/output working set (nnz*D vs N*D floats)
  - max_abs_err:    sparse vs dense output (allclose guard, not just speed)

Emits BENCH_mixing.json at the repo root.

Run:  PYTHONPATH=src python benchmarks/bench_mixing.py [--sizes 128,1024,4096]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decavg, mixing, sparse, topology as T

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_mixing.json")


def _time(fn, *args, reps: int) -> float:
    fn(*args)["p"].block_until_ready()  # compile + warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)["p"].block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def bench_one(n: int, d: int, reps: int, seed: int) -> dict:
    g = T.make(f"ba:n={n},m=2", seed=seed)
    w_np = mixing.decavg_matrix(g, np.ones(n))
    w = jnp.asarray(w_np, jnp.float32)
    csr = sparse.csr_from_dense(w_np)
    params = {"p": jax.random.normal(jax.random.PRNGKey(seed), (n, d), jnp.float32)}

    dense_fn = jax.jit(decavg.mix_dense)
    us_dense = _time(dense_fn, w, params, reps=reps)
    us_sparse = _time(sparse.mix_sparse, csr, params, reps=reps)

    err = float(
        jnp.max(jnp.abs(dense_fn(w, params)["p"] - sparse.mix_sparse(csr, params)["p"]))
    )
    row = {
        "n": n,
        "d": d,
        "edges": g.num_edges,
        "nnz": csr.nnz,
        "dense": {
            "us_per_round": round(us_dense, 1),
            "w_bytes": n * n * 4,
            "transient_bytes": n * d * 4,
        },
        "sparse": {
            "us_per_round": round(us_sparse, 1),
            "w_bytes": csr.nbytes,
            "transient_bytes": csr.nnz * d * 4,
        },
        "speedup": round(us_dense / us_sparse, 2) if us_sparse else None,
        "w_compression": round(n * n * 4 / csr.nbytes, 1),
        "max_abs_err": err,
    }
    print(
        f"N={n:5d}  dense {us_dense:10.1f} us / {n*n*4/2**20:7.2f} MiB W   "
        f"sparse {us_sparse:10.1f} us / {csr.nbytes/2**10:7.1f} KiB W   "
        f"speedup {row['speedup']}x  err {err:.2e}"
    )
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="128,1024,4096")
    ap.add_argument("--dim", type=int, default=64,
                    help="params per node (flattened)")
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()

    sizes = [int(s) for s in args.sizes.split(",") if s]
    rows = [bench_one(n, args.dim, args.reps, args.seed) for n in sizes]
    out = {
        "bench": "gossip_mixing_dense_vs_sparse",
        "topology": "ba:m=2",
        "dim": args.dim,
        "reps": args.reps,
        "backend": jax.default_backend(),
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
