"""End-to-end driver: decentralized training of a transformer LM cohort.

Four DecAvg nodes, each with a domain-skewed token stream (the LLM analogue
of the paper's non-IID label split), train a ~20M-param llama-family model
for a few hundred steps on CPU, gossiping weights over a ring topology every
step. The full-scale (1B-480B x 256/512-chip) version of this exact step
function is what launch/dryrun.py lowers and compiles.

Run:  PYTHONPATH=src python examples/decentralized_llm.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import base as cfgbase
from repro.core import decavg, topology as T
from repro.data import tokens as tok
from repro.launch import steps as ST
from repro.models import transformer as TF
from repro.optim import adamw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--ckpt", default=None, help="save final state here (.npz)")
    args = ap.parse_args()

    # ~20M-param member model: the assigned arch's family, CPU-sized.
    cfg = dataclasses.replace(
        cfgbase.get(args.arch),
        num_layers=4,
        d_model=256,
        num_heads=8,
        num_kv_heads=4,
        head_dim=32,
        d_ff=1024,
        vocab_size=8192,
        param_dtype="float32",
        optimizer="adamw",
    )
    n = args.nodes

    # Ring topology (the classic decentralized baseline) via the registry;
    # the engine builds and validates the Eq. 1 mixing matrix.
    engine = decavg.GossipEngine(T.make("ring", n=n))
    g, w = engine.graph, engine.w

    key = jax.random.PRNGKey(0)
    per_node = TF.init_params(key, cfg)
    params = jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), per_node)
    print(f"member model: {TF.param_count(per_node)/1e6:.1f}M params x {n} nodes ({g.name})")
    opt = adamw.init(params)

    step_fn = jax.jit(
        ST.build_train_step(cfg, num_nodes=n, optimizer="adamw", lr=3e-4)
    )

    data = tok.token_batches(
        n, args.batch, args.seq, cfg.vocab_size, steps=args.steps, seed=0
    )
    t0 = time.time()
    loss0 = None
    for i, (toks, labels) in enumerate(data):
        batch = {
            "tokens": jnp.asarray(toks)[None],   # leading microbatch axis
            "labels": jnp.asarray(labels)[None],
        }
        params, opt, loss = step_fn(params, opt, w, batch)
        if loss0 is None:
            loss0 = float(loss)
        if i % 25 == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(f"step {i:4d}  loss {float(loss):.4f}  ({dt:.0f}s)")

    print(f"\nloss {loss0:.3f} -> {float(loss):.3f} over {args.steps} steps")
    # all ring nodes stay in consensus-ish: check parameter spread
    print(f"consensus distance across nodes: {float(decavg.gossip_error(params)):.2e}")
    if args.ckpt:
        ckpt.save(args.ckpt, {"params": params, "opt": opt._asdict()}, step=args.steps)
        print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
