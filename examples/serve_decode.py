"""Batched serving demo: prefill + autoregressive decode with KV cache,
including the sliding-window (long-context) cache mode, for a reduced
member of each assigned family.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import base as cfgbase
from repro.models import transformer as TF
from repro.serve import decode as SD


def demo(arch: str, *, batch: int = 4, prompt_len: int = 8, gen: int = 24) -> None:
    cfg = cfgbase.get(arch).reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab_size)

    kw = {}
    if cfg.enc_dec:
        frames = jax.random.normal(jax.random.PRNGKey(2), (batch, 16, cfg.d_model), cfg.dtype())
        kw["memory"] = TF.encode(params, cfg, frames)

    cache_len = prompt_len + gen
    cache = TF.init_cache(cfg, batch, cache_len)
    t0 = time.time()
    toks = SD.generate(
        params, cfg, prompt, cache, steps=gen, key=jax.random.PRNGKey(3),
        temperature=0.8, **kw,
    )
    dt = time.time() - t0
    print(
        f"{arch:18s} generated {toks.shape} in {dt:5.1f}s "
        f"({batch * gen / dt:6.1f} tok/s, cache_len={cache_len})"
    )


def main() -> None:
    print("== batched sampling across the model zoo (reduced configs) ==")
    for arch in ["llama3.2-1b", "rwkv6-3b", "jamba-v0.1-52b", "whisper-base"]:
        demo(arch)

    print("\n== long-context mode: sliding-window ring cache ==")
    cfg = cfgbase.get("llama3.2-1b").reduced()  # window = 16 in reduced cfg
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    window = cfg.sliding_window
    cache = TF.init_cache(cfg, 2, window)  # ring buffer of window length only
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab_size)
    toks = SD.generate(
        params, cfg, prompt, cache, steps=3 * window, key=jax.random.PRNGKey(2)
    )
    print(
        f"generated {toks.shape[1]} tokens through a {window}-slot ring cache "
        f"(position wrapped {3 * window // window}x) - O(window) memory at any length"
    )


if __name__ == "__main__":
    main()
