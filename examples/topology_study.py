"""Mini topology study — the paper's experiment grid at reduced scale.

Runs ER (below/at/above p*), BA (m=2,5,10) and SBM (p_in=0.5/0.8) with
hub- and edge-focused non-IID splits, printing the qualitative orderings
the paper reports. Full-scale version: ``python -m benchmarks.run --full``.

Run:  PYTHONPATH=src python examples/topology_study.py [--rounds 25]
"""

import argparse

from benchmarks.paper_experiments import (
    ExpSettings,
    ba_experiments,
    er_experiments,
    sbm_experiments,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--nodes", type=int, default=50)
    args = ap.parse_args()

    s = ExpSettings(
        nodes=args.nodes,
        train_per_class=800,
        test_per_class=50,
        rounds=args.rounds,
        eval_every=max(1, args.rounds // 5),
    )

    print("=== ER (paper Fig. 1-3) ===")
    er = er_experiments(s)
    print("\n=== BA (paper Fig. 4-6) ===")
    ba = ba_experiments(s)
    print("\n=== SBM (paper Fig. 7 / Table 1) ===")
    sbm = sbm_experiments(s)

    print("\n=== qualitative claims ===")
    hub = [o["final_mean_acc"] for o, _ in er + ba if o["extra"]["focus"] == "hub"]
    edge = [o["final_mean_acc"] for o, _ in er + ba if o["extra"]["focus"] == "edge"]
    print(f"(i/ii) hub-focused mean acc {sum(hub)/len(hub):.4f} "
          f"vs edge-focused {sum(edge)/len(edge):.4f}  -> hubs spread knowledge better")
    acc = {o[0]["extra"]["p_in"]: o[0]["final_mean_acc"] for o in sbm}
    print(f"(iv) SBM p_in=0.5 acc {acc[0.5]:.4f} vs p_in=0.8 {acc[0.8]:.4f} "
          f"-> tighter communities hinder spread")


if __name__ == "__main__":
    main()
