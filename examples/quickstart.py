"""Quickstart: fully decentralized learning (DecAvg) over an ER graph.

30 nodes, non-IID data (hub-focused), 30 communication rounds on CPU.
Shows the paper's core object: per-node accuracy over rounds, and how
knowledge about classes 5-9 (held only by 3 hub nodes) spreads.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import partition as P, topology as T
from repro.core.mixing import decavg_matrix, spectral_gap
from repro.data.loader import NodeLoader
from repro.data.synthetic import make_mnist_like
from repro.train.trainer import DecentralizedTrainer


def main() -> None:
    print("== data ==")
    ds = make_mnist_like(train_per_class=600, test_per_class=60, seed=0)
    print(f"train {ds.x_train.shape}, test {ds.x_test.shape}, {ds.num_classes} classes")

    print("\n== topology ==")
    g = T.make("er:n=30,p=0.15", seed=0)  # registry spec; try "ws:n=30,k=4" etc.
    print(f"{g.name}: {g.num_edges} edges, degrees {g.degrees().min()}..{g.degrees().max()}")

    parts = P.hub_focused(ds.y_train, g, seed=1)
    summ = P.partition_summary(ds.y_train, parts)
    holders = np.flatnonzero(summ[:, 5:].sum(axis=1) > 0)
    print(f"hub-focused: classes 5-9 held only by nodes {holders.tolist()}")

    loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=32, seed=2)
    w = decavg_matrix(g, loader.sizes.astype(float))
    print(f"mixing spectral gap: {spectral_gap(w):.4f}")

    print("\n== decentralized training (DecAvg) ==")
    tr = DecentralizedTrainer(g, loader, lr=0.02, momentum=0.9, seed=0)
    tr.run(30, eval_every=5, x_test=ds.x_test, y_test=ds.y_test, verbose=True)

    print("\n== knowledge spread ==")
    accs, cms = tr._eval_jit(tr.params, ds.x_test, ds.y_test)
    cms = np.asarray(cms)
    non_holders = [n for n in range(30) if n not in holders]
    g2_recall = cms[non_holders][:, 5:, :].diagonal(offset=5, axis1=1, axis2=2).mean()
    print(f"mean recall on never-seen classes 5-9 at non-holder nodes: {g2_recall:.3f}")
    print("(> 0 only because gossip carried the hubs' knowledge across the graph)")


if __name__ == "__main__":
    main()
