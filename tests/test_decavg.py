"""DecAvg gossip: path equivalence (dense / pallas / shard_map), consensus
contraction, fixed points — the system invariants behind the paper's Eq. 1."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import requires_axis_type

from repro.core import decavg as D
from repro.core import mixing as M
from repro.core import topology as T


def _setup(n=24, seed=0, dtype=jnp.float32):
    g = T.erdos_renyi(n, 0.3, seed=seed)
    w = jnp.asarray(M.decavg_matrix(g, np.ones(n)), jnp.float32)
    key = jax.random.PRNGKey(seed)
    params = {
        "a": jax.random.normal(key, (n, 17, 3)).astype(dtype),
        "b": {"w": jax.random.normal(jax.random.fold_in(key, 1), (n, 41)).astype(dtype)},
    }
    return g, w, params


class TestEquivalence:
    def test_dense_vs_pallas(self):
        _, w, params = _setup()
        dense = D.mix_dense(w, params)
        pallas = D.mix_pallas(w, params)
        for dl, pl_ in zip(jax.tree.leaves(dense), jax.tree.leaves(pallas)):
            np.testing.assert_allclose(np.asarray(dl), np.asarray(pl_), rtol=3e-5, atol=3e-5)

    def test_bf16_dense_mixing_tolerance(self):
        """Pin the dense path's precision contract (module docstring): it
        accumulates in the LEAF dtype, so bf16 mixing tracks the f32
        reference only to bf16 resolution — while f32 inputs are exact."""
        _, w, params32 = _setup()
        ref = D.mix_dense(w, params32)
        params16 = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params32)
        out16 = D.mix_dense(w, params16)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out16)):
            assert b.dtype == jnp.bfloat16  # cast back to the leaf dtype
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b, dtype=np.float32),
                rtol=0.05, atol=0.05,
            )
        # f32 leaves really do take the tight path
        out32 = D.mix_dense(w, params32)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out32)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    def test_per_call_backend_override_does_not_mutate_engine(self):
        """mix(backend=...) is call-local: it must not change the engine's
        resolved backend, mesh, or the cached layouts its own backend uses."""
        g, w, params = _setup()
        e = D.GossipEngine(g, backend="dense")
        assert e.mesh is None and e.backend == "dense"
        want = D.mix_dense(e.w, params)
        for override in ("sparse", "sparse_sharded", "pallas"):
            got = e.mix(params, backend=override)
            for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5,
                    err_msg=override,
                )
            # sparse_sharded builds a call-local default mesh; none of the
            # overrides may leak into the engine's capability surface
            assert e.mesh is None and e.backend == "dense", override
        got = e.mix(params)  # the engine's own backend still works after
        for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    def test_halo_schedule_validated(self):
        g, _, _ = _setup()
        with pytest.raises(ValueError, match="halo_schedule"):
            D.GossipEngine(g, halo_schedule="spiral")

    @requires_axis_type
    def test_dense_vs_shardmap_subprocess(self):
        """shard_map schedules need >1 device: run with 8 fake CPU devices."""
        code = textwrap.dedent(
            """
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import decavg as D, mixing as M, topology as T
            g = T.erdos_renyi(16, 0.4, seed=0)
            w = jnp.asarray(M.decavg_matrix(g, np.ones(16)), jnp.float32)
            params = {"a": jax.random.normal(jax.random.PRNGKey(0), (16, 33, 2))}
            mesh = jax.make_mesh((4, 2), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,) * 2)
            dense = D.mix_dense(w, params)
            for sched in ("allgather", "reduce_scatter"):
                out = D.mix_sharded(w, params, mesh=mesh, node_axis="data", schedule=sched)
                np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(dense["a"]),
                                           rtol=1e-5, atol=1e-5)
            print("OK")
            """
        )
        r = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True, timeout=300
        )
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout


class TestGossipDynamics:
    def test_row_stochastic_fixed_point(self):
        """Identical node models are a fixed point of any valid mixing."""
        _, w, _ = _setup()
        n = w.shape[0]
        same = {"x": jnp.broadcast_to(jnp.arange(7.0), (n, 7))}
        out = D.mix_dense(w, same)
        np.testing.assert_allclose(np.asarray(out["x"]), np.asarray(same["x"]), rtol=1e-5)

    def test_consensus_contraction(self):
        """gossip_error strictly decreases round over round on a connected
        graph — the spectral-gap mechanism the paper's results rest on."""
        g, w, params = _setup(n=30, seed=1)
        assert T.connected_components(g.adj).max() == 0
        errs = [float(D.gossip_error(params))]
        for _ in range(5):
            params = D.mix_dense(w, params)
            errs.append(float(D.gossip_error(params)))
        assert all(b < a for a, b in zip(errs, errs[1:]))
        assert errs[-1] < 0.1 * errs[0]

    def test_disconnected_no_global_consensus(self):
        """Two components never agree: 'weak connectivity spreads information
        but zero connectivity spreads nothing' (paper §1, inverted)."""
        adj = np.zeros((8, 8), dtype=bool)
        for i, j in [(0, 1), (1, 2), (2, 3), (4, 5), (5, 6), (6, 7)]:
            adj[i, j] = adj[j, i] = True
        g = T.Graph(adj=adj)
        w = jnp.asarray(M.decavg_matrix(g, np.ones(8)), jnp.float32)
        params = {"x": jnp.concatenate([jnp.zeros((4, 5)), jnp.ones((4, 5))])}
        for _ in range(200):
            params = D.mix_dense(w, params)
        x = np.asarray(params["x"])
        assert np.allclose(x[:4], 0.0, atol=1e-4)
        assert np.allclose(x[4:], 1.0, atol=1e-4)

    @given(st.integers(6, 30), st.integers(0, 10**4))
    @settings(max_examples=10, deadline=None)
    def test_mean_preserved_by_mh(self, n, seed):
        """Doubly-stochastic (MH) gossip preserves the global average."""
        g = T.erdos_renyi(n, 0.5, seed=seed)
        w = jnp.asarray(M.metropolis_hastings_matrix(g), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(seed), (n, 9))
        mixed = D.mix_dense(w, {"x": x})["x"]
        np.testing.assert_allclose(
            np.asarray(mixed.mean(0)), np.asarray(x.mean(0)), rtol=2e-4, atol=2e-5
        )
