"""Mixing matrices (paper Eq. 1): stochasticity, support, trust weighting,
spectral-gap orderings that drive the paper's qualitative results."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mixing as M
from repro.core import topology as T


def _graph(n=40, p=0.2, seed=0):
    return T.erdos_renyi(n, p, seed=seed)


class TestDecAvgMatrix:
    def test_row_stochastic_and_support(self):
        g = _graph()
        sizes = np.random.default_rng(0).integers(10, 100, g.num_nodes)
        w = M.decavg_matrix(g, sizes)
        M.validate_mixing(w, g)

    def test_alpha_weighting(self):
        """Eq. 1: neighbor weight proportional to its dataset size."""
        adj = np.zeros((3, 3), dtype=bool)
        adj[0, 1] = adj[1, 0] = adj[0, 2] = adj[2, 0] = True
        g = T.Graph(adj=adj)
        w = M.decavg_matrix(g, np.array([10.0, 30.0, 60.0]))
        # node 0's row: self 10, nbr1 30, nbr2 60 -> /100
        np.testing.assert_allclose(w[0], [0.1, 0.3, 0.6])

    def test_self_trust(self):
        g = _graph(10, 0.5, 1)
        sizes = np.ones(10)
        w_hi = M.decavg_matrix(g, sizes, self_trust=10.0)
        w_lo = M.decavg_matrix(g, sizes, self_trust=1.0)
        assert np.all(np.diag(w_hi) > np.diag(w_lo))

    def test_isolated_node(self):
        adj = np.zeros((3, 3), dtype=bool)
        adj[0, 1] = adj[1, 0] = True
        g = T.Graph(adj=adj)
        w = M.decavg_matrix(g, np.array([5.0, 5.0, 0.0]), self_trust=0.0)
        # node 2 is isolated with zero data: keeps its own model
        np.testing.assert_allclose(w[2], [0, 0, 1])
        M.validate_mixing(w)

    @given(st.integers(5, 40), st.floats(0.1, 0.9), st.integers(0, 10**6))
    @settings(max_examples=20, deadline=None)
    def test_always_valid(self, n, p, seed):
        g = T.erdos_renyi(n, p, seed=seed)
        sizes = np.random.default_rng(seed).integers(1, 50, n).astype(float)
        w = M.decavg_matrix(g, sizes)
        M.validate_mixing(w, g)


class TestMetropolisHastings:
    def test_doubly_stochastic(self):
        g = _graph(30, 0.3, 2)
        w = M.metropolis_hastings_matrix(g)
        np.testing.assert_allclose(w.sum(axis=0), 1.0, atol=1e-9)
        np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-9)
        assert np.allclose(w, w.T)


class TestSpectralGap:
    def test_connectivity_increases_gap(self):
        """More connected ER -> faster consensus (larger spectral gap)."""
        gaps = []
        for p in (0.05, 0.15, 0.5):
            g = T.erdos_renyi(60, p, seed=3)
            w = M.decavg_matrix(g, np.ones(60))
            gaps.append(M.spectral_gap(w))
        assert gaps[0] < gaps[1] < gaps[2]

    def test_tight_communities_shrink_gap(self):
        """The paper's SBM finding: tighter communities -> slower spread."""
        g_tight = T.stochastic_block_model([25] * 4, 0.8, 0.01, seed=0)
        g_loose = T.stochastic_block_model([25] * 4, 0.5, 0.01, seed=0)
        w_t = M.decavg_matrix(g_tight, np.ones(100))
        w_l = M.decavg_matrix(g_loose, np.ones(100))
        assert M.spectral_gap(w_t) < M.spectral_gap(w_l)

    def test_complete_graph_gap_near_one(self):
        g = T.erdos_renyi(20, 1.0, seed=0)
        w = M.decavg_matrix(g, np.ones(20))
        assert M.spectral_gap(w) == pytest.approx(1.0, abs=1e-6)
