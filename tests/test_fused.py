"""Fused single-lax.scan training path vs the Python loop.

The contract under test (ISSUE 5 acceptance): same seed => ``run_fused``
produces params and metrics allclose (1e-6, f32) to ``run`` across
dense/sparse backends, static and ``@rewire`` schedules, and
``gossip_every`` in {0, 1, 3} — plus the satellites riding along: the
round-keyed sampler both paths share, the MixingProgram staging, the
no-re-jit-per-period round closure, and the opt-in gossip compression.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decavg
from repro.core import partition as P
from repro.data.loader import NodeLoader, round_batch_indices
from repro.models.mlp import init_mlp
from repro.train.trainer import DecentralizedTrainer

N_NODES = 10
DIM = 32


@pytest.fixture(scope="module")
def setup():
    from repro.data.synthetic import make_mnist_like

    ds = make_mnist_like(train_per_class=60, test_per_class=20, dim=DIM, seed=0)
    parts = P.iid(ds.y_train, N_NODES, seed=1)
    return ds, parts


def make_trainer(setup, topology="er:n=10,p=0.5", **kw):
    ds, parts = setup
    loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=8, seed=2)
    kw.setdefault("lr", 0.05)
    kw.setdefault("momentum", 0.9)
    return DecentralizedTrainer(topology, loader, seed=0, in_dim=DIM, **kw)


def assert_trees_close(a, b, **kw):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


def assert_histories_close(ha, hb):
    assert [m.round for m in ha] == [m.round for m in hb]
    for ma, mb in zip(ha, hb):
        np.testing.assert_allclose(ma.per_node_acc, mb.per_node_acc, atol=1e-6)
        assert ma.mean_acc == pytest.approx(mb.mean_acc, abs=1e-6)
        np.testing.assert_allclose(ma.consensus, mb.consensus, rtol=1e-4, atol=1e-5)
        if ma.group_acc is None:
            assert mb.group_acc is None
        else:
            np.testing.assert_allclose(ma.group_acc, mb.group_acc, atol=1e-6)


class TestFusedLoopEquivalence:
    """The acceptance matrix: backend x schedule x gossip cadence."""

    @pytest.mark.parametrize("backend", ["dense", "sparse"])
    @pytest.mark.parametrize(
        "topology", ["er:n=10,p=0.5", "er:n=10,p=0.5@rewire=2"],
        ids=["static", "rewire"],
    )
    @pytest.mark.parametrize("gossip_every", [0, 1, 3])
    def test_params_and_metrics_match(self, setup, backend, topology, gossip_every):
        ds, _ = setup
        kw = dict(topology=topology, mix_impl=backend, gossip_every=gossip_every)
        loop = make_trainer(setup, **kw)
        ha = loop.run(5, eval_every=2, x_test=ds.x_test, y_test=ds.y_test)
        fused = make_trainer(setup, **kw)
        hb = fused.run_fused(5, eval_every=2, x_test=ds.x_test, y_test=ds.y_test)
        assert_trees_close(loop.params, fused.params, rtol=1e-6, atol=1e-6)
        assert_trees_close(loop.opt_state, fused.opt_state, rtol=1e-6, atol=1e-6)
        assert_histories_close(ha, hb)

    def test_sparse_p_chunk_matches(self, setup):
        ds, _ = setup
        kw = dict(mix_impl="sparse", sparse_p_chunk=8)
        loop = make_trainer(setup, **kw)
        loop.run(3)
        fused = make_trainer(setup, **kw)
        fused.run_fused(3)
        assert_trees_close(loop.params, fused.params, rtol=1e-6, atol=1e-6)

    def test_gossip_first_matches(self, setup):
        ds, _ = setup
        loop = make_trainer(setup)
        loop.run(3, gossip_first=True)
        fused = make_trainer(setup)
        fused.run_fused(3, gossip_first=True)
        assert_trees_close(loop.params, fused.params, rtol=1e-6, atol=1e-6)

    def test_group_metrics_match(self, setup):
        ds, _ = setup
        groups = np.array([0] * 5 + [1] * 5)
        loop = make_trainer(setup, class_groups=groups)
        ha = loop.run(3, x_test=ds.x_test, y_test=ds.y_test)
        fused = make_trainer(setup, class_groups=groups)
        hb = fused.run_fused(3, x_test=ds.x_test, y_test=ds.y_test)
        assert ha[-1].group_acc is not None
        assert_histories_close(ha, hb)

    def test_rejects_unsupported_backend(self, setup):
        tr = make_trainer(setup, mix_impl="pallas")
        with pytest.raises(ValueError, match="run_fused supports"):
            tr.run_fused(2)

    def test_fused_backends_mirror_capability_matrix(self):
        from repro.train.trainer import _FUSED_BACKENDS

        caps = decavg.GossipEngine.capabilities()
        assert set(_FUSED_BACKENDS) == {b for b, c in caps.items() if c["fused"]}

    def test_streams_chunks_to_on_round(self, setup):
        """eval_every chunking: one scan dispatch per eval round, callbacks
        in the same order/rounds as the loop, wall clock monotone."""
        ds, _ = setup
        tr = make_trainer(setup)
        seen = []
        hist = tr.run_fused(
            8, eval_every=3, x_test=ds.x_test, y_test=ds.y_test,
            on_round=lambda m: seen.append(m),
        )
        assert [m.round for m in seen] == [0, 3, 6, 7]
        assert all(h is s for h, s in zip(hist, seen))
        walls = [m.wall_s for m in seen]
        assert walls == sorted(walls) and walls[0] > 0

    def test_no_eval_single_scan(self, setup):
        tr = make_trainer(setup)
        assert tr.run_fused(4) == []
        assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(tr.params))


class TestFusedEngineBackends:
    """Fused-vs-loop for the engine-held backends the tentpole adds.

    ``sparse_sharded`` must be BIT-identical (ring/allgather halo assembly is
    pure data movement and both paths build W via csr_from_graph); locally the
    mesh has one device, so this exercises the degenerate 1-shard layout —
    tests/test_fused_sharded.py covers 8 shards in a subprocess.
    ``sparse_pallas`` fuses the 8-row-blocked kernel while the loop runs the
    scalar interpret kernel off-TPU: the two sum in different orders, and the
    ~1e-7 per-mix gap is amplified by the SGD rounds *between* mixes, so the
    sparser the cadence the looser the budget. A small member model keeps the
    interpret-mode kernels affordable.
    """

    SMALL = dict(init_fn=lambda k: init_mlp(k, in_dim=DIM, hidden=(16,)))

    @pytest.mark.parametrize("backend", ["sparse_pallas", "sparse_sharded"])
    @pytest.mark.parametrize(
        "topology", ["er:n=10,p=0.5", "er:n=10,p=0.5@rewire=2"],
        ids=["static", "rewire"],
    )
    @pytest.mark.parametrize("gossip_every", [1, 3])
    def test_params_and_metrics_match(self, setup, backend, topology, gossip_every):
        ds, _ = setup
        kw = dict(topology=topology, mix_impl=backend, gossip_every=gossip_every,
                  **self.SMALL)
        if backend == "sparse_sharded":
            loop = make_trainer(setup, **kw)
            ha = loop.run(4, eval_every=2, x_test=ds.x_test, y_test=ds.y_test)
            fused = make_trainer(setup, **kw)
            hb = fused.run_fused(4, eval_every=2, x_test=ds.x_test, y_test=ds.y_test)
            assert_trees_close(loop.params, fused.params, rtol=0, atol=0)
            assert_trees_close(loop.opt_state, fused.opt_state, rtol=0, atol=0)
            assert_histories_close(ha, hb)
        else:
            loop = make_trainer(setup, **kw)
            loop.run(4)
            fused = make_trainer(setup, **kw)
            fused.run_fused(4)
            tol = 1e-6 if gossip_every == 1 else 5e-4
            assert_trees_close(loop.params, fused.params, rtol=tol, atol=tol)
            assert_trees_close(loop.opt_state, fused.opt_state, rtol=tol, atol=tol)

    def test_loop_backends_agree_across_periods(self, setup):
        """Regression: ``_jit_for_period`` once jitted the bound method, and
        equal bound methods share one pjit cache entry — after the first
        period change the loop silently reused the executable traced with the
        OLD period's engine state. All loop backends must agree on a @rewire
        schedule."""
        kw = dict(topology="er:n=10,p=0.5@rewire=2", gossip_every=1, **self.SMALL)
        ref = make_trainer(setup, mix_impl="sparse", **kw)
        ref.run(4)  # crosses the period-1 boundary at round 2
        for backend in ("sparse_pallas", "sparse_sharded"):
            tr = make_trainer(setup, mix_impl=backend, **kw)
            tr.run(4)
            assert_trees_close(tr.params, ref.params, rtol=1e-6, atol=1e-6)


class TestMixingProgram:
    def test_period_and_cadence_staging(self):
        e = decavg.GossipEngine("er:n=8,p=0.6@rewire=2", seed=3, gossip_every=3)
        prog = e.program(7)
        assert prog.kind == "dense" and prog.w.shape == (4, 8, 8)
        assert prog.num_periods == 4 and prog.rounds == 7
        assert np.asarray(prog.period_idx).tolist() == [0, 0, 1, 1, 2, 2, 3]
        assert np.asarray(prog.gossip_mask).tolist() == [
            True, False, False, True, False, False, True,
        ]
        assert prog.cadence == "mask"
        # the engine is left where a fresh Python-loop run expects it
        assert e.schedule.period_of(0) == 0 and np.asarray(e.w).shape == (8, 8)
        assert decavg.GossipEngine("ring:n=8").program(3).cadence == "always"
        assert decavg.GossipEngine("ring:n=8", gossip_every=0).program(3).cadence == "never"

    def test_sparse_padding_is_exact(self):
        """Padded stacked CSR periods mix identically to the dense stack."""
        e = decavg.GossipEngine("er:n=8,p=0.4@regen=1", seed=5)
        dense = e.program(3, kind="dense")
        sp = e.program(3, kind="sparse")
        assert sp.rows.shape == sp.values.shape  # (T, E) uniform padding
        params = {"p": jax.random.normal(jax.random.PRNGKey(0), (8, 7))}
        for r in range(3):
            a = jax.jit(lambda p, r=r: dense.apply(p, jnp.int32(r)))(params)
            b = jax.jit(lambda p, r=r: sp.apply(p, jnp.int32(r)))(params)
            np.testing.assert_allclose(
                np.asarray(a["p"]), np.asarray(b["p"]), atol=1e-6
            )

    def test_sparse_p_chunk_reaches_the_program(self):
        """The fused path must keep the documented gather-transient bound:
        the engine's sparse_p_chunk lands on the program and the chunked
        in-scan mix equals the unchunked one."""
        e = decavg.GossipEngine("er:n=8,p=0.5", seed=1, sparse_p_chunk=4)
        prog = e.program(2, kind="sparse")
        assert prog.p_chunk == 4
        auto = decavg.GossipEngine("er:n=8,p=0.5", seed=1, sparse_p_chunk="auto")
        assert isinstance(auto.program(2, kind="sparse").p_chunk, int)
        plain = decavg.GossipEngine("er:n=8,p=0.5", seed=1).program(2, kind="sparse")
        assert plain.p_chunk is None
        params = {"p": jax.random.normal(jax.random.PRNGKey(0), (8, 10))}
        a = jax.jit(lambda p: prog.apply(p, jnp.int32(0)))(params)
        b = jax.jit(lambda p: plain.apply(p, jnp.int32(0)))(params)
        np.testing.assert_allclose(np.asarray(a["p"]), np.asarray(b["p"]), atol=1e-6)

    def test_all_sparse_kinds_apply_match_dense(self):
        """One engine, four staged kinds: every period's in-scan mix agrees
        with the dense reference program (sparse/sparse_sharded exactly —
        same csr_from_graph values, exact-zero padding — pallas at 1e-6)."""
        e = decavg.GossipEngine("er:n=8,p=0.5@rewire=1", seed=7)
        dense = e.program(3, kind="dense")
        params = {"p": jax.random.normal(jax.random.PRNGKey(2), (8, 9))}
        tol = {"sparse": 5e-7, "sparse_pallas": 1e-6, "sparse_sharded": 5e-7}
        for kind, atol in tol.items():
            prog = e.program(3, kind=kind)
            assert prog.kind == kind and prog.num_periods == 3
            for r in range(3):
                a = jax.jit(lambda p, r=r: dense.apply(p, jnp.int32(r)))(params)
                b = jax.jit(lambda p, r=r, prog=prog: prog.apply(p, jnp.int32(r)))(params)
                np.testing.assert_allclose(
                    np.asarray(a["p"]), np.asarray(b["p"]), atol=atol
                )

    def test_stacked_layout_staging_invariants(self):
        """The period axis of every staged layout matches num_periods, and
        padding is uniform across periods (one shape for the whole scan)."""
        e = decavg.GossipEngine("er:n=16,p=0.3@rewire=1", seed=11)
        bell = e.program(3, kind="sparse_pallas")
        assert bell.bell_idx.shape[0] == 3 and bell.bell_val.shape[0] == 3
        assert bell.bell_val.shape[1:] == (
            bell.bell_idx.shape[1] * 8, bell.bell_idx.shape[2] * 8,
        )
        assert bell.w is None and bell.rows is None  # no dense/CSR staging
        sh = e.program(3, kind="sparse_sharded")
        assert sh.sh_values.shape[0] == 3
        assert sh.sh_rows.shape == sh.sh_cols.shape == sh.sh_values.shape
        assert sh.shards == sh.sh_halo.shape[1]
        assert len(sh.sh_ring_send) == len(sh.sh_ring_recv) == sh.shards - 1
        assert sh.mesh is not None and sh.halo_schedule == "auto"

    def test_pad_ratio_logged(self):
        """pad_ratio = staged operator slots per real W entry — 1.0 when
        nothing is padded (dense, single-period sparse), > 1 for blocked/
        sharded layouts, and finite always (ISSUE 6 satellite)."""
        static = decavg.GossipEngine("er:n=8,p=0.5", seed=0)
        assert static.program(2, kind="dense").pad_ratio == 1.0
        assert static.program(2, kind="sparse").pad_ratio == 1.0
        for kind in ("sparse", "sparse_pallas", "sparse_sharded"):
            r = decavg.GossipEngine(
                "er:n=8,p=0.4@rewire=1", seed=4
            ).program(3, kind=kind).pad_ratio
            assert np.isfinite(r) and r >= 1.0

    def test_program_validates_args(self):
        e = decavg.GossipEngine("ring:n=8")
        with pytest.raises(ValueError, match="rounds"):
            e.program(0)
        with pytest.raises(ValueError, match="kind"):
            e.program(2, kind="pallas")


class TestRoundKeyedSampler:
    def test_pure_and_deterministic(self, setup):
        ds, parts = setup
        loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=8, seed=2)
        xa, ya = loader.sample_round(2, round=3)
        # interleave legacy stateful draws: must not disturb keyed ones
        loader.sample_round(2)
        xb, yb = loader.sample_round(2, round=3)
        np.testing.assert_array_equal(xa, xb)
        np.testing.assert_array_equal(ya, yb)
        xc, _ = loader.sample_round(2, round=4)
        assert not np.array_equal(xa, xc)

    def test_device_pool_matches_host_gather(self, setup):
        """The staged bank + in-scan index rule reproduce the host batches."""
        ds, parts = setup
        loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=8, seed=2)
        data = loader.device_data()
        xs, ys = loader.sample_round(2, round=5)
        idx = round_batch_indices(data.key, 5, 2, loader.batch, data.sizes)
        node = jnp.arange(loader.num_nodes)
        rows = data.parts[node[None, :, None], idx]  # (steps, N, B)
        np.testing.assert_array_equal(np.asarray(data.x[rows]), xs)
        np.testing.assert_array_equal(np.asarray(data.y[rows]), ys.astype(np.int32))

    def test_indices_respect_pool_sizes(self, setup):
        ds, parts = setup
        loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=16, seed=0)
        data = loader.device_data()
        idx = np.asarray(round_batch_indices(data.key, 0, 4, 16, data.sizes))
        sizes = np.asarray(data.sizes)
        assert (idx >= 0).all()
        assert (idx < sizes[None, :, None]).all()

    def test_empty_node_rejected(self, setup):
        ds, parts = setup
        bad = [np.array([], dtype=np.int64)] + list(parts[1:])
        loader = NodeLoader(ds.x_train, ds.y_train, bad, batch_size=8, seed=0)
        with pytest.raises(ValueError, match="empty dataset"):
            loader.sample_round(1, round=0)
        with pytest.raises(ValueError, match="empty dataset"):
            loader.device_data()


class TestNoReJitPerPeriod:
    def test_dense_rewire_compiles_once(self, setup):
        """The round closure takes W as a traced argument: a 3-period
        @rewire run reuses ONE compiled program (the old code re-jitted —
        and recompiled — at every period boundary)."""
        tr = make_trainer(setup, topology="er:n=10,p=0.5@rewire=2")
        tr.run(6)
        assert tr._round_jit._cache_size() == 1
        tr.run(6)  # a second run revisits the periods: still one program
        assert tr._round_jit._cache_size() == 1

    def test_engine_backend_period_cache_reused(self, setup):
        """Backends mixing through engine-held static state get one jitted
        closure per period, cached across runs."""
        tr = make_trainer(setup, topology="er:n=10,p=0.5@rewire=2",
                          mix_impl="sparse_pallas")
        tr.run(4)  # periods 0 and 1
        assert set(tr._round_jit_cache) == {0, 1}
        jits = dict(tr._round_jit_cache)
        tr.run(4)
        assert tr._round_jit_cache == jits  # same objects: no re-jit


class TestCompressKnob:
    def test_full_k_equals_plain_decavg(self, setup):
        """k_frac=1 transmits the whole delta: CHOCO reduces exactly to
        W @ params, so the compressed run must match the baseline."""
        base = make_trainer(setup)
        base.run(4)
        comp = make_trainer(setup, compress=1.0)
        comp.run(4)
        assert_trees_close(base.params, comp.params, rtol=1e-5, atol=1e-6)

    def test_convergence_smoke(self, setup):
        """Top-k compressed gossip still learns and still spreads: accuracy
        climbs and consensus stays contracted vs isolated training."""
        ds, _ = setup
        tr = make_trainer(setup, topology="complete:n=10", compress=0.25)
        hist = tr.run(8, eval_every=7, x_test=ds.x_test, y_test=ds.y_test)
        assert hist[-1].mean_acc > max(0.2, hist[0].mean_acc + 0.05)
        assert np.isfinite(hist[-1].consensus).all()

    def test_fused_matches_loop_with_compress(self, setup):
        ds, _ = setup
        kw = dict(mix_impl="sparse", compress=0.25, gossip_every=2)
        loop = make_trainer(setup, **kw)
        ha = loop.run(5, eval_every=2, x_test=ds.x_test, y_test=ds.y_test)
        fused = make_trainer(setup, **kw)
        hb = fused.run_fused(5, eval_every=2, x_test=ds.x_test, y_test=ds.y_test)
        assert_trees_close(loop.params, fused.params, rtol=1e-6, atol=1e-6)
        assert_trees_close(
            loop.cstate.reference, fused.cstate.reference, rtol=1e-6, atol=1e-6
        )
        assert_histories_close(ha, hb)

    def test_rejects_bad_fraction(self, setup):
        with pytest.raises(ValueError, match="compress"):
            make_trainer(setup, compress=0.0)
        with pytest.raises(ValueError, match="compress"):
            make_trainer(setup, compress=1.5)


class TestRunnerRouting:
    def test_mlp_spec_routes_through_fused(self, setup, tmp_path, monkeypatch):
        from repro.experiments import runner
        from repro.experiments.spec import ExperimentSpec
        from repro.experiments.store import ResultsStore
        from repro.train.trainer import DecentralizedTrainer as DT

        calls = {"fused": 0, "loop": 0}
        orig_fused, orig_run = DT.run_fused, DT.run

        def spy_fused(self, *a, **k):
            calls["fused"] += 1
            return orig_fused(self, *a, **k)

        def spy_run(self, *a, **k):
            calls["loop"] += 1
            return orig_run(self, *a, **k)

        monkeypatch.setattr(DT, "run_fused", spy_fused)
        monkeypatch.setattr(DT, "run", spy_run)
        tiny = dict(rounds=2, eval_every=1, batch_size=8,
                    data={"train_per_class": 40, "test_per_class": 10})
        spec = ExperimentSpec(topology="ring:n=6", **tiny)
        out = runner.run_spec(spec, ResultsStore(str(tmp_path / "a.jsonl")))
        assert out["status"] == "completed"
        assert calls == {"fused": 1, "loop": 0}
        # the opt-out flag forces the Python loop (and changes the run id)
        opt_out = ExperimentSpec(topology="ring:n=6", model={"fused": False}, **tiny)
        assert opt_out.run_id != spec.run_id
        out = runner.run_spec(opt_out, ResultsStore(str(tmp_path / "b.jsonl")))
        assert out["status"] == "completed"
        assert calls == {"fused": 1, "loop": 1}

    def test_compress_spec_reaches_trainer(self, setup, tmp_path):
        from repro.experiments import runner
        from repro.experiments.spec import ExperimentSpec
        from repro.experiments.store import ResultsStore

        spec = ExperimentSpec(
            topology="ring:n=6", model={"kind": "mlp", "compress": 0.5},
            rounds=2, eval_every=1, batch_size=8,
            data={"train_per_class": 40, "test_per_class": 10},
        )
        out = runner.run_spec(spec, ResultsStore(str(tmp_path / "r.jsonl")))
        assert out["status"] == "completed"
        assert np.isfinite(out["final"]["mean_acc"])
