"""Launch-layer logic tests (no devices needed): mesh node-axis assignment,
input-shape specs, analytic roofline terms, HLO collective parsing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.launch import analysis as AN, hlo_walk as HW, shapes as SH
from repro.launch.mesh import node_axes_for


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


class TestNodeAxes:
    def test_single_pod(self):
        m = FakeMesh({"data": 16, "model": 16})
        assert node_axes_for(16, m) == ("data",)
        assert node_axes_for(2, m) == ()
        assert node_axes_for(1, m) == ()

    def test_multi_pod(self):
        m = FakeMesh({"pod": 2, "data": 16, "model": 16})
        assert node_axes_for(32, m) == ("pod", "data")
        assert node_axes_for(4, m) == ("pod",)
        assert node_axes_for(2, m) == ("pod",)
        assert node_axes_for(1, m) == ()


class TestShapes:
    def test_four_shapes_registered(self):
        assert set(SH.SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
        assert SH.SHAPES["long_500k"].seq_len == 524288
        assert SH.SHAPES["train_4k"].global_batch == 256

    @pytest.mark.parametrize("arch", cfgbase.ASSIGNED_ARCHS)
    def test_train_inputs_divide(self, arch):
        cfg = cfgbase.get(arch)
        shape = SH.SHAPES["train_4k"]
        n = cfg.num_nodes_single_pod
        specs = SH.train_inputs(cfg, shape, n, microbatches=1)
        tok = specs["tokens"]
        assert tok.shape[0] == 1 and tok.shape[1] == n
        assert tok.shape[2] * n == shape.global_batch

    @pytest.mark.parametrize("arch", cfgbase.ASSIGNED_ARCHS)
    def test_decode_inputs_build(self, arch):
        cfg = cfgbase.get(arch)
        for name in ("decode_32k", "long_500k"):
            specs = SH.decode_inputs(cfg, SH.SHAPES[name])
            assert specs["token"].shape == (SH.SHAPES[name].global_batch,)
            # long_500k must be sub-quadratic: attention caches bounded by window
            if name == "long_500k":
                for path, leaf in jax.tree_util.tree_flatten_with_path(specs["cache"])[0]:
                    pstr = "/".join(str(getattr(p, "key", p)) for p in path)
                    if pstr.endswith("/k"):
                        assert leaf.shape[2] <= cfg.sliding_window

    def test_long_context_applicable_everywhere(self):
        for arch in cfgbase.ASSIGNED_ARCHS:
            ok, why = SH.long_context_applicable(cfgbase.get(arch))
            assert ok, (arch, why)


class TestAnalyticTerms:
    def test_step_flops_scales_with_tokens(self):
        cfg = cfgbase.get("llama32_1b")
        f1 = AN.analytic_step_flops(cfg, kind="prefill", batch=1, seq=1024)
        f2 = AN.analytic_step_flops(cfg, kind="prefill", batch=2, seq=1024)
        assert f2 / f1 == pytest.approx(2.0, rel=0.05)

    def test_train_is_3x_prefill(self):
        cfg = cfgbase.get("stablelm_3b")
        fp = AN.analytic_step_flops(cfg, kind="prefill", batch=4, seq=512)
        ft = AN.analytic_step_flops(cfg, kind="train", batch=4, seq=512)
        assert ft / fp == pytest.approx(3.0, rel=0.01)

    def test_moe_active_vs_total(self):
        cfg = cfgbase.get("arctic_480b")
        total = AN.total_param_count(cfg)
        active = AN.active_param_count(cfg)
        # 128 experts top-2 -> active far below total
        assert active < 0.1 * total

    def test_window_caps_attention_flops(self):
        cfg = cfgbase.get("llama32_1b")
        full = AN.analytic_step_flops(cfg, kind="decode", batch=1, seq=0, cache_len=524288)
        win = AN.analytic_step_flops(
            cfg, kind="decode", batch=1, seq=0, cache_len=524288, window=4096
        )
        assert win < full

    def test_collective_model_modes(self):
        cfg = cfgbase.get("llama32_1b")
        mesh = {"data": 16, "model": 16}
        base = AN.analytic_collective_bytes(
            cfg, kind="train", batch=256, seq=4096, num_nodes=16,
            microbatches=2, mesh_shape=mesh, node_sharded=True, layout="tp",
        )
        opt = AN.analytic_collective_bytes(
            cfg, kind="train", batch=256, seq=4096, num_nodes=16,
            microbatches=1, mesh_shape=mesh, node_sharded=True, layout="fsdp_model",
        )
        assert sum(opt.values()) < 0.5 * sum(base.values())
        pipe = AN.analytic_collective_bytes(
            cfg, kind="decode", batch=128, seq=32768, num_nodes=1,
            microbatches=1, mesh_shape=mesh, node_sharded=False,
            serve_layout="pipeline",
        )
        shard = AN.analytic_collective_bytes(
            cfg, kind="decode", batch=128, seq=32768, num_nodes=1,
            microbatches=1, mesh_shape=mesh, node_sharded=False,
        )
        assert pipe.get("serve_ag", 0.0) == 0.0
        assert sum(pipe.values()) < 0.1 * sum(shard.values())


class TestHloWalk:
    HLO = """
HloModule test

%cond (arg: (s32[])) -> pred[] {
  %arg = (s32[]) parameter(0)
  %c = s32[] constant(8)
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

%body (arg: (s32[])) -> (s32[]) {
  %arg = (s32[]) parameter(0)
  %ag = f32[16,4]{1,0} all-gather(%x), dimensions={0}
  ROOT %t = (s32[]) tuple(%i)
}

ENTRY %main (p: f32[1,4]) -> f32[16,4] {
  %p = f32[1,4]{1,0} parameter(0)
  %w = (s32[]) while(%init), condition=%cond, body=%body
  %ar = f32[2,2]{1,0} all-reduce(%p2), to_apply=%add
  ROOT %r = f32[16,4]{1,0} copy(%gte2)
}
"""

    def test_computations_parsed(self):
        comps = HW.parse_computations(self.HLO)
        assert {"cond", "body", "main"} <= set(comps)
        assert comps["main"].is_entry

    def test_loop_multiplier_applied(self):
        rep = HW.collective_wire_bytes_looped(self.HLO)
        # all-gather inside the trip-8 loop (operand untyped -> result-size
        # fallback): 16*4*4B * 8 trips
        assert rep.wire_by_kind["all-gather"] == pytest.approx(64 * 4 * 8)
        # top-level all-reduce: 2 * result bytes
        assert rep.wire_by_kind["all-reduce"] == pytest.approx(2 * 16)

    def test_array_bytes(self):
        assert HW._array_bytes("bf16[2,3]") == 12
        assert HW._array_bytes("(f32[4], s8[8])") == 24


class TestConfigs:
    @pytest.mark.parametrize("arch", cfgbase.ASSIGNED_ARCHS)
    def test_exact_assigned_specs(self, arch):
        """Configs carry the exact assigned hyperparameters."""
        cfg = cfgbase.get(arch)
        expected = {
            "stablelm_3b": (32, 2560, 32, 32, 6912, 50304),
            "mistral_large_123b": (88, 12288, 96, 8, 28672, 32768),
            "jamba_v01_52b": (32, 4096, 32, 8, 14336, 65536),
            "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
            "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
            "llama32_1b": (16, 2048, 32, 8, 8192, 128256),
            "minicpm_2b": (40, 2304, 36, 36, 5760, 122753),
            "rwkv6_3b": (32, 2560, 40, 40, 8960, 65536),
            "whisper_base": (6, 512, 8, 8, 2048, 51865),
            "internvl2_76b": (80, 8192, 64, 8, 28672, 128256),
        }[arch]
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == expected
        assert cfg.source  # citation present

    def test_moe_specs(self):
        assert cfgbase.get("dbrx_132b").moe.num_experts == 16
        assert cfgbase.get("dbrx_132b").moe.top_k == 4
        arctic = cfgbase.get("arctic_480b").moe
        assert arctic.num_experts == 128 and arctic.top_k == 2 and arctic.dense_residual
        jamba = cfgbase.get("jamba_v01_52b")
        mixers = [s.mixer for s in jamba.pattern]
        assert mixers.count("attn") == 1 and mixers.count("mamba") == 7
        assert sum(s.ffn == "moe" for s in jamba.pattern) == 4

    def test_reduced_constraints(self):
        """Smoke variants respect the assignment's reduction bounds."""
        for arch in cfgbase.ASSIGNED_ARCHS:
            r = cfgbase.get(arch).reduced()
            assert r.num_layers <= 2 * r.period
            assert r.d_model <= 512
            if r.moe:
                assert r.moe.num_experts <= 4
