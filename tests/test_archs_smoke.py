"""Required per-architecture smoke tests: REDUCED variant of each assigned
family (<=2 pattern periods, d_model<=256, <=4 experts) runs one forward AND
one decentralized train step on CPU; output shapes + finiteness asserted.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.launch import steps as ST
from repro.models import transformer as TF
from repro.optim import adamw, sgd

ARCHS = list(cfgbase.ASSIGNED_ARCHS)


def _batch_for(cfg, num_nodes, b, s, key):
    out = {}
    if cfg.enc_dec:
        dec = min(s, 16)
        out["frames"] = jax.random.normal(key, (1, num_nodes, b, s, cfg.d_model), cfg.dtype())
        out["tokens"] = jax.random.randint(key, (1, num_nodes, b, dec), 0, cfg.vocab_size)
        out["labels"] = jax.random.randint(key, (1, num_nodes, b, dec), 0, cfg.vocab_size)
        return out
    if cfg.family == "vlm":
        p = max(1, s // 4)
        out["prefix_embeds"] = jax.random.normal(key, (1, num_nodes, b, p, cfg.d_model), cfg.dtype())
        out["tokens"] = jax.random.randint(key, (1, num_nodes, b, s - p), 0, cfg.vocab_size)
        out["labels"] = jax.random.randint(key, (1, num_nodes, b, s), 0, cfg.vocab_size)
        return out
    out["tokens"] = jax.random.randint(key, (1, num_nodes, b, s), 0, cfg.vocab_size)
    out["labels"] = jax.random.randint(key, (1, num_nodes, b, s), 0, cfg.vocab_size)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = cfgbase.get(arch).reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    b, s = cfg.smoke_batch, cfg.smoke_seq
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    kw = {}
    expect_s = s
    if cfg.enc_dec:
        frames = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.d_model), cfg.dtype())
        mem = TF.encode(params, cfg, frames)
        assert mem.shape == (b, s, cfg.d_model)
        kw["memory"] = mem
    if cfg.family == "vlm":
        p = s // 4
        kw["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (b, p, cfg.d_model), cfg.dtype()
        )
        expect_s = s + p
    logits, aux = TF.forward(params, cfg, tokens, **kw)
    assert logits.shape == (b, expect_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    """One full decentralized round: local grads + optimizer + gossip."""
    cfg = cfgbase.get(arch).reduced()
    num_nodes, b, s = 4, 2, cfg.smoke_seq
    key = jax.random.PRNGKey(0)
    per_node = TF.init_params(key, cfg)
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (num_nodes,) + x.shape).copy(), per_node
    )
    if cfg.optimizer == "adamw":
        opt = adamw.init(params)
    else:
        opt = sgd.init(params)
    w_mix = jnp.full((num_nodes, num_nodes), 1.0 / num_nodes, jnp.float32)
    batch = _batch_for(cfg, num_nodes, b, s, jax.random.PRNGKey(1))

    step = ST.build_train_step(cfg, num_nodes=num_nodes, optimizer=cfg.optimizer, lr=1e-3)
    new_params, new_opt, loss = jax.jit(step)(params, opt, w_mix, batch)

    assert jax.tree.structure(new_params) == jax.tree.structure(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    # params actually moved
    moved = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(bb, np.float32))
        for a, bb in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved
    # all-node uniform gossip after identical init keeps node copies identical
    lead = jax.tree.leaves(new_params)[0]
    np.testing.assert_allclose(
        np.asarray(lead[0], np.float32), np.asarray(lead[-1], np.float32), atol=1e-5
    )


@pytest.mark.parametrize(
    "arch", ["llama32_1b", "rwkv6_3b", "jamba_v01_52b", "whisper_base", "minicpm_2b"]
)
def test_decode_consistency(arch):
    """Token-by-token decode matches the full forward pass (dropless MoE)."""
    cfg = cfgbase.get(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0)
        )
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 10
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    kw = {}
    if cfg.enc_dec:
        frames = jax.random.normal(jax.random.PRNGKey(2), (b, 12, cfg.d_model), cfg.dtype())
        kw["memory"] = TF.encode(params, cfg, frames)
    full, _ = TF.forward(params, cfg, tokens, **kw)
    cache = TF.init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        lg, cache = TF.decode_step(params, cfg, tokens[:, t], cache, memory=kw.get("memory"))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full, np.float32), rtol=2e-4, atol=2e-4
    )


def test_param_counts_match_design():
    """Analytic full-size param counts are in the DESIGN.md ballpark."""
    from repro.launch import analysis

    expected = {
        "llama32_1b": 1.5e9,
        "stablelm_3b": 2.8e9,
        "mistral_large_123b": 122.6e9,
        "jamba_v01_52b": 51.6e9,
        "dbrx_132b": 131.6e9,
        "arctic_480b": 477e9,
        "rwkv6_3b": 3.0e9,
        "internvl2_76b": 70.6e9,
    }
    for arch, want in expected.items():
        cfg = cfgbase.get(arch)
        got = analysis.total_param_count(cfg)
        assert abs(got - want) / want < 0.15, f"{arch}: {got:.3e} vs {want:.3e}"
