"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the single real CPU device (the 512-device override is
exclusive to launch/dryrun.py). Sharded-path tests spawn subprocesses.

Optional-dependency fallback: several test modules import ``hypothesis``
(property tests) and ``networkx`` (cross-checks) at module scope, which
breaks *collection* of the whole module when the package is absent.
``pytest.importorskip`` can't help there (the import happens before any
conftest hook runs per-module), so we pre-register stub modules in
``sys.modules``: property tests and networkx cross-checks then SKIP
individually instead of erroring the other ~90 tests out of collection.
Install the real packages (``pip install -e ".[test]"``) to run them.
"""

import sys
import types

import jax
import numpy as np
import pytest

# Shared guard for subprocess tests that build meshes with
# jax.make_mesh(axis_types=jax.sharding.AxisType...), an API added after jax
# 0.4.37. Skip (not fail) on older jax so tier-1 stays green in pinned
# containers without hiding regressions on newer jax — the same
# sharded/permute numerics run on any jax in tests/test_backend_equivalence.py
# via plain jax.sharding.Mesh.
requires_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType requires jax > 0.4.37",
)


def _install_hypothesis_stub() -> None:
    mod = types.ModuleType("hypothesis")

    def given(*_args, **_kwargs):
        def deco(fn):
            # No functools.wraps: pytest would unwrap to the original
            # signature and demand fixtures for the hypothesis arguments.
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed (property test)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    class settings:
        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "text", "lists",
                 "sampled_from", "tuples", "one_of", "just"):
        setattr(strategies, name, lambda *a, _n=name, **k: None)

    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


class _SkipOnUse(types.ModuleType):
    """Module stub whose first attribute access skips the running test."""

    def __getattr__(self, name):
        pytest.skip(f"{self.__name__} not installed")


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()

try:
    import networkx  # noqa: F401
except ImportError:
    sys.modules["networkx"] = _SkipOnUse("networkx")


@pytest.fixture(scope="session")
def small_dataset():
    from repro.data.synthetic import make_mnist_like

    return make_mnist_like(train_per_class=120, test_per_class=40, seed=0)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
