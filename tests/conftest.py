"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the single real CPU device (the 512-device override is
exclusive to launch/dryrun.py). Sharded-path tests spawn subprocesses."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_dataset():
    from repro.data.synthetic import make_mnist_like

    return make_mnist_like(train_per_class=120, test_per_class=40, seed=0)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
