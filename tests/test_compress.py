"""Gossip delta compression: top-k sparsity, implicit error feedback
(reference tracking), losslessness in the limit."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as C


def _params(key, shapes=((16, 8), (32,))):
    return {
        f"p{i}": jax.random.normal(jax.random.fold_in(key, i), s)
        for i, s in enumerate(shapes)
    }


def test_topk_sparsity():
    key = jax.random.PRNGKey(0)
    p0 = _params(key)
    state = C.init(p0)
    p1 = jax.tree.map(lambda x: x + 0.1 * jnp.sign(x), p0)
    sent, state = C.compress(p1, state, k_frac=0.1)
    for leaf in jax.tree.leaves(sent):
        nnz = int((np.asarray(leaf) != 0).sum())
        assert nnz <= max(1, int(0.1 * leaf.size)) + 1


def test_error_feedback_catches_up():
    """Repeated compression of a FIXED target converges: error feedback
    re-queues everything that was dropped."""
    key = jax.random.PRNGKey(1)
    p0 = _params(key)
    state = C.init(p0)
    target = jax.tree.map(lambda x: x + jax.random.normal(key, x.shape), p0)
    for _ in range(40):
        _, state = C.compress(target, state, k_frac=0.05)
    for ref, tgt in zip(jax.tree.leaves(state.reference), jax.tree.leaves(target)):
        np.testing.assert_allclose(np.asarray(ref), np.asarray(tgt), atol=1e-4)


def test_full_k_is_lossless():
    key = jax.random.PRNGKey(2)
    p0 = _params(key)
    state = C.init(p0)
    p1 = jax.tree.map(lambda x: x * 1.5, p0)
    _, state = C.compress(p1, state, k_frac=1.0)
    for ref, tgt in zip(jax.tree.leaves(state.reference), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(ref), np.asarray(tgt), rtol=1e-6)


def test_wire_bytes_scale():
    p = {"a": jnp.zeros((1000,))}
    assert C.wire_bytes(p, k_frac=0.01) == 10 * 8
