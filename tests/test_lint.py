"""repro.lint: every rule locked by a triggering + clean fixture, and the
src tree (plus the runtime hash-compat / capability-matrix contracts) clean.

The fixture files under tests/fixtures/lint/ are linted by *content* with a
bare filename as the path — the D002 path allowlist would otherwise exempt
anything under tests/.
"""

import importlib.util
import pathlib

import pytest

import repro.lint as lint
from repro.lint import contracts

FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "lint"
ROOT = pathlib.Path(__file__).parent.parent


def lint_fixture(name: str):
    path = FIXTURES / name
    return lint.lint_source(path.read_text(), path.name)


def load_fixture_module(name: str):
    path = FIXTURES / name
    spec = importlib.util.spec_from_file_location(path.stem, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestAstRuleFixtures:
    @pytest.mark.parametrize("rule", ["J001", "J002", "D001", "D002", "P001",
                                      "L001"])
    def test_trigger_fires_and_clean_is_silent(self, rule):
        stem = rule.lower()
        trigger = lint_fixture(f"{stem}_trigger.py")
        assert any(f.rule == rule for f in trigger), (
            f"{stem}_trigger.py raised no {rule}: "
            f"{[f.format() for f in trigger]}"
        )
        clean = lint_fixture(f"{stem}_clean.py")
        assert clean == [], [f.format() for f in clean]

    def test_d001_catches_all_three_flavors(self):
        lines = {f.line for f in lint_fixture("d001_trigger.py")
                 if f.rule == "D001"}
        assert len(lines) >= 3  # import random, bare default_rng, np.random.seed

    def test_j002_sees_through_views_and_bound_methods(self):
        found = [f for f in lint_fixture("j002_trigger.py") if f.rule == "J002"]
        # the astype view at module scope AND the bound-method reshape/ravel
        assert len(found) >= 2

    def test_l001_pragma_does_not_suppress(self):
        rules = {f.rule for f in lint_fixture("l001_trigger.py")}
        assert rules == {"L001", "D002"}

    def test_findings_carry_location_and_hint(self):
        f = next(f for f in lint_fixture("j001_trigger.py")
                 if f.rule == "J001")
        assert f.path == "j001_trigger.py" and f.line > 0 and f.hint
        assert "j001_trigger.py:" in f.format() and "fix:" in f.format()


class TestHashCompat:
    def test_h001_fires_on_new_default_field_without_entry(self):
        """The acceptance demo: adding a default-valued field to the spec
        without a _HASH_OPTIONAL entry must fail the lint pass."""
        mod = load_fixture_module("h001_trigger.py")
        findings = contracts.check_hash_compat(mod.DriftSpec)
        assert any(f.rule == "H001" and "fancy_new_knob" in f.message
                   for f in findings)
        # and the golden pin catches the run-id drift itself
        assert any("drift" in f.message for f in findings)

    def test_h001_clean_with_registered_entry(self):
        mod = load_fixture_module("h001_clean.py")
        assert contracts.check_hash_compat(mod.CompatSpec) == []

    def test_h001_finds_stale_and_mismatched_entries(self):
        import dataclasses

        from repro.experiments.spec import ExperimentSpec

        @dataclasses.dataclass(frozen=True)
        class StaleSpec(ExperimentSpec):
            _HASH_OPTIONAL = {"faults": None, "ghost_field": 1}

        findings = contracts.check_hash_compat(StaleSpec)
        assert any("stale" in f.message for f in findings)

        @dataclasses.dataclass(frozen=True)
        class MismatchSpec(ExperimentSpec):
            knob: int = 3
            _HASH_OPTIONAL = {"faults": None, "knob": 4}  # default is 3

        findings = contracts.check_hash_compat(MismatchSpec, golden=None)
        assert any(f.rule == "H001" and "knob" in f.message for f in findings)

    def test_real_spec_is_clean(self):
        assert contracts.check_hash_compat() == []


class TestCapabilityMatrix:
    def test_trigger_fixture_drifts(self):
        text = (FIXTURES / "c001_trigger.md").read_text()
        findings = contracts.check_capability_matrix(
            text, readme_path="c001_trigger.md")
        assert any(f.rule == "C001" and "drifted" in f.message
                   for f in findings)

    def test_clean_fixture_matches_emitter(self):
        text = (FIXTURES / "c001_clean.md").read_text()
        assert contracts.check_capability_matrix(
            text, readme_path="c001_clean.md") == []

    def test_missing_markers_is_a_finding(self):
        findings = contracts.check_capability_matrix(
            "# README with no matrix\n", readme_path="x.md")
        assert any(f.rule == "C001" and "markers" in f.message
                   for f in findings)

    def test_emitter_row_per_backend(self):
        from repro.core.decavg import GossipEngine

        lines = contracts.capability_matrix_lines()
        assert len(lines) == 2 + len(GossipEngine.BACKENDS)
        for b in GossipEngine.BACKENDS:
            assert any(f"| `{b}` |" in l for l in lines)


class TestSrcTreeClean:
    def test_full_lint_pass_over_src(self):
        """What CI runs: AST rules over src/ plus H001/C001, zero findings."""
        nfiles, findings = lint.run([str(ROOT / "src")], root=str(ROOT))
        assert nfiles > 50
        assert findings == [], "\n".join(f.format() for f in findings)
