"""End-to-end decentralized training (paper-faithful path): learning
happens, gossip spreads knowledge, pallas path agrees with dense."""

import numpy as np
import pytest

from repro.core import partition as P
from repro.core import topology as T
from repro.data.loader import NodeLoader
from repro.train.trainer import DecentralizedTrainer


@pytest.fixture(scope="module")
def setup(request):
    from repro.data.synthetic import make_mnist_like

    ds = make_mnist_like(train_per_class=120, test_per_class=40, seed=0)
    g = T.erdos_renyi(12, 0.4, seed=0)
    parts = P.iid(ds.y_train, 12, seed=1)
    loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=32, seed=2)
    return ds, g, loader


def test_training_improves_accuracy(setup):
    ds, g, loader = setup
    tr = DecentralizedTrainer(g, loader, lr=0.05, momentum=0.9, seed=0)
    hist = tr.run(8, eval_every=7, x_test=ds.x_test, y_test=ds.y_test)
    assert hist[-1].mean_acc > max(0.3, hist[0].mean_acc + 0.1)


def test_knowledge_spread_vs_isolated(setup):
    """THE paper's core phenomenon: a node that never saw classes 5-9 gets
    them (well above chance) through gossip; without gossip it cannot."""
    ds, g, _ = setup
    parts = P.hub_focused(ds.y_train, g, seed=3)
    loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=32, seed=2)
    from repro.core.partition import partition_summary

    summ = partition_summary(ds.y_train, parts)
    have_not = np.flatnonzero(summ[:, 5:].sum(axis=1) == 0)
    assert len(have_not) > 0
    g2_mask = ds.y_test >= 5

    import jax
    import jax.numpy as jnp

    from repro.models.mlp import mlp_forward

    def g2_acc(trainer):
        accs = []
        for node in have_not:
            p = jax.tree.map(lambda l: l[node], trainer.params)
            logits = mlp_forward(p, jnp.asarray(ds.x_test[g2_mask]))
            accs.append(float((logits.argmax(-1) == ds.y_test[g2_mask]).mean()))
        return float(np.mean(accs))

    gossip = DecentralizedTrainer(g, loader, lr=0.05, momentum=0.9, seed=0)
    gossip.run(14)
    # isolated control: identity mixing (no edges used)
    isolated = DecentralizedTrainer(g, loader, lr=0.05, momentum=0.9, seed=0)
    isolated.w = jnp.eye(g.num_nodes)
    isolated._round_jit = jax.jit(isolated._round)
    isolated.run(14)

    assert g2_acc(isolated) < 0.12  # ~chance on unseen classes
    assert g2_acc(gossip) > g2_acc(isolated) + 0.15


def test_pallas_mix_path_runs(setup):
    ds, g, loader = setup
    tr = DecentralizedTrainer(g, loader, lr=0.05, mix_impl="pallas", seed=0)
    hist = tr.run(2, x_test=ds.x_test, y_test=ds.y_test)
    assert np.isfinite(hist[-1].mean_acc)


def test_checkpoint_roundtrip_mid_training(setup, tmp_path):
    import jax

    from repro.checkpoint import ckpt

    ds, g, loader = setup
    tr = DecentralizedTrainer(g, loader, lr=0.05, seed=0)
    tr.run(2)
    path = str(tmp_path / "state.npz")
    ckpt.save(path, {"params": tr.params, "opt": tr.opt_state._asdict()}, step=2)
    restored, step = ckpt.restore(path, {"params": tr.params, "opt": tr.opt_state._asdict()})
    assert step == 2
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves({"params": tr.params, "opt": tr.opt_state._asdict()})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
