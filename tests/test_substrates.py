"""Substrate tests: data pipeline, optimizers, schedules, checkpointing,
losses/metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.data import synthetic, tokens as tok
from repro.data.loader import NodeLoader
from repro.optim import adamw, schedules, sgd
from repro.train import losses, metrics


class TestSyntheticData:
    def test_deterministic(self):
        a = synthetic.make_mnist_like(train_per_class=20, test_per_class=5, seed=3)
        b = synthetic.make_mnist_like(train_per_class=20, test_per_class=5, seed=3)
        np.testing.assert_array_equal(a.x_train, b.x_train)

    def test_ranges_and_classes(self):
        ds = synthetic.make_mnist_like(train_per_class=30, test_per_class=10, seed=0)
        assert ds.x_train.min() >= 0.0 and ds.x_train.max() <= 1.0
        assert ds.num_classes == 10
        assert len(ds.x_train) == 300 and len(ds.x_test) == 100

    def test_learnable_but_not_trivial(self):
        """A linear probe separates classes (learnable) but not perfectly
        (within-class variation is real)."""
        ds = synthetic.make_mnist_like(train_per_class=100, test_per_class=50, seed=0)
        # one ridge-regression step as a cheap probe
        x, y = ds.x_train, ds.y_train
        yoh = np.eye(10)[y]
        wmat = np.linalg.solve(x.T @ x + 10 * np.eye(784), x.T @ yoh)
        acc = (np.argmax(ds.x_test @ wmat, 1) == ds.y_test).mean()
        assert 0.5 < acc < 0.999


class TestLoader:
    def test_round_shapes(self):
        ds = synthetic.make_mnist_like(train_per_class=30, test_per_class=5, seed=0)
        parts = [np.arange(i * 30, (i + 1) * 30) for i in range(10)]
        loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=8, seed=0)
        xs, ys = loader.sample_round(3)
        assert xs.shape == (3, 10, 8, 784)
        assert ys.shape == (3, 10, 8)
        # samples come from each node's own pool
        for n in range(10):
            assert set(np.unique(ys[:, n])) <= set(np.unique(ds.y_train[parts[n]]))

    def test_empty_node_raises(self):
        ds = synthetic.make_mnist_like(train_per_class=10, test_per_class=5, seed=0)
        loader = NodeLoader(ds.x_train, ds.y_train, [np.array([], np.int64)], batch_size=4)
        with pytest.raises(ValueError):
            loader.sample_round(1)


class TestTokens:
    def test_stream_shapes_and_determinism(self):
        batches = list(tok.token_batches(4, 2, 16, 1000, steps=3, seed=0))
        assert len(batches) == 3
        t, l = batches[0]
        assert t.shape == (4, 2, 16) and l.shape == (4, 2, 16)
        np.testing.assert_array_equal(t[:, :, 1:], l[:, :, :-1])  # next-token shift
        again = list(tok.token_batches(4, 2, 16, 1000, steps=3, seed=0))
        np.testing.assert_array_equal(batches[1][0], again[1][0])

    def test_domain_skew(self):
        """Different nodes see measurably different token distributions."""
        a = tok.node_token_stream(0, 20000, 4096, seed=0)
        b = tok.node_token_stream(1, 20000, 4096, seed=0)
        ha = np.bincount(a, minlength=4096) / len(a)
        hb = np.bincount(b, minlength=4096) / len(b)
        assert 0.5 * np.abs(ha - hb).sum() > 0.1  # total-variation distance


class TestOptim:
    def test_sgd_momentum_math(self):
        p = {"w": jnp.ones((3,))}
        g = {"w": jnp.full((3,), 0.5)}
        st_ = sgd.init(p)
        p1, st1 = sgd.update(g, st_, p, lr=0.1, mu=0.5)
        np.testing.assert_allclose(np.asarray(p1["w"]), 1 - 0.1 * 0.5)
        p2, st2 = sgd.update(g, st1, p1, lr=0.1, mu=0.5)
        # momentum: m2 = 0.5*0.5 + 0.5 = 0.75
        np.testing.assert_allclose(np.asarray(st2.momentum["w"]), 0.75)

    def test_adamw_reduces_loss(self):
        key = jax.random.PRNGKey(0)
        w_true = jax.random.normal(key, (8,))
        x = jax.random.normal(jax.random.fold_in(key, 1), (64, 8))
        y = x @ w_true

        params = {"w": jnp.zeros((8,))}
        st_ = adamw.init(params)
        loss = lambda p: jnp.mean((x @ p["w"] - y) ** 2)
        l0 = float(loss(params))
        for _ in range(100):
            g = jax.grad(loss)(params)
            params, st_ = adamw.update(g, st_, params, lr=0.05, weight_decay=0.0)
        assert float(loss(params)) < 0.01 * l0

    def test_wsd_schedule_shape(self):
        fn = schedules.wsd(1.0, 1000)
        lrs = np.array([float(fn(s)) for s in [0, 5, 300, 600, 899, 950, 999]])
        assert lrs[0] < 0.6  # warmup
        np.testing.assert_allclose(lrs[2:5], 1.0, atol=1e-2)  # stable stage
        assert lrs[5] < 0.5 and lrs[6] < 0.02  # sharp decay tail

    @given(st.integers(1, 10**6))
    @settings(max_examples=20, deadline=None)
    def test_schedules_positive(self, step):
        for name in ("const", "cosine", "wsd"):
            fn = schedules.get(name, 3e-4, 10**6)
            assert 0 <= float(fn(step)) <= 3e-4 + 1e-9


class TestLossesMetrics:
    def test_xent_matches_manual(self):
        logits = jnp.array([[2.0, 0.0], [0.0, 2.0]])
        labels = jnp.array([0, 0])
        want = np.mean([np.log(1 + np.exp(-2.0)), np.log(1 + np.exp(2.0))])
        np.testing.assert_allclose(float(losses.softmax_xent(logits, labels)), want, rtol=1e-6)

    def test_lm_loss_ignore_index(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 8))
        labels = jnp.array([[1, 2, -1, -1]])
        full = losses.lm_loss(logits, jnp.array([[1, 2, 3, 4]]))
        masked = losses.lm_loss(logits, labels)
        manual = losses.lm_loss(logits[:, :2], jnp.array([[1, 2]]))
        np.testing.assert_allclose(float(masked), float(manual), rtol=1e-6)
        assert float(masked) != pytest.approx(float(full))

    def test_confusion_matrix_rows(self):
        logits = jnp.eye(4)[jnp.array([0, 1, 1, 3])] * 5  # predictions 0,1,1,3
        labels = jnp.array([0, 1, 2, 3])
        cm = metrics.confusion_matrix(logits, labels, 4)
        assert float(cm[0, 0]) == 1.0
        assert float(cm[2, 1]) == 1.0  # true 2 predicted 1
        assert float(cm[2, 2]) == 0.0


class TestCheckpoint:
    def test_roundtrip_dtypes(self, tmp_path):
        tree = {
            "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": jnp.array(3, jnp.int32)},
            "e": [jnp.zeros((2,)), jnp.ones((2,), jnp.bfloat16)],
        }
        path = str(tmp_path / "x.npz")
        ckpt.save(path, tree, step=17)
        back, step = ckpt.restore(path, tree)
        assert step == 17
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))

    def test_shape_mismatch_raises(self, tmp_path):
        path = str(tmp_path / "y.npz")
        ckpt.save(path, {"a": jnp.ones((2,))})
        with pytest.raises(ValueError):
            ckpt.restore(path, {"a": jnp.ones((3,))})
