"""Serving layer: batched generation, sliding-window cache sizing, and the
launch-level serve/prefill step builders."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.launch import steps as ST
from repro.models import transformer as TF
from repro.serve import decode as SD


@pytest.mark.parametrize("arch", ["llama32_1b", "rwkv6_3b"])
def test_generate_greedy(arch):
    cfg = cfgbase.get(arch).reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, cfg.vocab_size)
    cache = TF.init_cache(cfg, 2, 32)
    toks = SD.generate(
        params, cfg, prompt, cache, steps=6, key=jax.random.PRNGKey(2)
    )
    assert toks.shape == (2, 6)
    assert toks.dtype == jnp.int32
    assert int(toks.max()) < cfg.vocab_size
    # greedy generation is deterministic
    toks2 = SD.generate(
        params, cfg, prompt, TF.init_cache(cfg, 2, 32), steps=6, key=jax.random.PRNGKey(9)
    )
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_cache_len_policy():
    cfg = cfgbase.get("llama32_1b")
    assert SD.cache_len_for(cfg, 32768, long_context=False) == 32768
    assert SD.cache_len_for(cfg, 524288, long_context=True) == cfg.sliding_window


def test_serve_step_builder_windowed():
    """long_500k-style decode: window-length ring cache, arbitrary position."""
    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    window = cfg.sliding_window  # 16 in reduced configs
    step = ST.build_serve_step(cfg, window=window)
    cache = TF.init_cache(cfg, 2, window)
    tok = jnp.array([1, 2], jnp.int32)
    for _ in range(window + 5):  # run past the ring boundary
        tok, cache = jax.jit(step)(params, tok, cache)
    assert bool(jnp.all(tok >= 0)) and int(tok.max()) < cfg.vocab_size


def test_prefill_step_builder_matches_forward():
    cfg = cfgbase.get("minicpm_2b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab_size)
    step = ST.build_prefill_step(cfg)
    got = jax.jit(step)(params, {"tokens": tokens})
    logits, _ = TF.forward(params, cfg, tokens)
    want = jnp.argmax(logits[:, -1], axis=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_whisper_serve_with_memory():
    cfg = cfgbase.get("whisper_base").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model), cfg.dtype())
    memory = TF.encode(params, cfg, frames)
    step = ST.build_serve_step(cfg)
    cache = TF.init_cache(cfg, 2, 16)
    tok = jnp.zeros((2,), jnp.int32)
    tok, cache = jax.jit(step)(params, tok, cache, memory)
    assert tok.shape == (2,)


# -- chunked prefill (PR: batched serving engine) ---------------------------


@pytest.mark.parametrize("arch", ["llama32_1b", "stablelm_3b", "rwkv6_3b"])
def test_chunked_prefill_matches_sequential(arch):
    """One full-sequence forward writes the same cache/logits as feeding the
    prompt token-by-token through decode_step."""
    cfg = cfgbase.get(arch).reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    lg_c, cache_c = SD.prefill(params, cfg, prompt, TF.init_cache(cfg, 2, 32), flash=False)
    lg_s, cache_s = SD.prefill_sequential(params, cfg, prompt, TF.init_cache(cfg, 2, 32))
    np.testing.assert_allclose(np.asarray(lg_c), np.asarray(lg_s), atol=2e-5, rtol=2e-5)
    # the caches must CONTINUE identically, not just score the last token
    tok = jnp.argmax(lg_c, axis=-1).astype(jnp.int32)
    for _ in range(4):
        lc, cache_c = TF.decode_step(params, cfg, tok, cache_c)
        ls, cache_s = TF.decode_step(params, cfg, tok, cache_s)
        np.testing.assert_allclose(np.asarray(lc), np.asarray(ls), atol=2e-5, rtol=2e-5)
        tok = jnp.argmax(lc, axis=-1).astype(jnp.int32)


def test_prefill_flash_matches_reference():
    """Pallas flash kernel (interpret mode on CPU) == reference attention."""
    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    lg_ref, c_ref = SD.prefill(params, cfg, prompt, TF.init_cache(cfg, 2, 32), flash=False)
    lg_fl, c_fl = SD.prefill(params, cfg, prompt, TF.init_cache(cfg, 2, 32), flash=True)
    np.testing.assert_allclose(np.asarray(lg_fl), np.asarray(lg_ref), atol=1e-4, rtol=1e-4)
    for a, b in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c_fl)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-4, rtol=1e-4
        )


def test_prefill_padded_lengths_per_slot():
    """Right-padded prompts with per-row lengths serve identically to each
    prompt prefilled alone at its true length."""
    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    lens = [5, 9, 12]
    full = jax.random.randint(jax.random.PRNGKey(1), (3, 12), 0, cfg.vocab_size)
    padded = np.zeros((3, 12), np.int32)
    for i, n in enumerate(lens):
        padded[i, :n] = np.asarray(full)[i, :n]
    cache = TF.init_cache(cfg, 3, 32, per_slot=True)
    lg, cache = SD.prefill(
        params, cfg, jnp.asarray(padded), cache,
        length=jnp.asarray(lens, jnp.int32), flash=False,
    )
    for i, n in enumerate(lens):
        ref_lg, ref_cache = SD.prefill_sequential(
            params, cfg, full[i : i + 1, :n], TF.init_cache(cfg, 1, 32)
        )
        np.testing.assert_allclose(
            np.asarray(lg[i]), np.asarray(ref_lg[0]), atol=2e-5, rtol=2e-5
        )


def test_prefill_vector_length_requires_per_slot_cache():
    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jnp.zeros((2, 8), jnp.int32)
    with pytest.raises(ValueError, match="per-slot"):
        SD.prefill(
            params, cfg, prompt, TF.init_cache(cfg, 2, 16),
            length=jnp.array([4, 6], jnp.int32), flash=False,
        )


def test_prefill_padded_overflow_raises():
    """Right-padded rows + prompt wider than the ring is the one combination
    the ring contract cannot survive (padded slots would wrap below the
    written index and be attended as real context) — it must raise, not
    silently corrupt. Padding alone and overflow alone are each covered
    above (test_prefill_padded_lengths_per_slot,
    test_prompt_longer_than_cache_window)."""
    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    ring = 16
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab_size)
    cache = TF.init_cache(cfg, 2, ring, per_slot=True)
    with pytest.raises(ValueError, match="padded"):
        SD.prefill(
            params, cfg, prompt, cache,
            length=jnp.array([20, 24], jnp.int32), flash=False,
        )


def test_cache_len_for_clamps_to_seq():
    cfg = cfgbase.get("llama32_1b")
    # window policy clamps BOTH ways: never longer than the window, never
    # longer than the sequence itself
    assert SD.cache_len_for(cfg, 8, long_context=True) == 8
    assert SD.cache_len_for(cfg, 10 * cfg.sliding_window, long_context=True) == cfg.sliding_window
    assert SD.cache_len_for(cfg, 8, long_context=False) == 8


def test_generate_temperature_zero_equals_explicit_greedy():
    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab_size)
    steps = 5
    got = SD.generate(
        params, cfg, prompt, TF.init_cache(cfg, 2, 32), steps=steps,
        key=jax.random.PRNGKey(7),
    )
    # hand-rolled greedy loop over the sequential reference path
    logits, cache = SD.prefill_sequential(params, cfg, prompt, TF.init_cache(cfg, 2, 32))
    toks = []
    for _ in range(steps):
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(np.asarray(tok))
        logits, cache = TF.decode_step(params, cfg, tok, cache)
    np.testing.assert_array_equal(np.asarray(got), np.stack(toks, axis=1))


def test_prompt_longer_than_cache_window():
    """Prompt longer than the ring cache: chunked prefill masks to the window
    and lands the same ring state as sequential windowed decode."""
    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    window = cfg.sliding_window  # 16 in reduced configs
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab_size)
    assert prompt.shape[1] > window
    lg_c, cache_c = SD.prefill(
        params, cfg, prompt, TF.init_cache(cfg, 2, window), window=window, flash=False
    )
    lg_s, cache_s = SD.prefill_sequential(
        params, cfg, prompt, TF.init_cache(cfg, 2, window), window=window
    )
    np.testing.assert_allclose(np.asarray(lg_c), np.asarray(lg_s), atol=2e-5, rtol=2e-5)
    tok = jnp.argmax(lg_c, axis=-1).astype(jnp.int32)
    for _ in range(window + 2):  # continue past another full ring revolution
        lc, cache_c = TF.decode_step(params, cfg, tok, cache_c, window=window)
        ls, cache_s = TF.decode_step(params, cfg, tok, cache_s, window=window)
        np.testing.assert_allclose(np.asarray(lc), np.asarray(ls), atol=2e-5, rtol=2e-5)
        tok = jnp.argmax(lc, axis=-1).astype(jnp.int32)


# -- continuous batching engine ---------------------------------------------


def test_engine_token_identical_to_generate():
    """Staggered arrivals through 2 slots produce exactly the tokens the
    sequential ``generate`` produces for each prompt alone (temperature=0)."""
    from repro.serve.engine import Engine

    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 3, 7)]
    max_new = [6, 4, 5, 6]

    eng = Engine(params, cfg, slots=2, cache_len=32, flash=False)
    r0 = eng.submit(prompts[0], max_new=max_new[0])
    r1 = eng.submit(prompts[1], max_new=max_new[1])
    eng.step(); eng.step()  # partially drain before the late arrivals
    r2 = eng.submit(prompts[2], max_new=max_new[2])
    r3 = eng.submit(prompts[3], max_new=max_new[3])
    out = eng.run()
    assert sorted(out) == [r0, r1, r2, r3]

    for rid, p, n in zip((r0, r1, r2, r3), prompts, max_new):
        want = SD.generate(
            params, cfg, jnp.asarray(p)[None], TF.init_cache(cfg, 1, 32),
            steps=n, key=jax.random.PRNGKey(0),
        )
        np.testing.assert_array_equal(out[rid], np.asarray(want)[0])


def test_engine_streams_and_retires():
    from repro.serve.engine import Engine, _bucket

    assert _bucket(1) == 8 and _bucket(8) == 8 and _bucket(9) == 16
    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, slots=2, cache_len=32, flash=False)
    rid = eng.submit([1, 2, 3], max_new=3)
    events = []
    for ev in iter(eng.step, []):
        events.extend(ev)
    assert [e["rid"] for e in events] == [rid] * 3
    assert [e["done"] for e in events] == [False, False, True]
    # slot freed: a new request reuses it without recompiling; run() collects
    # everything finished since the last collection (the streamed one too)
    rid2 = eng.submit([4, 5], max_new=1)
    out = eng.run()
    assert sorted(out) == [rid, rid2] and out[rid2].shape == (1,)
    assert np.array_equal(out[rid], [e["token"] for e in events])


def test_engine_rejects_recurrent_patterns():
    from repro.serve.engine import Engine, engine_ok

    cfg = cfgbase.get("rwkv6_3b").reduced()
    assert not engine_ok(cfg)
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="attention-only"):
        Engine(params, cfg, slots=2, cache_len=16)


def test_engine_rejects_prompt_longer_than_cache():
    """A prompt that cannot fit the slot cache must be refused at submit():
    admitting it would pad past the ring and silently corrupt the output."""
    from repro.serve.engine import Engine

    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, slots=2, cache_len=16, flash=False)
    with pytest.raises(ValueError, match="cache_len"):
        eng.submit(list(range(1, 18)), max_new=2)
    ok = eng.submit(list(range(1, 17)), max_new=2)  # exactly cache_len fits
    assert eng.run()[ok].shape == (2,)


def test_engine_non_pow2_cache_len_token_identical():
    """cache_len=24 (not a power of two): the pow2 pad bucket above a
    20-token prompt overshoots the ring, so admission must cap the pad at
    cache_len — and still match sequential generate exactly."""
    from repro.serve.engine import Engine

    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    cache_len = 24
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(4), (20,), 0, cfg.vocab_size),
        np.int32,
    )
    eng = Engine(params, cfg, slots=2, cache_len=cache_len, flash=False)
    rid = eng.submit(prompt, max_new=4)
    out = eng.run()
    want = SD.generate(
        params, cfg, jnp.asarray(prompt)[None],
        TF.init_cache(cfg, 1, cache_len), steps=4, key=jax.random.PRNGKey(0),
    )
    np.testing.assert_array_equal(out[rid], np.asarray(want)[0])


def test_engine_sampled_smoke():
    from repro.serve.engine import Engine

    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, slots=2, cache_len=32, temperature=0.8, seed=5, flash=False)
    a = eng.submit([1, 2, 3, 4], max_new=4)
    b = eng.submit([9, 8], max_new=4)
    out = eng.run()
    assert out[a].shape == (4,) and out[b].shape == (4,)
    assert int(max(out[a].max(), out[b].max())) < cfg.vocab_size
