"""Serving layer: batched generation, sliding-window cache sizing, and the
launch-level serve/prefill step builders."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.launch import steps as ST
from repro.models import transformer as TF
from repro.serve import decode as SD


@pytest.mark.parametrize("arch", ["llama32_1b", "rwkv6_3b"])
def test_generate_greedy(arch):
    cfg = cfgbase.get(arch).reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, cfg.vocab_size)
    cache = TF.init_cache(cfg, 2, 32)
    toks = SD.generate(
        params, cfg, prompt, cache, steps=6, key=jax.random.PRNGKey(2)
    )
    assert toks.shape == (2, 6)
    assert toks.dtype == jnp.int32
    assert int(toks.max()) < cfg.vocab_size
    # greedy generation is deterministic
    toks2 = SD.generate(
        params, cfg, prompt, TF.init_cache(cfg, 2, 32), steps=6, key=jax.random.PRNGKey(9)
    )
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_cache_len_policy():
    cfg = cfgbase.get("llama32_1b")
    assert SD.cache_len_for(cfg, 32768, long_context=False) == 32768
    assert SD.cache_len_for(cfg, 524288, long_context=True) == cfg.sliding_window


def test_serve_step_builder_windowed():
    """long_500k-style decode: window-length ring cache, arbitrary position."""
    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    window = cfg.sliding_window  # 16 in reduced configs
    step = ST.build_serve_step(cfg, window=window)
    cache = TF.init_cache(cfg, 2, window)
    tok = jnp.array([1, 2], jnp.int32)
    for _ in range(window + 5):  # run past the ring boundary
        tok, cache = jax.jit(step)(params, tok, cache)
    assert bool(jnp.all(tok >= 0)) and int(tok.max()) < cfg.vocab_size


def test_prefill_step_builder_matches_forward():
    cfg = cfgbase.get("minicpm_2b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab_size)
    step = ST.build_prefill_step(cfg)
    got = jax.jit(step)(params, {"tokens": tokens})
    logits, _ = TF.forward(params, cfg, tokens)
    want = jnp.argmax(logits[:, -1], axis=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_whisper_serve_with_memory():
    cfg = cfgbase.get("whisper_base").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model), cfg.dtype())
    memory = TF.encode(params, cfg, frames)
    step = ST.build_serve_step(cfg)
    cache = TF.init_cache(cfg, 2, 16)
    tok = jnp.zeros((2,), jnp.int32)
    tok, cache = jax.jit(step)(params, tok, cache, memory)
    assert tok.shape == (2,)
