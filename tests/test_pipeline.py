"""§Perf optimization correctness: sparse permute gossip, int8 KV cache,
manual pipeline-parallel decode (subprocess with fake devices)."""

import subprocess
import sys
import textwrap

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import requires_axis_type

from repro.configs import base as cfgbase
from repro.models import transformer as TF


def test_int8_kv_cache_close_to_bf16():
    """int8 decode logits stay within quantization tolerance of exact."""
    cfg = cfgbase.get("llama32_1b").reduced()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    B, T = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    exact = TF.init_cache(cfg, B, T)
    quant = TF.init_cache(cfg, B, T, kv_quant=True)
    errs = []
    for t in range(T):
        le, exact = TF.decode_step(params, cfg, tokens[:, t], exact)
        lq, quant = TF.decode_step(params, cfg, tokens[:, t], quant)
        errs.append(float(jnp.max(jnp.abs(le - lq))))
    scale = float(jnp.max(jnp.abs(le)))
    assert max(errs) < 0.05 * max(scale, 1.0), f"int8 err {max(errs)} vs scale {scale}"


@requires_axis_type
def test_sparse_gossip_equals_dense_subprocess():
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import topology as T, mixing as M, decavg as D
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        g = T.erdos_renyi(4, 0.6, seed=2)
        sizes = np.array([3.0, 1.0, 2.0, 4.0])
        w = jnp.asarray(M.decavg_matrix(g, sizes), jnp.float32)
        colors = M.edge_coloring(g)
        params = {"a": jax.random.normal(jax.random.PRNGKey(0), (4, 9, 5))}
        dense = D.mix_dense(w, params)
        sparse = D.mix_permute(w, params, colors, mesh=mesh, node_axis="data")
        np.testing.assert_allclose(np.asarray(sparse["a"]), np.asarray(dense["a"]),
                                   rtol=2e-5, atol=2e-5)
        print("OK")
        """
    )
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_edge_coloring_is_proper():
    from hypothesis import given, settings, strategies as st

    from repro.core import mixing as M, topology as T

    @given(st.integers(4, 24), st.floats(0.1, 0.9), st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def inner(n, p, seed):
        g = T.erdos_renyi(n, p, seed=seed)
        colors = M.edge_coloring(g)
        seen = set()
        for pairs in colors:
            srcs = [s for s, _ in pairs]
            dsts = [d for _, d in pairs]
            assert len(set(srcs)) == len(srcs), "color class has duplicate sources"
            assert len(set(dsts)) == len(dsts), "color class has duplicate dests"
            seen.update((s, d) for s, d in pairs)
        # every edge covered in both directions
        ii, jj = np.nonzero(g.adj)
        assert seen == {(int(a), int(b)) for a, b in zip(ii, jj)}

    inner()


@requires_axis_type
def test_manual_pipeline_matches_decode_subprocess():
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import base as cfgbase
        from repro.models import transformer as TF
        from repro.serve import pipeline_manual as PM
        cfg = dataclasses.replace(
            cfgbase.get("llama32_1b").reduced(),
            num_layers=4, num_heads=4, num_kv_heads=2, head_dim=32,
            d_model=128, d_ff=256, vocab_size=512)
        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        params = TF.init_params(jax.random.PRNGKey(0), cfg)
        B, T = 4, 16
        ref_cache = TF.init_cache(cfg, B, T, kv_quant=True)
        tok = jnp.array([1, 2, 3, 4], jnp.int32)
        refs, t = [], tok
        for _ in range(4):
            logits, ref_cache = TF.decode_step(params, cfg, t, ref_cache)
            t = jnp.argmax(logits, -1).astype(jnp.int32)
            refs.append(t)
        step = PM.build_manual_pipeline_step(cfg, mesh)
        cache = PM.init_kv_cache(cfg, B, T, tp=2)
        t = tok
        for i in range(4):
            t, cache = jax.jit(step)(params, t, cache)
            assert np.array_equal(np.asarray(t), np.asarray(refs[i])), i
        print("OK")
        """
    )
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True, timeout=400)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_gpipe_microbatch_slice_and_write():
    """The shared GPipe helpers are plain JAX — testable without shard_map."""
    from repro.serve import gpipe

    def is_index(path):
        last = path[-1]
        return str(getattr(last, "key", last)) == "index"

    tree = {
        "k": jnp.arange(24, dtype=jnp.float32).reshape(2, 4, 3),
        "index": jnp.array([5, 5], jnp.int32),
    }
    sub = gpipe.microbatch_slice(tree, 1, 2, skip=is_index)
    np.testing.assert_array_equal(np.asarray(sub["k"]), np.asarray(tree["k"][:, 2:4]))
    np.testing.assert_array_equal(np.asarray(sub["index"]), [5, 5])  # passed whole

    new = {"k": jnp.full((2, 2, 3), -1.0), "index": jnp.array([9, 9], jnp.int32)}
    wrote = gpipe.microbatch_write(tree, new, 1, 2, jnp.asarray(True), skip=is_index)
    np.testing.assert_array_equal(np.asarray(wrote["k"][:, 2:4]), np.asarray(new["k"]))
    np.testing.assert_array_equal(np.asarray(wrote["k"][:, :2]), np.asarray(tree["k"][:, :2]))
    np.testing.assert_array_equal(np.asarray(wrote["index"]), [5, 5])  # skip wins

    # the warm-up/drain bubble: inactive ticks keep the old rows
    kept = gpipe.microbatch_write(tree, new, 1, 2, jnp.asarray(False), skip=is_index)
    np.testing.assert_array_equal(np.asarray(kept["k"]), np.asarray(tree["k"]))


def test_pipeline_entry_point_dispatch():
    """build_pipeline_step validates configs for both variants up front."""
    from repro.serve import pipeline as PL

    cfg = cfgbase.get("whisper_base").reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="decoder-only"):
        PL.build_pipeline_step(cfg, mesh)
    with pytest.raises(ValueError, match="dense decoder-only"):
        PL.build_pipeline_step(cfg, mesh, manual=True)
