"""Topology-aware routing + params-only checkpoint restore (serving stack)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import base as cfgbase
from repro.models import transformer as TF
from repro.serve import decode as SD
from repro.serve.router import CohortRouter, load_cohort, stacked_params_like


def _stacked_params(cfg, nodes, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), nodes)
    per = [TF.init_params(k, cfg) for k in keys]
    return jax.tree.map(lambda *ls: jnp.stack(ls), *per)


@pytest.fixture(scope="module")
def tiny():
    cfg = cfgbase.get("llama32_1b").reduced()
    return cfg, _stacked_params(cfg, 3)


def test_router_coverage_and_classify(tiny):
    cfg, params = tiny
    router = CohortRouter(params, cfg, seed=0, domain_size=16, coverage_batch=2, coverage_seq=8)
    assert router.nodes == 3
    assert router.coverage.shape == (3, 3)
    assert np.isfinite(router.coverage).all()
    # a query made of domain j's own token set classifies as j
    for j in range(3):
        assert router.classify(router.domains[j]) == j


def test_router_policies(tiny):
    cfg, params = tiny
    router = CohortRouter(params, cfg, seed=0, domain_size=16, coverage_batch=2, coverage_seq=8)
    q = router.domains[1]
    # pinned node id passes through (and range-checks)
    assert router.route(q, route=2) == 2
    with pytest.raises(ValueError, match="out of range"):
        router.route(q, route=7)
    with pytest.raises(ValueError, match="route must be"):
        router.route(q, route="nearest")
    # round_robin cycles every node and honors exclusions
    assert [router.route(q, route="round_robin") for _ in range(4)] == [0, 1, 2, 0]
    assert router.route(q, route="round_robin", exclude=(1,)) in (0, 2)
    with pytest.raises(ValueError, match="every node excluded"):
        router.route(q, exclude=(0, 1, 2))
    # "best" follows the coverage table exactly; exclusion falls through to
    # the runner-up (the owner-offline scenario)
    router.coverage = np.array([[0.1, 0.9, 0.2],
                                [0.3, 0.5, 0.1],
                                [0.2, 0.8, 0.7]])
    assert router.route(q, route="best") == 0  # argmax of column classify(q)=1
    assert router.route(q, route="best", exclude=(0,)) == 2


def test_restore_subtree_params_only_bitwise(tiny, tmp_path):
    """Serving restores params bit-identically from a trainer checkpoint
    without materializing the optimizer subtree."""
    cfg, params = tiny
    opt = {
        "mu": jax.tree.map(jnp.zeros_like, params),
        "nu": jax.tree.map(jnp.ones_like, params),
    }
    path = str(tmp_path / "cohort.npz")
    ckpt.save(path, {"params": params, "opt": opt}, step=42)

    like = stacked_params_like(cfg, 3)
    got, step = ckpt.restore_subtree(path, like, prefix="params")
    assert step == 42
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8)
        )

    # end to end: a node served from the restored tree generates the exact
    # same tokens as the in-memory original
    node0 = jax.tree.map(lambda l: l[0], got)
    orig0 = jax.tree.map(lambda l: l[0], params)
    prompt = jnp.asarray([[3, 1, 4, 1, 5]], jnp.int32)
    t_got = SD.generate(node0, cfg, prompt, TF.init_cache(cfg, 1, 16),
                        steps=4, key=jax.random.PRNGKey(0))
    t_want = SD.generate(orig0, cfg, prompt, TF.init_cache(cfg, 1, 16),
                         steps=4, key=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(t_got), np.asarray(t_want))


def test_restore_subtree_bad_prefix(tiny, tmp_path):
    cfg, params = tiny
    path = str(tmp_path / "c.npz")
    ckpt.save(path, {"params": params})
    with pytest.raises(KeyError, match="available top-level"):
        ckpt.restore_subtree(path, stacked_params_like(cfg, 3), prefix="opt")
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore_subtree(path, stacked_params_like(cfg, 4), prefix="params")


def test_load_cohort_roundtrip(tiny, tmp_path):
    cfg, params = tiny
    path = str(tmp_path / "c2.npz")
    ckpt.save(path, {"params": params, "opt": {"x": jnp.zeros(3)}}, step=7)
    got, step = load_cohort(path, cfg, nodes=3)
    assert step == 7
    assert jax.tree.structure(got) == jax.tree.structure(params)
