"""End-to-end system behaviour: the paper's qualitative claims reproduced at
test scale, plus the launch-layer step builders wired together."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mixing, partition as P, topology as T
from repro.data.loader import NodeLoader
from repro.data.synthetic import make_mnist_like
from repro.train.trainer import DecentralizedTrainer


@pytest.fixture(scope="module")
def ds():
    return make_mnist_like(train_per_class=150, test_per_class=40, seed=0)


def _final_acc(g, parts, ds, rounds=8, lr=0.05):
    loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=32, seed=2)
    tr = DecentralizedTrainer(g, loader, lr=lr, momentum=0.9, seed=0)
    hist = tr.run(rounds, eval_every=rounds - 1, x_test=ds.x_test, y_test=ds.y_test)
    return hist[-1]


def test_hub_beats_edge_focus(ds):
    """Claim (ii)/(iii): knowledge (the G2 classes) spreads to nodes that
    never saw it far better when the holders are hubs than when they are
    leaves. Measured exactly as the paper frames it: accuracy on the held
    classes at NON-holder nodes (overall mean accuracy is confounded at
    test scale by local data-diversity effects)."""
    import jax
    import jax.numpy as jnp

    from repro.models.mlp import mlp_forward

    g = T.barabasi_albert(20, 2, seed=0)
    g2_mask = ds.y_test >= 5

    def g2_at_nonholders(part_fn):
        parts = part_fn(ds.y_train, g, seed=1)
        summ = P.partition_summary(ds.y_train, parts)
        nonholders = np.flatnonzero(summ[:, 5:].sum(axis=1) == 0)
        loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=32, seed=2)
        tr = DecentralizedTrainer(g, loader, lr=0.05, momentum=0.9, seed=0)
        tr.run(15)
        accs = []
        for node in nonholders:
            p = jax.tree.map(lambda l: l[node], tr.params)
            lg = mlp_forward(p, jnp.asarray(ds.x_test[g2_mask]))
            accs.append(float((lg.argmax(-1) == ds.y_test[g2_mask]).mean()))
        return float(np.mean(accs))

    hub = g2_at_nonholders(P.hub_focused)
    edge = g2_at_nonholders(P.edge_focused)
    assert hub > edge + 0.1, f"hub {hub} vs edge {edge}"


def test_sbm_communities_trap_knowledge(ds):
    """Claim (iv): with community-exclusive classes, per-node accuracy stays
    near the intra-community ceiling early in training."""
    g = T.stochastic_block_model([5] * 4, 0.8, 0.02, seed=0)
    parts = P.community(ds.y_train, g, seed=1)
    keep = ds.y_test < 8
    import dataclasses

    ds8 = dataclasses.replace(ds, x_test=ds.x_test[keep], y_test=ds.y_test[keep])
    res = _final_acc(g, parts, ds8, rounds=6)
    # 2-of-8 intra ceiling = 0.25; a tight SBM shouldn't be far above it yet,
    # but learning should have brought it near that ceiling.
    assert 0.10 < res.mean_acc < 0.45


def test_spectral_gap_predicts_consensus_speed(ds):
    """System invariant: topology's spectral gap orders consensus speed."""
    from repro.core.decavg import gossip_error, mix_dense

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (30, 64))}
    errs = {}
    for p in (0.1, 0.6):
        g = T.erdos_renyi(30, p, seed=1)
        w = jnp.asarray(mixing.decavg_matrix(g, np.ones(30)), jnp.float32)
        cur = params
        for _ in range(3):
            cur = mix_dense(w, cur)
        errs[p] = float(gossip_error(cur))
    assert errs[0.6] < errs[0.1]


def test_llm_cohort_loss_decreases():
    """Decentralized LLM training (the launch path) reduces loss."""
    import dataclasses

    from repro.configs import base as cfgbase
    from repro.data import tokens as tok
    from repro.launch import steps as ST
    from repro.models import transformer as TF
    from repro.optim import adamw

    cfg = dataclasses.replace(
        cfgbase.get("llama32_1b").reduced(),
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256,
    )
    n = 2
    per_node = TF.init_params(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), per_node)
    opt = adamw.init(params)
    w = jnp.full((n, n), 0.5, jnp.float32)
    step = jax.jit(ST.build_train_step(cfg, num_nodes=n, lr=1e-2))
    losses = []
    for toks, labels in tok.token_batches(n, 4, 32, cfg.vocab_size, steps=30, seed=0):
        batch = {"tokens": jnp.asarray(toks)[None], "labels": jnp.asarray(labels)[None]}
        params, opt, loss = step(params, opt, w, batch)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_sharding_rules_consistent():
    """leaf_spec emits valid divisible specs for every arch's full params."""
    from repro.configs import base as cfgbase
    from repro.launch import sharding as SR
    from repro.models import transformer as TF

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    for arch in cfgbase.ASSIGNED_ARCHS:
        cfg = cfgbase.get(arch)
        shapes = jax.eval_shape(lambda c=cfg: TF.init_params(jax.random.PRNGKey(0), c))
        flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
        for path, leaf in flat:
            pstr = SR._path_str(path)
            spec = SR.leaf_spec(pstr, tuple(leaf.shape), cfg, FakeMesh(), has_node_axis=False)
            # every named axis must divide its dim
            for dim, s in zip(leaf.shape, spec):
                if s is None:
                    continue
                axes = (s,) if isinstance(s, str) else s
                size = 1
                for a in axes:
                    size *= FakeMesh.shape[a]
                assert dim % size == 0, f"{arch} {pstr} {leaf.shape} {spec}"
