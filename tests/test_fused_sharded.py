"""Fused sparse_sharded vs the Python loop on a REAL multi-shard mesh.

The in-process suite (tests/test_fused.py::TestFusedEngineBackends) only sees
one local device, so its sharded runs use the degenerate 1-shard layout with
no ring steps. Here each test re-executes under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in a subprocess (the
flag must be set before jax imports), so the fused scan body really runs the
S-1 ppermute ring steps / allgather inside ``shard_map`` on 8 shards.

Contract (ISSUE 6): fused is BIT-identical to the loop — both paths stage W
via ``csr_from_graph`` and ring vs allgather are pure data movement — and the
two halo schedules agree with the loop reference at 1e-6 (they are in fact
exact here too).
"""

import subprocess
import sys
import textwrap

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.core import partition as P
from repro.data.loader import NodeLoader
from repro.data.synthetic import make_mnist_like
from repro.train.trainer import DecentralizedTrainer

assert jax.device_count() == 8
N, DIM = 24, 32
ds = make_mnist_like(train_per_class=48, test_per_class=10, dim=DIM, seed=0)
parts = P.iid(ds.y_train, N, seed=1)

def trainer(topology, **kw):
    loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=8, seed=2)
    return DecentralizedTrainer(
        topology, loader, lr=0.05, momentum=0.9, seed=0, in_dim=DIM,
        mix_impl="sparse_sharded", **kw,
    )

def max_err(a, b):
    return max(
        float(np.abs(np.asarray(x) - np.asarray(y)).max())
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )
"""


def _run(body: str) -> None:
    code = textwrap.dedent(_PRELUDE) + textwrap.dedent(body)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=500
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_fused_matches_loop_8_shards_static_ring_vs_allgather():
    """Static ws graph, gossip_every=1: fused == loop bitwise under BOTH halo
    schedules, and the two schedules agree with each other (the ring moves
    O(H*P) per device instead of O(N*P) but lands identical halo buffers)."""
    _run("""
    outs = {}
    for sched in ("ring", "allgather"):
        loop = trainer("ws:n=24,k=4,beta=0.2")
        loop.engine.halo_schedule = sched  # pin past the "auto" resolution
        loop.run(4)
        fused = trainer("ws:n=24,k=4,beta=0.2")
        fused.engine.halo_schedule = sched
        fused.run_fused(4)
        prog = fused.engine.program(4)
        assert prog.shards == 8 and len(prog.sh_ring_send) == 7
        assert prog.halo_schedule == sched
        err = max_err(loop.params, fused.params)
        assert err == 0.0, (sched, err)
        assert max_err(loop.opt_state, fused.opt_state) == 0.0, sched
        outs[sched] = fused.params
    cross = max_err(outs["ring"], outs["allgather"])
    assert cross <= 1e-6, cross
    print("OK")
    """)


def test_fused_matches_loop_8_shards_rewire():
    """@rewire schedule: the fused program stages every period's ShardedCSR
    (stacked, scratch-remapped) up front, the loop rebuilds per period —
    still bit-identical, for gossip_every in {1, 3}."""
    _run("""
    for ge in (1, 3):
        loop = trainer("ba:n=24,m=2@rewire=2", gossip_every=ge)
        loop.run(6)  # 3 periods; ge=3 gossips on rounds 0 and 3
        fused = trainer("ba:n=24,m=2@rewire=2", gossip_every=ge)
        fused.run_fused(6)
        prog = fused.engine.program(6)
        assert prog.num_periods == 3 and prog.sh_values.shape[0] == 3
        assert float(prog.pad_ratio) >= 1.0
        err = max_err(loop.params, fused.params)
        assert err == 0.0, (ge, err)
    print("OK")
    """)


def test_fused_matches_loop_8_shards_faulted():
    """ISSUE 7 acceptance: a faulted sparse_sharded run — churn + stragglers
    + edge drops, with the per-shard straggler ring buffer and halo'd
    renormalized mix inside the scan body — is still a single SPMD
    ``lax.scan`` that matches the Python loop at 1e-6, and matches the
    dense reference at the usual cross-backend tolerance."""
    _run("""
    FAULTS = "churn:p_leave=0.15,p_join=0.5;straggler:frac=0.3,delay=3;drop:p_edge=0.2"
    loop = trainer("ba:n=24,m=2@rewire=3", faults=FAULTS)
    loop.run(7)
    fused = trainer("ba:n=24,m=2@rewire=3", faults=FAULTS)
    fused.run_fused(7)
    prog = fused.engine.program(7)
    assert prog.faulted and prog.shards == 8
    assert prog.f_alive.shape == (7, N) and prog.delay_max == 3
    err = max_err(loop.params, fused.params)
    assert err <= 1e-6, err
    assert max_err(loop.opt_state, fused.opt_state) <= 1e-6

    def dense_trainer():
        loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=8, seed=2)
        return DecentralizedTrainer(
            "ba:n=24,m=2@rewire=3", loader, lr=0.05, momentum=0.9, seed=0,
            in_dim=DIM, mix_impl="dense", faults=FAULTS,
        )
    ref = dense_trainer()
    ref.run_fused(7)
    cross = max_err(ref.params, fused.params)
    assert cross <= 1e-5, cross
    print("OK")
    """)
