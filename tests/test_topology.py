"""Topology generators: structural invariants + cross-checks vs networkx."""

import networkx as nx
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology as T


class TestER:
    def test_edge_probability(self):
        g = T.erdos_renyi(200, 0.1, seed=0)
        possible = 200 * 199 / 2
        # binomial(19900, 0.1): std ~ 42 -> 5 sigma band
        assert abs(g.num_edges - 0.1 * possible) < 5 * np.sqrt(possible * 0.1 * 0.9)

    def test_determinism(self):
        a = T.erdos_renyi(50, 0.2, seed=7)
        b = T.erdos_renyi(50, 0.2, seed=7)
        assert np.array_equal(a.adj, b.adj)
        c = T.erdos_renyi(50, 0.2, seed=8)
        assert not np.array_equal(a.adj, c.adj)

    def test_critical_threshold_connectivity(self):
        """Above p* ER graphs are almost surely connected; well below, not."""
        n = 100
        pstar = T.er_critical_p(n)
        connected_above = sum(
            T.connected_components(T.erdos_renyi(n, 2.5 * pstar, seed=s).adj).max() == 0
            for s in range(10)
        )
        connected_below = sum(
            T.connected_components(T.erdos_renyi(n, 0.2 * pstar, seed=s).adj).max() == 0
            for s in range(10)
        )
        assert connected_above >= 8
        assert connected_below <= 2

    @given(st.integers(10, 80), st.floats(0.0, 1.0), st.integers(0, 10**6))
    @settings(max_examples=20, deadline=None)
    def test_graph_invariants(self, n, p, seed):
        g = T.erdos_renyi(n, p, seed=seed)
        assert g.num_nodes == n
        assert np.array_equal(g.adj, g.adj.T)
        assert not np.any(np.diag(g.adj))


class TestBA:
    def test_edge_count(self):
        # star seed (m edges) + m edges per new node
        n, m = 100, 3
        g = T.barabasi_albert(n, m, seed=0)
        assert g.num_edges == m + m * (n - m - 1)

    def test_min_degree(self):
        g = T.barabasi_albert(100, 4, seed=1)
        assert g.degrees().min() >= 4

    def test_heavy_tail_vs_er(self):
        """BA degree distribution is much more skewed than a same-density ER."""
        gba = T.barabasi_albert(200, 2, seed=0)
        p = 2 * gba.num_edges / (200 * 199)
        ger = T.erdos_renyi(200, p, seed=0)
        assert gba.degrees().max() > 2.5 * ger.degrees().max()

    @given(st.integers(12, 60), st.integers(1, 5), st.integers(0, 10**6))
    @settings(max_examples=15, deadline=None)
    def test_invariants(self, n, m, seed):
        g = T.barabasi_albert(n, m, seed=seed)
        assert np.array_equal(g.adj, g.adj.T)
        assert not np.any(np.diag(g.adj))
        # preferential attachment keeps the graph connected
        assert T.connected_components(g.adj).max() == 0


class TestSBM:
    def test_block_structure(self):
        g = T.stochastic_block_model([25] * 4, 0.8, 0.01, seed=0)
        assert g.num_nodes == 100
        assert g.blocks is not None
        intra = extra = 0
        ii, jj = np.nonzero(np.triu(g.adj, 1))
        for u, v in zip(ii, jj):
            if g.blocks[u] == g.blocks[v]:
                intra += 1
            else:
                extra += 1
        # expected: intra ~ 0.8 * 4 * C(25,2) = 960; extra ~ 0.01 * 3750 = 37.5
        assert intra > 800
        assert extra < 100

    def test_modularity_ordering(self):
        """Tighter communities -> higher modularity (the paper's SBM knob)."""
        g8 = T.stochastic_block_model([25] * 4, 0.8, 0.01, seed=0)
        g5 = T.stochastic_block_model([25] * 4, 0.5, 0.01, seed=0)
        assert T.modularity(g8.adj, g8.blocks) > T.modularity(g5.adj, g5.blocks) > 0.5

    def test_modularity_matches_networkx(self):
        g = T.stochastic_block_model([20] * 3, 0.5, 0.05, seed=3)
        nxg = nx.from_numpy_array(g.adj)
        comms = [set(np.flatnonzero(g.blocks == b)) for b in range(3)]
        expected = nx.algorithms.community.modularity(nxg, comms)
        assert T.modularity(g.adj, g.blocks) == pytest.approx(expected, abs=1e-9)

    def test_external_edge_counts_symmetric(self):
        g = T.stochastic_block_model([25] * 4, 0.5, 0.01, seed=0)
        ext = T.external_edge_counts(g)
        assert np.array_equal(ext, ext.T)
        assert np.all(np.diag(ext) == 0)


def test_connected_components_labels():
    adj = np.zeros((6, 6), dtype=bool)
    adj[0, 1] = adj[1, 0] = True
    adj[2, 3] = adj[3, 2] = True
    labels = T.connected_components(adj)
    assert labels[0] == labels[1]
    assert labels[2] == labels[3]
    assert len(set(labels.tolist())) == 4
