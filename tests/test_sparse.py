"""Sparse gossip path + GossipEngine: CSR round-trips, sparse mixing is
allclose to mix_dense on every paper topology, the engine's dispatch,
cadence and capability checks behave."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decavg as D
from repro.core import mixing as M
from repro.core import sparse as S
from repro.core import topology as T

SPECS = [
    "er:n=40,p=0.2",
    "ba:n=40,m=3",
    "sbm:sizes=10+10+10+10,p_in=0.6,p_out=0.05",
    "ring:n=40",
    "ws:n=40,k=4,beta=0.2",
]


def _params(n, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(key, (n, 13, 2)).astype(dtype),
        "b": {"w": jax.random.normal(jax.random.fold_in(key, 1), (n, 41)).astype(dtype)},
    }


class TestCSR:
    def test_dense_round_trip(self):
        g = T.make("ba:n=30,m=2", seed=0)
        w = M.decavg_matrix(g, np.ones(30))
        csr = S.csr_from_dense(w)
        np.testing.assert_allclose(S.csr_to_dense(csr), w.astype(np.float32))

    def test_nnz_is_o_of_e(self):
        g = T.make("ba:n=200,m=2", seed=0)
        csr = S.csr_from_dense(M.decavg_matrix(g, np.ones(200)))
        assert csr.nnz == 2 * g.num_edges + 200  # neighbors + self loops
        assert csr.nbytes < 200 * 200 * 4 / 4  # far below dense W

    def test_ell_padding(self):
        g = T.make("star:n=10")
        csr = S.csr_from_dense(M.decavg_matrix(g, np.ones(10)))
        idx, val = S.ell_from_csr(csr)
        assert idx.shape == val.shape == (10, csr.max_row_nnz)
        assert csr.max_row_nnz == 10  # hub row: 9 spokes + self
        # padded slots carry zero weight
        assert np.all(val[1] [2:] == 0.0)


class TestCSRFromGraph:
    """Direct edge-list CSR construction — same support and allclose values
    as compressing the dense matrix, without materializing it."""

    @pytest.mark.parametrize("kind", ["decavg", "uniform", "mh"])
    @pytest.mark.parametrize("spec", SPECS)
    def test_matches_dense_reference(self, kind, spec):
        g = T.make(spec, seed=3)
        n = g.num_nodes
        sizes = np.random.default_rng(7).uniform(0.5, 5.0, size=n)
        dense = {
            "decavg": lambda: M.decavg_matrix(g, sizes),
            "uniform": lambda: M.uniform_neighbor_matrix(g),
            "mh": lambda: M.metropolis_hastings_matrix(g),
        }[kind]()
        ref = S.csr_from_dense(dense)
        got = S.csr_from_graph(g, sizes, matrix=kind)
        np.testing.assert_array_equal(np.asarray(got.rows), np.asarray(ref.rows))
        np.testing.assert_array_equal(
            np.asarray(got.indices), np.asarray(ref.indices)
        )
        np.testing.assert_array_equal(
            np.asarray(got.indptr), np.asarray(ref.indptr)
        )
        np.testing.assert_allclose(
            np.asarray(got.values), np.asarray(ref.values), rtol=2e-7, atol=0
        )

    def test_zero_size_sources_dropped(self):
        """Zero-|D_j| neighbors get weight 0 in Eq. 1 — the direct build must
        drop them exactly like csr_from_dense's |w| > 0 support rule."""
        g = T.make("er:n=12,p=0.5", seed=0)
        sizes = np.ones(12)
        sizes[3] = sizes[7] = 0.0
        ref = S.csr_from_dense(M.decavg_matrix(g, sizes))
        got = S.csr_from_graph(g, sizes, matrix="decavg")
        assert got.nnz == ref.nnz
        np.testing.assert_array_equal(np.asarray(got.indices), np.asarray(ref.indices))

    def test_isolated_zero_data_row_keeps_own_model(self):
        """A node whose closed neighborhood has zero total data keeps its own
        model (dense path's bad-row fix)."""
        adj = np.zeros((4, 4), bool)
        adj[0, 1] = adj[1, 0] = True  # node 2, 3 isolated
        g = T.Graph(adj=adj, name="pair")
        sizes = np.array([1.0, 1.0, 0.0, 1.0])
        got = S.csr_from_graph(g, sizes, matrix="decavg")
        np.testing.assert_allclose(
            S.csr_to_dense(got)[2], np.eye(4, dtype=np.float32)[2]
        )

    def test_default_sizes_uniform(self):
        g = T.make("ring:n=8")
        a = S.csr_from_graph(g)
        b = S.csr_from_graph(g, np.ones(8))
        np.testing.assert_array_equal(np.asarray(a.values), np.asarray(b.values))

    def test_rejects_bad_args(self):
        g = T.make("ring:n=8")
        with pytest.raises(ValueError, match="matrix"):
            S.csr_from_graph(g, matrix="nope")
        with pytest.raises(ValueError, match="data_sizes"):
            S.csr_from_graph(g, np.ones(5))


class TestStackedLayouts:
    """Cross-period padding for the fused program: stacked blocked-ELL and
    stacked ShardedCSR reconstruct every period's W exactly."""

    def _csrs(self):
        return [
            S.csr_from_graph(T.make(f"er:n=24,p={p}", seed=s))
            for s, p in enumerate((0.15, 0.5, 0.08))
        ]

    def test_stack_block_ell_reconstructs(self):
        csrs = self._csrs()
        idx, val = S.stack_block_ell(csrs)
        assert idx.shape[0] == val.shape[0] == 3
        assert (idx.shape[2] * 8) % 128 == 0  # lane alignment survives stacking
        assert val.shape[1:] == (idx.shape[1] * 8, idx.shape[2] * 8)
        for t, c in enumerate(csrs):
            rec = np.zeros((24, 24), np.float32)
            for b in range(idx.shape[1]):
                for s in range(idx.shape[2]):
                    sb = idx[t, b, s]
                    rec[b * 8:(b + 1) * 8, sb * 8:(sb + 1) * 8] += (
                        val[t, b * 8:(b + 1) * 8, s * 8:(s + 1) * 8]
                    )
            np.testing.assert_allclose(rec, S.csr_to_dense(c), atol=0)

    def test_stack_shard_csr_reconstructs_with_scratch_remap(self):
        csrs = self._csrs()
        shcsrs = [S.shard_csr(c, 4) for c in csrs]
        st = S.stack_shard_csr(shcsrs)
        h_max = st["halo"].shape[2]
        assert h_max == max(s.halo_width for s in shcsrs)
        blk = 6
        for t, c in enumerate(csrs):
            rec = np.zeros((24, 24), np.float32)
            for s in range(4):
                np.add.at(
                    rec,
                    (st["rows"][t, s] + s * blk,
                     st["halo"][t, s][st["cols"][t, s]]),
                    st["values"][t, s],
                )
            np.testing.assert_allclose(rec, S.csr_to_dense(c), atol=0)
            # scratch slots follow the widened halo: every destination is a
            # real slot < halo_width_t or exactly the stacked scratch h_max
            ld = st["local_dst"][t]
            assert np.all((ld < shcsrs[t].halo_width) | (ld == h_max))
            for d in range(3):
                rr = st["ring_recv"][d][t]
                assert np.all((rr < shcsrs[t].halo_width) | (rr == h_max))
            # per-shard padded entries keep segment ids sorted
            assert np.all(np.diff(st["rows"][t], axis=1) >= 0)

    def test_stack_rejects_mismatched_periods(self):
        a = S.csr_from_graph(T.make("ring:n=8"))
        b = S.csr_from_graph(T.make("ring:n=16"))
        with pytest.raises(ValueError, match="share"):
            S.stack_block_ell([a, b])
        with pytest.raises(ValueError, match="share"):
            S.stack_shard_csr([S.shard_csr(a, 2), S.shard_csr(b, 2)])


class TestSparseEquivalence:
    @pytest.mark.parametrize("spec", SPECS)
    def test_segment_sum_matches_dense(self, spec):
        g = T.make(spec, seed=1)
        n = g.num_nodes
        w = M.decavg_matrix(g, np.arange(1, n + 1, dtype=np.float64))
        csr = S.csr_from_dense(w)
        params = _params(n)
        dense = D.mix_dense(jnp.asarray(w, jnp.float32), params)
        sp = S.mix_sparse(csr, params)
        for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(sp)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5)

    @pytest.mark.parametrize("spec", SPECS[:2] + ["ring:n=40"])
    def test_pallas_ell_kernel_matches_dense(self, spec):
        g = T.make(spec, seed=1)
        n = g.num_nodes
        w = M.decavg_matrix(g, np.ones(n))
        csr = S.csr_from_dense(w)
        params = _params(n)
        dense = D.mix_dense(jnp.asarray(w, jnp.float32), params)
        sp = S.mix_sparse_pallas(csr, params, interpret=True)
        for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(sp)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5)

    @pytest.mark.parametrize("p_chunk", [1, 7, 16, 64, 4096])
    def test_chunked_segment_sum_matches_unchunked(self, p_chunk):
        """Feature-axis chunking (bounded gather transient) is exact, incl.
        non-divisible chunk sizes and chunk > P (single-gather fallback)."""
        g = T.make("ba:n=40,m=3", seed=1)
        w = M.decavg_matrix(g, np.arange(1, 41, dtype=np.float64))
        csr = S.csr_from_dense(w)
        params = _params(40)  # leaf P: 26 and 41 (odd, exercises padding)
        want = S.mix_sparse(csr, params)
        got = S.mix_sparse(csr, params, p_chunk=p_chunk)
        for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)

    def test_auto_p_chunk_bounds_buffer(self):
        assert S.auto_p_chunk(nnz=1 << 14, budget_elems=1 << 22) == 256
        assert S.auto_p_chunk(nnz=1 << 20) == 64  # floor keeps chunks vectorizable
        # engine plumbing: sparse_p_chunk="auto" stays allclose to dense
        e = D.GossipEngine("ba:n=64,m=2", backend="sparse", sparse_p_chunk="auto",
                           seed=0)
        params = _params(64)
        dense = D.mix_dense(e.w, params)
        sp = e.mix(params)
        for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(sp)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5)

    def test_bf16_params(self):
        g = T.make("er:n=24,p=0.3", seed=0)
        w = M.decavg_matrix(g, np.ones(24))
        params = _params(24, dtype=jnp.bfloat16)
        dense = D.mix_dense(jnp.asarray(w, jnp.float32), params)
        sp = S.mix_sparse(S.csr_from_dense(w), params)
        for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(sp)):
            assert b.dtype == jnp.bfloat16
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=2e-2
            )


class TestGossipEngine:
    def test_every_registered_topology_sparse_equals_dense(self):
        """Acceptance: engine.mix(spec='sparse') allclose to mix_dense on
        every registered family (built from its example spec)."""
        for name, fam in T.families().items():
            e = D.GossipEngine(fam.example, seed=2, n=20)
            params = _params(e.num_nodes, seed=3)
            dense = D.mix_dense(e.w, params)
            sp = e.mix(params, spec="sparse")
            for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(sp)):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5,
                    err_msg=f"family {name}",
                )

    def test_auto_backend_scales_with_n(self):
        assert D.GossipEngine("ring:n=16").backend == "dense"
        assert D.GossipEngine("ring:n=16", sparse_threshold=8).backend == "sparse"

    def test_gossip_every_identity_rounds_are_free(self):
        e = D.GossipEngine("ring:n=12", gossip_every=3)
        params = _params(12)
        assert e.mix(params, round=1) is params  # no copy, no matmul
        assert e.mix(params, round=2) is params
        out = e.mix(params, round=3)
        assert out is not params
        # gossip_every=0 disables gossip entirely (legacy falsy semantics)
        e0 = D.GossipEngine("ring:n=12", gossip_every=0)
        assert e0.mix(params, round=0) is params

    def test_capability_checks(self):
        with pytest.raises(ValueError, match="needs a mesh"):
            D.GossipEngine("ring:n=8", backend="sharded")
        with pytest.raises(ValueError, match="needs a mesh"):
            D.GossipEngine("ring:n=8", backend="permute")
        with pytest.raises(ValueError, match="unknown backend"):
            D.GossipEngine("ring:n=8", backend="warp")
        caps = D.GossipEngine.capabilities()
        assert set(caps) == set(D.GossipEngine.BACKENDS)
        assert "O(E" in caps["sparse"]["cost"]
        assert "O(E" in caps["sparse_sharded"]["cost"]

    def test_sparse_sharded_defaults_to_local_device_mesh(self):
        """sparse_sharded without an explicit mesh builds a 1-D mesh over all
        local devices — and still needs N divisible by the shard count."""
        ndev = len(jax.devices())
        n = 8 * ndev
        e = D.GossipEngine(f"ring:n={n}", backend="sparse_sharded")
        assert e.mesh is not None and e.mesh.shape[e.node_axis] == ndev
        params = _params(n)
        dense = D.mix_dense(e.w, params)
        out = e.mix(params)
        for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(out)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-5, atol=3e-5)
        if ndev > 1:  # indivisible N must be an actionable error
            with pytest.raises(ValueError, match="not divisible"):
                D.GossipEngine(f"ring:n={n + 1}", backend="sparse_sharded")

    def test_sparse_sharded_override_does_not_leak_mesh(self):
        """A per-call 'sparse_sharded' override builds its mesh locally — it
        must not mutate the engine, so later calls keep the configured
        capability surface (no mesh => 'sharded' still rejects)."""
        e = D.GossipEngine("ring:n=8", backend="dense")
        params = _params(8)
        out = e.mix(params, backend="sparse_sharded")
        dense = D.mix_dense(e.w, params)
        for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(out)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-5, atol=3e-5)
        assert e.mesh is None
        with pytest.raises(ValueError, match="needs a mesh"):
            e.mix(params, backend="sharded")

    def test_permute_time_varying_recolors_per_period(self):
        """The permute backend now supports TopologySchedules by recomputing
        the edge coloring at each schedule period — exactly once per period,
        cached and reused within it (and across revisits). The numeric
        round-boundary equality runs with real devices in
        tests/test_backend_equivalence.py."""

        class FakeMesh:  # capability checks only read mesh.shape
            shape = {"data": 8}

        calls: list[int] = []
        orig = M.edge_coloring
        M.edge_coloring = lambda g: (calls.append(1), orig(g))[1]
        try:
            e = D.GossipEngine("ring:n=8@rewire=2", backend="permute",
                               mesh=FakeMesh(), seed=3)
            assert len(calls) == 1  # construction colors period 0
            assert not e.refresh(1)  # same period: cached coloring, no rebuild
            assert len(calls) == 1
            assert e.refresh(2)  # period 1: recolor once
            assert len(calls) == 2
            assert not e.refresh(3)
            assert len(calls) == 2
            assert e.refresh(4)  # period 2
            assert len(calls) == 3
            # regen schedules construct too (previously a ValueError)
            D.GossipEngine("ring:n=8@regen=2", backend="permute", mesh=FakeMesh())
        finally:
            M.edge_coloring = orig

    def test_permute_still_requires_matching_mesh_axis(self):
        class FakeMesh:
            shape = {"data": 8}

        with pytest.raises(ValueError, match="num_nodes"):
            D.GossipEngine("ring:n=12", backend="permute", mesh=FakeMesh())

    def test_matrix_kinds(self):
        e = D.GossipEngine("er:n=20,p=0.4", matrix="mh", seed=0)
        w = np.asarray(e.w)
        np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-6)  # doubly stochastic
        np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-6)
        with pytest.raises(ValueError, match="matrix must be one of"):
            D.GossipEngine("ring:n=8", matrix="bogus")

    def test_time_varying_schedule_rebuilds_w(self):
        e = D.GossipEngine("er:n=24,p=0.3@regen=2", seed=0)
        w0 = np.asarray(e.w_at(0))
        assert not e.refresh(1)  # same period: no rebuild
        assert e.refresh(2)
        w2 = np.asarray(e.w_at(2))
        assert not np.allclose(w0, w2)
        # sparse state follows the period
        params = _params(24)
        sp = e.mix(params, round=2, spec="sparse")
        dense = D.mix_dense(e.w, params)
        for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(sp)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5)

    def test_mix_without_round_keeps_current_period(self):
        """Regression: engine.mix() with no round must not refresh(0)-reset
        a time-varying engine (the trainer's jitted closure relies on it)."""
        e = D.GossipEngine("er:n=24,p=0.3@regen=2", backend="sparse", seed=0)
        e.refresh(4)
        w4 = np.asarray(e.w)
        params = _params(24)
        out = e.mix(params)  # no round: current period, no cadence
        assert e._period == 2
        want = D.mix_dense(jnp.asarray(w4), params)
        for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(out)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5)

    def test_consensus_contraction_via_sparse(self):
        """The spectral-gap mechanism survives the sparse path."""
        e = D.GossipEngine("ws:n=30,k=4,beta=0.2", backend="sparse", seed=1)
        params = _params(30, seed=5)
        errs = [float(D.gossip_error(params))]
        for r in range(5):
            params = e.mix(params, round=r)
            errs.append(float(D.gossip_error(params)))
        assert errs[-1] < 0.5 * errs[0]


def test_trainer_accepts_spec_and_sparse_backend():
    """DecentralizedTrainer end-to-end through the registry + sparse path."""
    from repro.core import partition as P
    from repro.data.loader import NodeLoader
    from repro.data.synthetic import make_mnist_like
    from repro.train.trainer import DecentralizedTrainer

    ds = make_mnist_like(train_per_class=60, test_per_class=20, seed=0)
    parts = P.iid(ds.y_train, 12, seed=1)
    loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=32, seed=2)
    tr = DecentralizedTrainer("ba:m=2", loader, lr=0.05, mix_impl="sparse", seed=0)
    assert tr.num_nodes == 12  # n defaulted from the loader
    hist = tr.run(2, x_test=ds.x_test, y_test=ds.y_test)
    assert np.isfinite(hist[-1].mean_acc)
