"""Property tests for core/sparse.py, core/mixing.py, and core/faults.py.

Runs under hypothesis when installed; the conftest stub makes each
``@given`` test an explicit skip otherwise (the registry-sweep checks at the
bottom are plain pytest and always run).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import faults as F  # noqa: E402
from repro.core import mixing as M  # noqa: E402
from repro.core import sparse as S  # noqa: E402
from repro.core import topology as T  # noqa: E402


def _random_w(n: int, p: float, seed: int) -> tuple[np.ndarray, T.Graph]:
    g = T.erdos_renyi(n, p, seed=seed)
    sizes = np.random.default_rng(seed).uniform(0.5, 5.0, size=n)
    return M.decavg_matrix(g, sizes), g


# ---------------------------------------------------------------------------
# core/sparse.py layout invariants
# ---------------------------------------------------------------------------


@given(st.integers(2, 40), st.floats(0.05, 0.9), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_csr_dense_round_trip(n, p, seed):
    w, _ = _random_w(n, p, seed)
    csr = S.csr_from_dense(w)
    np.testing.assert_allclose(S.csr_to_dense(csr), w.astype(np.float32))
    # structural invariants: sorted rows, indptr consistent with nnz
    rows = np.asarray(csr.rows)
    assert np.all(np.diff(rows) >= 0)
    ptr = np.asarray(csr.indptr)
    assert ptr[0] == 0 and ptr[-1] == csr.nnz
    assert np.all(np.diff(ptr) >= 0)


@given(st.integers(2, 40), st.floats(0.05, 0.9), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_ell_from_csr_padding_invariants(n, p, seed):
    w, _ = _random_w(n, p, seed)
    csr = S.csr_from_dense(w)
    idx, val = S.ell_from_csr(csr)
    k = max(csr.max_row_nnz, 1)
    assert idx.shape == val.shape == (n, k)
    # padded slots carry zero weight; scatter-reconstruction is exact
    ptr = np.asarray(csr.indptr)
    counts = ptr[1:] - ptr[:-1]
    for i in range(n):
        assert np.all(val[i, counts[i]:] == 0.0)
    rec = np.zeros((n, n), np.float32)
    np.add.at(rec, (np.repeat(np.arange(n), k), idx.ravel()), val.ravel())
    np.testing.assert_allclose(rec, w.astype(np.float32), atol=1e-7)


@given(
    st.integers(2, 40),
    st.floats(0.05, 0.9),
    st.integers(0, 10**6),
    st.sampled_from(["decavg", "uniform", "mh"]),
)
@settings(max_examples=25, deadline=None)
def test_csr_from_graph_matches_dense_route(n, p, seed, kind):
    """The edge-list staging path (what program() uses to avoid O(T*N^2)
    host memory) carries the same support and values as going through the
    dense matrix — for every matrix kind and ragged data sizes."""
    g = T.erdos_renyi(n, p, seed=seed)
    sizes = np.random.default_rng(seed).uniform(0.5, 5.0, size=n)
    dense = {
        "decavg": lambda: M.decavg_matrix(g, sizes),
        "uniform": lambda: M.uniform_neighbor_matrix(g),
        "mh": lambda: M.metropolis_hastings_matrix(g),
    }[kind]()
    ref = S.csr_from_dense(dense)
    got = S.csr_from_graph(g, sizes if kind == "decavg" else None, matrix=kind)
    np.testing.assert_array_equal(np.asarray(got.indptr), np.asarray(ref.indptr))
    np.testing.assert_array_equal(np.asarray(got.indices), np.asarray(ref.indices))
    np.testing.assert_allclose(
        np.asarray(got.values), np.asarray(ref.values), atol=1e-6
    )


@given(st.integers(2, 40), st.floats(0.05, 0.9), st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_shard_csr_round_trip(n, p, seed):
    w, _ = _random_w(n, p, seed)
    csr = S.csr_from_dense(w)
    for shards in (s for s in (1, 2, 4) if n % s == 0):
        sh = S.shard_csr(csr, shards)
        blk = sh.rows_per_shard
        rec = np.zeros((n, n), np.float32)
        for s in range(shards):
            halo = np.asarray(sh.halo[s])
            rows = np.asarray(sh.rows[s])
            np.add.at(
                rec,
                (rows + s * blk, halo[np.asarray(sh.cols[s])]),
                np.asarray(sh.values[s]),
            )
            assert np.all(np.diff(rows) >= 0), "padded rows must keep sort order"
        np.testing.assert_allclose(rec, w.astype(np.float32), atol=1e-7)


@given(st.integers(2, 40), st.floats(0.05, 0.9), st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_ring_peer_slot_round_trip(n, p, seed):
    """The ring halo exchange's peer/slot metadata reassembles exactly the
    halo buffer the segment-sum consumes: simulating the schedule in numpy
    (local copies + S-1 pairwise sends) and summing reproduces W @ P."""
    w, _ = _random_w(n, p, seed)
    csr = S.csr_from_dense(w)
    x = np.random.default_rng(seed).standard_normal((n, 3)).astype(np.float32)
    for shards in (s for s in (1, 2, 4) if n % s == 0):
        sh = S.shard_csr(csr, shards)
        blk, h = sh.rows_per_shard, sh.halo_width
        blocks = x.reshape(shards, blk, -1)
        out = np.zeros_like(x)
        for s in range(shards):
            buf = np.zeros((h + 1, x.shape[1]), np.float32)  # scratch at H
            buf[np.asarray(sh.local_dst[s])] = blocks[s][np.asarray(sh.local_src[s])]
            written = set(np.asarray(sh.local_dst[s]).tolist())
            for d, (send, recv) in enumerate(zip(sh.ring_send, sh.ring_recv), 1):
                o = (s - d) % shards
                send_o = np.asarray(send[o])
                recv_s = np.asarray(recv[s])
                # sender-side indices stay inside the sender's block; slots
                # stay inside the halo buffer (+ scratch)
                assert np.all((send_o >= 0) & (send_o < blk)), (shards, d)
                assert np.all((recv_s >= 0) & (recv_s <= h)), (shards, d)
                buf[recv_s] = blocks[o][send_o]
                written.update(recv_s.tolist())
            # every slot the shard's entries reference was actually delivered
            cols = np.asarray(sh.cols[s])
            vals = np.asarray(sh.values[s])
            assert set(cols[vals != 0].tolist()) <= written, shards
            contrib = buf[cols] * vals[:, None]
            np.add.at(out[s * blk:(s + 1) * blk], np.asarray(sh.rows[s]), contrib)
        np.testing.assert_allclose(
            out, w.astype(np.float32) @ x, rtol=1e-5, atol=1e-5
        )


@given(st.integers(1, 1 << 24), st.integers(1 << 10, 1 << 24))
@settings(max_examples=50, deadline=None)
def test_auto_p_chunk_bounds(nnz, budget):
    chunk = S.auto_p_chunk(nnz, budget_elems=budget)
    assert chunk >= 64  # floor keeps chunks vectorizable
    assert chunk == max(64, budget // nnz)
    if chunk > 64:  # above the floor the transient respects the budget
        assert chunk * nnz <= budget


# ---------------------------------------------------------------------------
# core/mixing.py matrix invariants
# ---------------------------------------------------------------------------


@given(st.integers(2, 40), st.floats(0.05, 0.9), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_mixing_matrices_row_stochastic(n, p, seed):
    w, g = _random_w(n, p, seed)
    for kind, mat in (
        ("decavg", w),
        ("uniform", M.uniform_neighbor_matrix(g)),
        ("mh", M.metropolis_hastings_matrix(g)),
    ):
        assert np.all(mat >= -1e-12), kind
        np.testing.assert_allclose(mat.sum(axis=1), 1.0, atol=1e-9, err_msg=kind)
        M.validate_mixing(mat, g)


@given(st.integers(2, 40), st.floats(0.05, 0.9), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_mh_symmetric_doubly_stochastic(n, p, seed):
    g = T.erdos_renyi(n, p, seed=seed)
    w = M.metropolis_hastings_matrix(g)
    np.testing.assert_allclose(w, w.T, atol=1e-12)
    np.testing.assert_allclose(w.sum(axis=0), 1.0, atol=1e-9)
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-9)


# ---------------------------------------------------------------------------
# core/faults.py renormalized-mixing invariants
# ---------------------------------------------------------------------------


def _masks(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Arbitrary symmetric entry-keep + aliveness masks (worst case: allowed
    to sever self-loops and whole neighborhoods, unlike real FaultTraces)."""
    rng = np.random.default_rng(seed)
    keep = rng.random((n, n)) < rng.uniform(0.1, 1.0)
    keep = keep & keep.T
    alive = rng.random(n) < rng.uniform(0.3, 1.0)
    return keep, alive


@given(st.integers(2, 24), st.floats(0.05, 0.9), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_faulted_w_row_stochastic_under_arbitrary_masks(n, p, seed):
    """Whatever entries a round loses, the effective mixing matrix stays a
    valid averaging operator: nonnegative, rows sum to 1, masked entries
    zero, and rows with no surviving mass fall back to identity."""
    w, _ = _random_w(n, p, seed)
    keep, alive = _masks(n, seed + 1)
    eff = F.faulted_dense_w(w, keep, alive)
    assert np.all(eff >= -1e-12)
    np.testing.assert_allclose(eff.sum(axis=1), 1.0, atol=1e-6)
    dead_or_empty = ~alive | ~(np.asarray(w * keep).sum(axis=1) > 0)
    np.testing.assert_array_equal(
        eff[dead_or_empty], np.eye(n, dtype=eff.dtype)[dead_or_empty]
    )
    live = ~dead_or_empty
    assert np.all(eff[live][~keep[live]] == 0.0)


@given(st.integers(2, 24), st.floats(0.05, 0.9), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_faulted_mix_preserves_fixed_points_on_alive(n, p, seed):
    """Consensus fixed point: if every node already holds the same params,
    a faulted round changes nothing (renormalized rows still average)."""
    w, _ = _random_w(n, p, seed)
    keep, alive = _masks(n, seed + 2)
    const = jnp.full((n, 3), 1.25, jnp.float32)
    out = F.mix_faulted_dense(
        jnp.asarray(w, jnp.float32), jnp.asarray(keep), jnp.asarray(alive),
        const, const,
    )
    np.testing.assert_allclose(np.asarray(out), 1.25, atol=1e-6)


@given(st.integers(2, 24), st.floats(0.05, 0.9), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_faulted_mix_dead_nodes_bit_unchanged(n, p, seed):
    """Dead nodes' params pass through *bit*-identical — no epsilon — on
    both the fresh-publish and stale-publish code paths."""
    w, _ = _random_w(n, p, seed)
    keep, alive = _masks(n, seed + 3)
    rng = np.random.default_rng(seed)
    params = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    pub = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    wj, kj, aj = jnp.asarray(w, jnp.float32), jnp.asarray(keep), jnp.asarray(alive)
    for out in (
        F.mix_faulted_dense(wj, kj, aj, params),
        F.mix_faulted_dense(wj, kj, aj, params, pub),
    ):
        np.testing.assert_array_equal(
            np.asarray(out)[~alive], np.asarray(params)[~alive]
        )


@given(st.integers(2, 24), st.floats(0.05, 0.9), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_faulted_csr_matches_dense_under_arbitrary_masks(n, p, seed):
    """The CSR faulted mix agrees with the dense reference on its support
    for any mask pair — the loop/fused sparse paths both ride on it."""
    w, _ = _random_w(n, p, seed)
    w = w.astype(np.float32)
    keep, alive = _masks(n, seed + 4)
    csr = S.csr_from_dense(w)
    rows, cols = np.asarray(csr.rows), np.asarray(csr.indices)
    rng = np.random.default_rng(seed)
    params = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    pub = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    a = F.mix_faulted_dense(
        jnp.asarray(w), jnp.asarray(keep), jnp.asarray(alive), params, pub
    )
    b = F.mix_faulted_csr(
        csr.rows, csr.indices, csr.values, jnp.asarray(keep[rows, cols]),
        jnp.asarray(alive), n, params, pub,
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_validate_mixing_accepts_every_registry_family():
    """Every registered topology family yields valid mixing matrices for
    every matrix kind (the registry x matrix compatibility sweep)."""
    for name, fam in T.families().items():
        g = T.make(fam.example, seed=0, n=20)
        sizes = np.random.default_rng(0).uniform(0.5, 5.0, size=g.num_nodes)
        for kind, mat in (
            ("decavg", M.decavg_matrix(g, sizes)),
            ("uniform", M.uniform_neighbor_matrix(g)),
            ("mh", M.metropolis_hastings_matrix(g)),
        ):
            M.validate_mixing(mat, g)


def test_spectral_gap_orders_connectivity():
    """Sanity anchor for the analysis join: complete > ring in gap."""
    wc = M.uniform_neighbor_matrix(T.complete(16))
    wr = M.uniform_neighbor_matrix(T.ring(16))
    assert M.spectral_gap(wc) > M.spectral_gap(wr)
