"""Topology registry: every family builds from its example spec, specs
round-trip through make(), schedules vary (or don't) on cue."""

import numpy as np
import pytest

from repro.core import topology as T


class TestMake:
    def test_every_family_example_builds_valid_graph(self):
        for name, fam in T.families().items():
            g = T.make(fam.example, seed=3, n=20)
            # Graph.__post_init__ enforces symmetry/zero-diagonal; spot-check
            # basic structure on top.
            assert g.num_nodes >= 2, name
            assert g.num_edges >= 1, name
            assert np.array_equal(g.adj, g.adj.T), name

    def test_every_spec_round_trips(self):
        """g.name is the canonical spec: make(g.name) reproduces g exactly,
        regardless of the fallback seed."""
        for name, fam in T.families().items():
            g = T.make(fam.example, seed=3, n=20)
            g2 = T.make(g.name, seed=99, n=20)
            assert np.array_equal(g.adj, g2.adj), name
            assert g2.name == g.name, name

    def test_registry_matches_legacy_generators(self):
        a = T.make("er:n=50,p=0.2,seed=7")
        b = T.erdos_renyi(50, 0.2, seed=7)
        assert np.array_equal(a.adj, b.adj)
        a = T.make("ba:n=50,m=3,seed=7")
        b = T.barabasi_albert(50, 3, seed=7)
        assert np.array_equal(a.adj, b.adj)
        a = T.make("sbm:sizes=10+10+10,p_in=0.6,p_out=0.05,seed=7")
        b = T.stochastic_block_model([10, 10, 10], 0.6, 0.05, seed=7)
        assert np.array_equal(a.adj, b.adj)
        assert np.array_equal(a.blocks, b.blocks)

    def test_caller_defaults_fill_missing_params(self):
        g = T.make("ring", n=6)
        assert g.num_nodes == 6
        # spec params win over caller defaults
        g = T.make("ring:n=8", n=6)
        assert g.num_nodes == 8

    def test_aliases(self):
        assert np.array_equal(
            T.make("full:n=5").adj, T.make("complete:n=5").adj
        )

    def test_errors(self):
        with pytest.raises(ValueError, match="unknown topology family"):
            T.make("nope:n=4")
        with pytest.raises(ValueError, match="unknown params"):
            T.make("ring:n=4,bogus=1")
        with pytest.raises(ValueError, match="needs params"):
            T.make("ring")
        with pytest.raises(ValueError, match="schedule suffix"):
            T.make("er:n=4@regen=2")
        with pytest.raises(ValueError, match="malformed param"):
            T.make("ring:n")


class TestStructure:
    def test_ring(self):
        g = T.make("ring:n=10")
        assert np.all(g.degrees() == 2)
        assert T.connected_components(g.adj).max() == 0

    def test_star(self):
        g = T.make("star:n=10")
        d = g.degrees()
        assert d[0] == 9 and np.all(d[1:] == 1)

    def test_complete(self):
        g = T.make("complete:n=10")
        assert np.all(g.degrees() == 9)

    def test_k_regular(self):
        g = T.make("kreg:n=12,k=4")
        assert np.all(g.degrees() == 4)
        # odd k needs even n
        assert np.all(T.make("kreg:n=12,k=5").degrees() == 5)
        with pytest.raises(ValueError):
            T.make("kreg:n=11,k=5")

    def test_torus_and_grid(self):
        t = T.make("torus:rows=4,cols=5")
        assert np.all(t.degrees() == 4)
        gr = T.make("grid:rows=4,cols=5")
        assert gr.num_edges == 4 * 4 + 3 * 5  # rows*(cols-1) + (rows-1)*cols
        # n-only form factors to a near square
        assert T.make("grid:n=20").num_nodes == 20

    def test_watts_strogatz_keeps_edge_count(self):
        base = T.make("kreg:n=40,k=4")
        ws = T.make("ws:n=40,k=4,beta=0.3,seed=1")
        assert ws.num_edges == base.num_edges
        assert not np.array_equal(ws.adj, base.adj)  # something rewired
        # beta=0 is exactly the lattice
        assert np.array_equal(T.make("ws:n=40,k=4,beta=0.0").adj, base.adj)

    def test_caveman(self):
        g = T.make("caveman:cliques=4,size=5")
        assert g.num_nodes == 20
        assert g.blocks is not None
        assert T.connected_components(g.adj).max() == 0  # bridged, not islands
        # high modularity by construction (the SBM axis's deterministic extreme)
        assert T.modularity(g.adj, g.blocks) > 0.5
        # bridging rewires each 2-clique's only edge -> rejected, not silent
        with pytest.raises(ValueError, match="size >= 3"):
            T.make("caveman:cliques=3,size=2")
        # single clique needs no bridge: size=2 is a plain edge
        assert T.make("caveman:cliques=1,size=2").num_edges == 1


class TestSchedule:
    def test_static_is_constant(self):
        s = T.make_schedule("ring:n=8")
        assert not s.is_time_varying
        assert np.array_equal(s.graph_at(0).adj, s.graph_at(100).adj)

    def test_static_wraps_existing_graph(self):
        g = T.make("ba:n=12,m=2", seed=0)
        s = T.TopologySchedule.static(g)
        assert s.graph_at(37) is g

    def test_regen_changes_per_period_deterministically(self):
        s = T.make_schedule("er:n=30,p=0.2@regen=5", seed=0)
        assert s.is_time_varying
        assert np.array_equal(s.graph_at(0).adj, s.graph_at(4).adj)
        assert not np.array_equal(s.graph_at(0).adj, s.graph_at(5).adj)
        s2 = T.make_schedule("er:n=30,p=0.2@regen=5", seed=0)
        assert np.array_equal(s.graph_at(7).adj, s2.graph_at(7).adj)

    def test_rewire_preserves_nodes(self):
        s = T.make_schedule("ba:n=30,m=2@rewire=2,frac=0.2", seed=0)
        g0, g1 = s.graph_at(0), s.graph_at(2)
        assert g0.num_nodes == g1.num_nodes == 30
        assert not np.array_equal(g0.adj, g1.adj)

    def test_bad_schedules(self):
        with pytest.raises(ValueError, match="regen= or rewire="):
            T.make_schedule("ring:n=8@warp=2")
        with pytest.raises(ValueError, match="unknown schedule params"):
            T.make_schedule("ring:n=8@regen=2,zz=1")
