"""Cross-backend differential suite: every GossipEngine mixing backend must
compute the same DecAvg round as the dense reference, on every topology
family shape class, for ragged pytrees — and preserve consensus fixed points.

This is the lockdown for the sparse/scale paths: one parametrized matrix
over backends x topologies x pytree shapes, plus subprocess runs with 8 fake
CPU devices for the genuinely multi-device backends (sparse_sharded with
real cross-shard halos, permute, both dense sharded schedules) and the
permute x TopologySchedule recolor-per-period regression.
"""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decavg as D
from repro.core import mixing as M
from repro.core import sparse as S
from repro.core import topology as T

N = 24
TOPOLOGIES = [
    f"ring:n={N}",
    f"star:n={N}",
    f"ws:n={N},k=4,beta=0.2",
    "caveman:cliques=4,size=6",
    "torus:rows=4,cols=6",
]
# Backends runnable in-process on any jax backend (sparse_sharded builds its
# default 1-device mesh; the >1-shard halo path runs in the subprocess test).
# The sparse_sharded+ring entry pins the degenerate local-copy-only ring.
BACKENDS = ["dense", "pallas", "sparse", "sparse_pallas", "sparse_sharded",
            "sparse_sharded+ring"]

PYTREES = {
    "ragged": lambda n, key: {
        "a": jax.random.normal(key, (n, 13, 2)),
        "b": {"w": jax.random.normal(jax.random.fold_in(key, 1), (n, 41))},
    },
    "odd": lambda n, key: {
        "x": jax.random.normal(key, (n, 1)),
        "y": jax.random.normal(jax.random.fold_in(key, 2), (n, 129)),
        "z": jax.random.normal(jax.random.fold_in(key, 3), (n, 5, 3, 2)),
    },
}


def _engine(spec: str, backend: str) -> D.GossipEngine:
    n = T.make(spec, seed=2).num_nodes
    backend, _, halo = backend.partition("+")
    return D.GossipEngine(
        spec, backend=backend, seed=2,
        halo_schedule=halo or "auto",
        data_sizes=np.arange(1, n + 1, dtype=np.float64),
    )


@pytest.mark.parametrize("pytree", sorted(PYTREES))
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("spec", TOPOLOGIES)
def test_backend_matches_dense_reference(spec, backend, pytree):
    e = _engine(spec, backend)
    params = PYTREES[pytree](e.num_nodes, jax.random.PRNGKey(7))
    want = D.mix_dense(e.w, params)
    got = e.mix(params)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5,
            err_msg=f"{backend} vs dense on {spec} ({pytree})",
        )


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("spec", TOPOLOGIES)
def test_fixed_point_preserved(spec, backend):
    """Consensus state (all nodes identical) is invariant under one round of
    any backend — W is row-stochastic, so W @ (1 x c^T) == 1 x c^T."""
    e = _engine(spec, backend)
    n = e.num_nodes
    params = {
        "a": jnp.broadcast_to(jnp.arange(13.0 * 2).reshape(13, 2), (n, 13, 2)),
        "b": {"w": jnp.broadcast_to(jnp.linspace(-3.0, 5.0, 41), (n, 41))},
    }
    out = e.mix(params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5,
            err_msg=f"{backend} broke the consensus fixed point on {spec}",
        )


@pytest.mark.parametrize("spec", TOPOLOGIES)
def test_blocked_ell_kernel_matches_mix_sparse(spec):
    """Acceptance: the 8-row-blocked ELL kernel matches the segment-sum
    sparse path to 1e-6 (forced through the interpreter off-TPU)."""
    g = T.make(spec, seed=2)
    n = g.num_nodes
    w = M.decavg_matrix(g, np.arange(1, n + 1, dtype=np.float64))
    csr = S.csr_from_dense(w)
    params = PYTREES["ragged"](n, jax.random.PRNGKey(9))
    want = S.mix_sparse(csr, params)
    got = S.mix_sparse_pallas(csr, params, blocked=True, interpret=True)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6,
            err_msg=f"blocked ELL vs mix_sparse on {spec}",
        )


def test_block_ell_layout_invariants():
    """Blocked layout reconstructs W exactly; padding is lane-aligned."""
    g = T.make("ba:n=30,m=3", seed=0)
    w = M.decavg_matrix(g, np.ones(30))
    csr = S.csr_from_dense(w)
    bell = S.block_ell_from_csr(csr)
    assert bell.n == 30 and bell.num_blocks == 4  # ceil(30 / 8)
    assert bell.max_blocks_per_row % 16 == 0  # lane padding
    assert bell.val.shape == (bell.num_blocks * 8, bell.max_blocks_per_row * 8)
    rec = np.zeros((bell.num_blocks * 8, bell.num_blocks * 8), np.float32)
    for b in range(bell.num_blocks):
        for t in range(bell.max_blocks_per_row):
            sb = int(bell.idx[b, t])
            rec[b * 8:(b + 1) * 8, sb * 8:(sb + 1) * 8] += bell.val[
                b * 8:(b + 1) * 8, t * 8:(t + 1) * 8
            ]
    np.testing.assert_allclose(rec[:30, :30], w.astype(np.float32), atol=1e-7)
    assert np.all(rec[30:] == 0.0) and np.all(rec[:, 30:] == 0.0)


def test_shard_csr_layout_invariants():
    """Sharded CSR reconstructs W; halos cover exactly the referenced
    sources; padded entries are weightless and keep segments sorted."""
    g = T.make("ws:n=24,k=4,beta=0.3", seed=5)
    w = M.decavg_matrix(g, np.ones(24))
    csr = S.csr_from_dense(w)
    sh = S.shard_csr(csr, 4)
    assert sh.shards == 4 and sh.rows_per_shard == 6
    rec = np.zeros((24, 24), np.float32)
    for s in range(4):
        halo = np.asarray(sh.halo[s])
        rows = np.asarray(sh.rows[s])
        cols = np.asarray(sh.cols[s])
        vals = np.asarray(sh.values[s])
        assert np.all(np.diff(rows) >= 0), "segment ids must stay sorted"
        assert np.all((rows >= 0) & (rows < 6))
        np.add.at(rec, (rows + s * 6, halo[cols]), vals)
    np.testing.assert_allclose(rec, w.astype(np.float32), atol=1e-7)
    with pytest.raises(ValueError, match="not divisible"):
        S.shard_csr(csr, 5)


def test_sparse_sharded_subprocess_multi_shard():
    """The real halo path: 8 node shards over 8 fake CPU devices, every
    topology in the matrix, both halo schedules (ring ppermute vs allgather,
    allclose to dense at 1e-6 — the acceptance bar), plus both dense sharded
    schedules as a cross-check of the shard_map shim. Halos genuinely span
    several shards here (24 nodes / 8 shards = 3 rows per shard, degree >= 2).
    """
    code = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import decavg as D, mixing as M, sparse as S, topology as T
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("nodes",))
        for spec in {TOPOLOGIES!r}:
            g = T.make(spec, seed=2)
            n = g.num_nodes
            w = M.decavg_matrix(g, np.arange(1, n + 1, dtype=np.float64))
            wj = jnp.asarray(w, jnp.float32)
            csr = S.csr_from_dense(w)
            shcsr = S.shard_csr(csr, 8)
            params = {{"a": jax.random.normal(jax.random.PRNGKey(0), (n, 9, 3)),
                       "b": jax.random.normal(jax.random.PRNGKey(1), (n, 41))}}
            dense = D.mix_dense(wj, params)
            sched_outs = {{
                sched: D.mix_sharded_sparse(shcsr, params, mesh=mesh,
                                            node_axis="nodes",
                                            halo_schedule=sched)
                for sched in ("allgather", "ring", "auto")
            }}
            for sched, out in sched_outs.items():
                for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(out)):
                    np.testing.assert_allclose(
                        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6,
                        err_msg=f"{{spec}} halo_schedule={{sched}}")
            # ring wire never exceeds the allgather's on a sparse graph
            wire = S.halo_wire_bytes(shcsr, 41)
            assert wire["ring"] <= wire["allgather"], (spec, wire)
            for sched in ("allgather", "reduce_scatter"):
                out = D.mix_sharded(wj, params, mesh=mesh,
                                    node_axis="nodes", schedule=sched)
                for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(out)):
                    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                               rtol=2e-5, atol=2e-5, err_msg=spec)
        # ring + p_chunk: the feature-chunked segment-sum consumes the same
        # ring-assembled halo buffer
        g = T.make("ws:n=24,k=4,beta=0.2", seed=2)
        w = M.decavg_matrix(g, np.ones(24))
        shcsr = S.shard_csr(S.csr_from_dense(w), 8)
        params = {{"a": jax.random.normal(jax.random.PRNGKey(3), (24, 131))}}
        dense = D.mix_dense(jnp.asarray(w, jnp.float32), params)
        out = D.mix_sharded_sparse(shcsr, params, mesh=mesh, node_axis="nodes",
                                   p_chunk=32, halo_schedule="ring")
        np.testing.assert_allclose(np.asarray(dense["a"]), np.asarray(out["a"]),
                                   rtol=1e-6, atol=1e-6)
        print("OK")
        """
    )
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=500
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_sparse_sharded_ring_time_varying_subprocess():
    """GossipEngine(sparse_sharded, halo_schedule=ring) tracks a @rewire
    schedule: the per-period ShardedCSR (peer metadata included) is rebuilt
    at period boundaries and every round still matches dense mixing."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import decavg as D, topology as T
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("nodes",))
        e = D.GossipEngine("ws:n=24,k=4,beta=0.3@rewire=2", backend="sparse_sharded",
                           halo_schedule="ring", mesh=mesh, node_axis="nodes", seed=4)
        params = {"a": jax.random.normal(jax.random.PRNGKey(5), (24, 7, 2))}
        seen = set()
        for r in range(6):
            out = e.mix(params, round=r)
            want = D.mix_dense(e.w, params)  # refreshed for round r by mix()
            np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(want["a"]),
                                       rtol=1e-6, atol=1e-6, err_msg=f"round {r}")
            seen.add(bytes(np.asarray(e.w).tobytes()))
        assert len(seen) == 3, len(seen)  # rewire=2 over 6 rounds -> 3 periods
        print("OK")
        """
    )
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=500
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_permute_schedule_recolor_subprocess():
    """Regression: permute + @rewire schedule equals dense mixing at every
    round boundary, and colorings are computed once per period (counter)."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import decavg as D, mixing as M, topology as T
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("nodes",))
        calls = []
        orig = M.edge_coloring
        M.edge_coloring = lambda g: (calls.append(1), orig(g))[1]
        e = D.GossipEngine("er:n=8,p=0.5@rewire=2", backend="permute",
                           mesh=mesh, node_axis="nodes", seed=3)
        params = {"a": jax.random.normal(jax.random.PRNGKey(2), (8, 7, 2))}
        for r in range(6):
            out = e.mix(params, round=r)
            want = D.mix_dense(e.w, params)  # W refreshed for round r by mix()
            np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(want["a"]),
                                       rtol=2e-5, atol=2e-5, err_msg=f"round {r}")
        # periods 0, 1, 2 -> exactly 3 colorings; re-mixing inside a period
        # must reuse the cached one.
        assert len(calls) == 3, calls
        e.mix(params, round=5)
        assert len(calls) == 3, calls
        # a static permute engine on the same mesh still works (n == |axis|)
        e2 = D.GossipEngine("ring:n=8", backend="permute", mesh=mesh,
                            node_axis="nodes", seed=0)
        out2 = e2.mix(params, round=0)
        want2 = D.mix_dense(e2.w, params)
        np.testing.assert_allclose(np.asarray(out2["a"]), np.asarray(want2["a"]),
                                   rtol=2e-5, atol=2e-5)
        print("OK")
        """
    )
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=500
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
