"""LLM cohorts through the fused scan (ISSUE 8).

The contract under test: ``LMCohortTrainer.run_fused`` matches the
per-round loop at 1e-6 (params + losses) across gossip cadences, static
and ``@rewire`` schedules, faults and CHOCO compression — plus the
satellites riding along: the PR 7 bit-exact dead-node freeze the old lm
runner violated, full ``(params, opt, step)`` checkpoints with
bit-identical resume, the truncated-zipf token distribution, the
``compress="auto"`` threshold, and lm run_id hash-compat pins.
"""

import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.train import trainer as trainer_mod
from repro.train.trainer import LMCohortTrainer

N_NODES = 4


@pytest.fixture(scope="module")
def cfg():
    base = cfgbase.get("llama32_1b")
    return dataclasses.replace(
        base.reduced(),
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256,
    )


def make_trainer(cfg, topology="ring:n=4", **kw):
    kw.setdefault("seed", 0)
    return LMCohortTrainer(
        topology, cfg, nodes=N_NODES, batch=2, seq=16, lr=1e-3, **kw
    )


def assert_trees_close(a, b, **kw):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestFusedEquivalence:
    """run_fused == run at 1e-6 on a reduced transformer cohort."""

    @pytest.mark.parametrize("gossip_every", [1, 3])
    def test_static_ring(self, cfg, gossip_every):
        t1 = make_trainer(cfg, gossip_every=gossip_every)
        h1 = t1.run(7, eval_every=3)
        t2 = make_trainer(cfg, gossip_every=gossip_every)
        h2 = t2.run_fused(7, eval_every=3)
        assert_trees_close(t1.params, t2.params, atol=1e-6)
        assert [r["round"] for r in h1] == [r["round"] for r in h2]
        for a, b in zip(h1, h2):
            assert a["loss"] == pytest.approx(b["loss"], abs=1e-6)
            assert a["lr"] == pytest.approx(b["lr"], abs=1e-9)

    def test_rewire_schedule(self, cfg):
        topo = "er:n=4,p=0.6@rewire=2"
        t1 = make_trainer(cfg, topology=topo, seed=1)
        t1.run(6, eval_every=3)
        t2 = make_trainer(cfg, topology=topo, seed=1)
        t2.run_fused(6, eval_every=3)
        assert_trees_close(t1.params, t2.params, atol=1e-6)

    @pytest.mark.parametrize("gossip_every,rounds", [(1, 6), (3, 7)])
    def test_compress_equivalence(self, cfg, gossip_every, rounds):
        # Short horizons on purpose: CHOCO's top-k mask is discontinuous, so
        # a float-rounding difference between the scan and the loop can flip
        # a selected coordinate and amplify chaotically once enough rounds
        # accumulate. At these round counts both paths pick identical masks
        # and agree to f32 rounding.
        t1 = make_trainer(cfg, compress=0.25, gossip_every=gossip_every)
        t1.run(rounds, eval_every=3)
        t2 = make_trainer(cfg, compress=0.25, gossip_every=gossip_every)
        t2.run_fused(rounds, eval_every=3)
        assert_trees_close(t1.params, t2.params, atol=1e-6)

    def test_faults_equivalence(self, cfg):
        spec = "churn:p_leave=0.4,p_join=0.3"
        t1 = make_trainer(cfg, faults=spec)
        h1 = t1.run(6, eval_every=3)
        t2 = make_trainer(cfg, faults=spec)
        h2 = t2.run_fused(6, eval_every=3)
        assert_trees_close(t1.params, t2.params, atol=1e-6)
        assert h1[-1]["alive_count"] == h2[-1]["alive_count"]

    def test_straggler_equivalence(self, cfg):
        spec = "churn:p_leave=0.3,p_join=0.3;straggler:frac=0.3,delay=2"
        t1 = make_trainer(cfg, faults=spec)
        t1.run(6, eval_every=3)
        t2 = make_trainer(cfg, faults=spec)
        t2.run_fused(6, eval_every=3)
        assert_trees_close(t1.params, t2.params, atol=1e-6)

    def test_unsupported_backend_raises(self, cfg):
        # "pallas" is a real single-host backend the MixingProgram lm scan
        # does not stage; the runner must fall back to the loop.
        t = make_trainer(cfg, backend="pallas")
        assert not t.supports_fused
        with pytest.raises(ValueError, match="run_fused supports"):
            t.run_fused(2)


class TestFaultFreeze:
    """ISSUE 8 satellite: dead lm nodes stay bit-frozen — params AND
    optimizer moments — across churn rounds, in both run paths."""

    # Targeted kill of the top-degree half, no rejoin: nodes 0-1 die at
    # round 0 and stay dead; nodes 2-3 stay alive for the whole run.
    FAULTS = "churn:p_leave=1.0,p_join=0.0,frac=0.5@targeted=hubs"

    def _dead_nodes(self, t, rounds):
        trace = t.engine.fault_trace
        trace.ensure(rounds)
        alive = np.stack([np.asarray(trace.alive(r)) for r in range(rounds)])
        dead = np.flatnonzero(~alive.any(axis=0))
        assert dead.size, "fault spec killed nobody; fixture broken"
        return dead

    @pytest.mark.parametrize("path", ["run", "run_fused"])
    def test_dead_nodes_bit_frozen(self, cfg, path):
        t = make_trainer(cfg, faults=self.FAULTS)
        dead = self._dead_nodes(t, 4)
        before = jax.tree.map(lambda x: np.asarray(x).copy(), t.params)
        opt_before = jax.tree.map(lambda x: np.asarray(x).copy(), t.opt_state)
        getattr(t, path)(4, eval_every=4)
        for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(t.params)):
            for d in dead:
                np.testing.assert_array_equal(np.asarray(a)[d], np.asarray(b)[d])
        # Moments frozen too (node-stacked leaves only: AdamW's shared step
        # count is global and advances).
        n = t.num_nodes
        for a, b in zip(jax.tree.leaves(opt_before), jax.tree.leaves(t.opt_state)):
            a, b = np.asarray(a), np.asarray(b)
            if a.ndim == 0 or a.shape[0] != n:
                continue
            for d in dead:
                np.testing.assert_array_equal(a[d], b[d])

    def test_alive_nodes_train(self, cfg):
        t = make_trainer(cfg, faults=self.FAULTS)
        trace = t.engine.fault_trace
        trace.ensure(4)
        alive = np.stack([np.asarray(trace.alive(r)) for r in range(4)])
        live = np.flatnonzero(alive.all(axis=0))
        assert live.size
        before = jax.tree.map(lambda x: np.asarray(x).copy(), t.params)
        t.run(4, eval_every=4)
        changed = any(
            not np.array_equal(np.asarray(a)[l], np.asarray(b)[l])
            for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(t.params))
            for l in live
        )
        assert changed

    def test_where_alive_stacked_passes_scalars(self):
        from repro.core import faults as F

        alive = jnp.array([True, False])
        new = {"mu": jnp.ones((2, 3)), "count": jnp.asarray(7)}
        old = {"mu": jnp.zeros((2, 3)), "count": jnp.asarray(3)}
        out = F.where_alive_stacked(alive, new, old)
        np.testing.assert_array_equal(np.asarray(out["mu"][0]), 1.0)
        np.testing.assert_array_equal(np.asarray(out["mu"][1]), 0.0)
        assert int(out["count"]) == 7  # shared scalar passes through


class TestCheckpointResume:
    """ISSUE 8 satellite: (params, opt, step) checkpoints; resume continues
    bit-identically; the final round is always checkpointed."""

    def test_ckpt_rounds_include_final(self):
        assert LMCohortTrainer._ckpt_rounds(10, 0) == set()
        assert LMCohortTrainer._ckpt_rounds(10, 3) == {3, 6, 9}
        # rounds % ckpt_every != 0: final round still saved (the pre-PR-8
        # runner dropped it).
        assert LMCohortTrainer._ckpt_rounds(10, 4) == {4, 8, 9}

    def test_checkpoint_carries_opt_and_step(self, cfg, tmp_path):
        path = str(tmp_path / "lm.ckpt")
        t = make_trainer(cfg)
        t.run(4, eval_every=4, ckpt_every=3, ckpt_path=path)
        t2 = make_trainer(cfg)
        start = t2.restore(path)
        assert start == 4  # final round 3 saved
        assert_trees_equal(t.params, t2.params)
        assert_trees_equal(t.opt_state, t2.opt_state)

    @pytest.mark.parametrize("fused", [False, True])
    def test_resume_past_end_still_reports_final(self, cfg, tmp_path, fused):
        # Restoring the FINAL checkpoint leaves no rounds to train; the run
        # must still emit one eval record at the restored state (the CLI's
        # summary print reads loss/wall_s from it) and not touch params.
        path = str(tmp_path / "lm.ckpt")
        t = make_trainer(cfg)
        t.run(4, eval_every=4, ckpt_every=2, ckpt_path=path)
        t2 = make_trainer(cfg)
        assert t2.restore(path) == 4
        run = t2.run_fused if fused else t2.run
        history = run(4, eval_every=4)
        assert len(history) == 1
        assert history[0]["round"] == 3
        assert np.isfinite(history[0]["loss"])
        assert "g2_token_spread" in history[0]
        assert_trees_equal(t.params, t2.params)

    def test_loop_resume_bit_identical(self, cfg, tmp_path):
        path = str(tmp_path / "lm.ckpt")
        grab = str(tmp_path / "lm_mid.ckpt")
        ref = make_trainer(cfg)
        ref.run(8, eval_every=4)

        t1 = make_trainer(cfg)

        def snatch(rec):
            if rec["round"] == 4:  # ckpt at step 3 already on disk
                shutil.copy(path + ".npz", grab + ".npz")

        t1.run(8, eval_every=1, on_round=snatch, ckpt_every=3, ckpt_path=path)
        t2 = make_trainer(cfg)
        assert t2.restore(grab) == 4
        t2.run(8, eval_every=4)
        assert_trees_equal(ref.params, t2.params)
        assert_trees_equal(ref.opt_state, t2.opt_state)

    def test_fused_resume_bit_identical(self, cfg, tmp_path):
        path = str(tmp_path / "lm.ckpt")
        grab = str(tmp_path / "lm_mid.ckpt")
        ref = make_trainer(cfg)
        ref.run_fused(8, eval_every=4)

        t1 = make_trainer(cfg)

        def snatch(rec):
            if rec["round"] == 6:  # ckpt at step 4 already on disk
                shutil.copy(path + ".npz", grab + ".npz")

        t1.run_fused(8, eval_every=2, on_round=snatch, ckpt_every=4,
                     ckpt_path=path)
        t2 = make_trainer(cfg)
        assert t2.restore(grab) == 5
        t2.run_fused(8, eval_every=4)
        assert_trees_equal(ref.params, t2.params)

    def test_straggler_resume_raises(self, cfg, tmp_path):
        path = str(tmp_path / "lm.ckpt")
        t = make_trainer(cfg, faults="straggler:frac=0.5,delay=2")
        t.save(path, step=0)
        t2 = make_trainer(cfg, faults="straggler:frac=0.5,delay=2")
        with pytest.raises(ValueError, match="straggler"):
            t2.restore(path)

    def test_runner_resume_path(self, cfg, tmp_path):
        """model={'resume': True} restores through the experiment runner."""
        from repro.experiments.runner import run_spec
        from repro.experiments.spec import ExperimentSpec
        from repro.experiments.store import ResultsStore

        path = str(tmp_path / "run.ckpt")
        model = {
            "kind": "lm", "nodes": 4, "batch": 2, "seq": 16,
            "ckpt_every": 3, "ckpt_path": path,
        }
        base = dict(topology="ring:n=4", rounds=4, eval_every=4, lr=1e-3)
        store = ResultsStore(str(tmp_path / "a.jsonl"))
        r1 = run_spec(ExperimentSpec(**base, model=model), store)
        assert r1["status"] == "completed"
        # Resume from the final-round ckpt: nothing left to run, finishes
        # with the same params-derived consensus.
        store2 = ResultsStore(str(tmp_path / "b.jsonl"))
        r2 = run_spec(
            ExperimentSpec(**base, model={**model, "resume": True}), store2
        )
        assert r2["status"] == "completed"
        assert r2["final"]["consensus_mean"] == pytest.approx(
            r1["final"]["consensus_mean"], abs=1e-7
        )


class TestCompressDefault:
    """compress='auto' thresholds on member pytree bytes."""

    def test_small_member_stays_raw(self, cfg):
        t = make_trainer(cfg)
        assert t.member_bytes < trainer_mod._COMPRESS_AUTO_BYTES
        assert t.compress is None
        assert t.cstate is None

    def test_large_member_compresses(self, cfg, monkeypatch):
        monkeypatch.setattr(trainer_mod, "_COMPRESS_AUTO_BYTES", 1024)
        t = make_trainer(cfg)
        assert t.compress == trainer_mod._COMPRESS_AUTO_K
        assert t.cstate is not None

    def test_auto_resolves_off_under_faults(self, cfg, monkeypatch):
        monkeypatch.setattr(trainer_mod, "_COMPRESS_AUTO_BYTES", 1024)
        t = make_trainer(cfg, faults="churn:p_leave=0.2,p_join=0.5")
        assert t.compress is None

    def test_explicit_compress_with_faults_raises(self, cfg):
        with pytest.raises(ValueError, match="faults do not compose"):
            make_trainer(cfg, compress=0.1, faults="churn:p_leave=0.2,p_join=0.5")

    def test_bad_fraction_raises(self, cfg):
        with pytest.raises(ValueError, match="top-k fraction"):
            make_trainer(cfg, compress=1.5)


class TestTokenDistribution:
    """ISSUE 8 satellite: truncated zipf without modulo aliasing."""

    def test_range_and_head_heavy(self):
        from repro.data import tokens as tok

        toks, labels = tok.round_token_batch(2, 0, 8, 255, 128, seed=0)
        assert toks.min() >= 0 and toks.max() < 128
        assert labels.min() >= 0 and labels.max() < 128
        # Head-heavy background: with domain_frac=0 the first token must be
        # the most frequent — a `% vocab` fold flattens this.
        stream = tok.node_token_stream(0, 50_000, 128, seed=0, domain_frac=0.0)
        counts = np.bincount(stream, minlength=128)
        assert counts[0] == counts.max()
        assert counts[0] > 2 * counts[64:].max()

    def test_round_keyed_determinism(self):
        from repro.data import tokens as tok

        a = tok.round_token_batch(3, 5, 4, 16, 64, seed=7)
        b = tok.round_token_batch(3, 5, 4, 16, 64, seed=7)
        np.testing.assert_array_equal(a[0], b[0])
        c = tok.round_token_batch(3, 6, 4, 16, 64, seed=7)
        assert not np.array_equal(a[0], c[0])

    def test_slab_matches_per_round(self):
        from repro.data import tokens as tok

        slab_t, slab_l = tok.round_token_slab(2, range(3, 6), 2, 8, 64, seed=1)
        for i, r in enumerate(range(3, 6)):
            t, l = tok.round_token_batch(2, r, 2, 8, 64, seed=1)
            np.testing.assert_array_equal(slab_t[i], t)
            np.testing.assert_array_equal(slab_l[i], l)


class TestDomainEval:
    """g2_token_spread metric: deterministic, foreign-domain only."""

    def test_eval_batch_deterministic_and_foreign(self):
        from repro.data import tokens as tok

        t1, l1 = tok.domain_eval_batch(4, 2, 16, 64, seed=3)
        t2, _ = tok.domain_eval_batch(4, 2, 16, 64, seed=3)
        np.testing.assert_array_equal(t1, t2)
        domains = [tok.node_domain(i, 64, seed=3) for i in range(4)]
        for i in range(4):
            foreign = np.concatenate([d for j, d in enumerate(domains) if j != i])
            assert np.isin(t1[i], foreign).all()

    def test_single_node_raises(self):
        from repro.data import tokens as tok

        with pytest.raises(ValueError, match=">= 2 nodes"):
            tok.domain_eval_batch(1, 2, 8, 64)

    def test_metric_deterministic(self, cfg):
        t = make_trainer(cfg)
        m1 = t.domain_metrics()
        m2 = t.domain_metrics()
        assert m1["g2_token_spread"] == m2["g2_token_spread"]
        assert m1["domain_acc"] == m2["domain_acc"]
        assert len(m1["domain_acc"]) == N_NODES

    def test_metric_streams_through_records(self, cfg):
        t = make_trainer(cfg)
        h = t.run(2, eval_every=1)
        assert all("g2_token_spread" in r and "domain_acc" in r for r in h)


class TestRunIdCompat:
    """New model keys must not shift pre-PR-8 lm run ids."""

    def _cli_spec(self, **model_extra):
        from repro.experiments.spec import ExperimentSpec

        model = {
            "kind": "lm", "arch": "llama3.2-1b", "nodes": 4, "batch": 4,
            "seq": 128, "schedule": "cosine", "full_scale": False,
            "ckpt_every": 0, "ckpt_path": "results/train_ckpt.npz",
            **model_extra,
        }
        return ExperimentSpec(
            topology="ring", rounds=100, eval_every=20, lr=3e-4,
            model=model, tag="launch.train",
        )

    def test_cli_default_pin(self):
        # Pinned before PR 8's model-dict growth; launch/train.py defaults.
        assert self._cli_spec().run_id == "ring-iid-s0-37889d7a"

    def test_bare_lm_pin(self):
        from repro.experiments.spec import ExperimentSpec

        s = ExperimentSpec(topology="ring:n=4", model={"kind": "lm"})
        assert s.run_id == "ring-iid-s0-af2615d7"

    def test_default_model_keys_do_not_shift_hash(self):
        base = self._cli_spec()
        withdefaults = self._cli_spec(compress="auto", fused=True, resume=True)
        assert withdefaults.run_id == base.run_id

    def test_nondefault_model_keys_do_shift_hash(self):
        base = self._cli_spec()
        assert self._cli_spec(compress=0.25).run_id != base.run_id
        assert self._cli_spec(fused=False).run_id != base.run_id

    def test_build_spec_defaults_match_pin(self):
        import argparse

        from repro.launch.train import build_spec

        ns = argparse.Namespace(
            arch="llama3.2-1b", steps=100, nodes=4, topology="ring",
            mix_backend="auto", batch=4, seq=128, lr=3e-4, schedule="cosine",
            gossip_every=1, compress="auto", fused=True, faults=None,
            ckpt_every=0, ckpt_path="results/train_ckpt.npz",
            full_scale=False, resume=False, seed=0,
        )
        assert build_spec(ns).run_id == "ring-iid-s0-37889d7a"
