"""Non-IID partitioners (paper §5.1): focus-node selection with the paper's
tie-break, class allocation invariants, community splits."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import partition as P
from repro.core import topology as T


def _labels(per_class=60, num_classes=10):
    return np.repeat(np.arange(num_classes), per_class)


class TestFocusSelection:
    def test_exactly_ten_percent(self):
        g = T.barabasi_albert(100, 2, seed=0)
        hubs = P.select_extreme_degree_nodes(g, 0.10, highest=True, seed=0)
        leaves = P.select_extreme_degree_nodes(g, 0.10, highest=False, seed=0)
        assert len(hubs) == 10 and len(leaves) == 10
        deg = g.degrees()
        # every selected hub has degree >= every non-selected node's... at the
        # boundary ties are broken randomly, so compare against the threshold.
        assert deg[hubs].min() >= np.sort(deg)[::-1][9]
        assert deg[leaves].max() <= np.sort(deg)[9]

    @given(st.integers(20, 100), st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def test_disjoint_quota(self, n, seed):
        g = T.erdos_renyi(n, 0.2, seed=seed)
        k = max(1, round(0.1 * n))
        sel = P.select_extreme_degree_nodes(g, 0.1, highest=True, seed=seed)
        assert len(sel) == k
        assert len(set(sel.tolist())) == k

    def test_boundary_degree_tie_break(self):
        """Paper rule at the boundary: whole degree classes are taken while
        they fit; the class that would overshoot is sampled, so every
        boundary pick has exactly the boundary degree."""
        # star: node 0 has degree n-1, the 19 leaves all have degree 1 —
        # quota 4 forces sampling 3 of the tied leaves.
        g = T.star(20)
        sel = P.select_extreme_degree_nodes(g, 0.2, highest=True, seed=0)
        assert len(sel) == 4
        assert 0 in sel  # the whole top degree class (the hub) is taken
        deg = g.degrees()
        assert np.all(deg[[v for v in sel if v != 0]] == 1)  # boundary picks
        # lowest side: the hub can never be picked while leaves remain
        lo = P.select_extreme_degree_nodes(g, 0.2, highest=False, seed=0)
        assert 0 not in lo and np.all(deg[lo] == 1)

    def test_boundary_tie_break_is_uniform_over_seeds(self):
        """Different seeds sample different boundary subsets; the
        non-boundary prefix is deterministic."""
        g = T.star(20)
        picks = [
            frozenset(P.select_extreme_degree_nodes(g, 0.2, highest=True, seed=s).tolist())
            for s in range(12)
        ]
        assert all(0 in p for p in picks)  # hub always in (full class)
        assert len(set(picks)) > 1  # boundary subset varies with seed
        # same seed -> same subset (reproducible)
        again = frozenset(
            P.select_extreme_degree_nodes(g, 0.2, highest=True, seed=3).tolist()
        )
        assert again in picks

    def test_exact_boundary_no_overshoot(self):
        """When the boundary class fits exactly, no sampling happens and the
        selection is the full degree prefix regardless of seed."""
        # kreg is degree-regular: any quota is filled entirely by sampling
        # within one class; with frac=1.0 every node must be selected.
        g = T.k_regular(10, 4)
        sel = P.select_extreme_degree_nodes(g, 1.0, highest=True, seed=5)
        assert sel.tolist() == list(range(10))


class TestFocusedPartitions:
    def test_hub_focused_allocation(self):
        g = T.barabasi_albert(100, 2, seed=0)
        labels = _labels(per_class=300)
        parts = P.hub_focused(labels, g, seed=1)
        summ = P.partition_summary(labels, parts)
        # G1 classes (0-4) on every node; G2 (5-9) only on the 10 hubs
        assert np.all(summ[:, :5].sum(axis=1) > 0)
        holders = np.flatnonzero(summ[:, 5:].sum(axis=1) > 0)
        assert len(holders) == 10
        deg = g.degrees()
        assert deg[holders].min() >= np.sort(deg)[::-1][9]

    def test_edge_focused_allocation(self):
        g = T.barabasi_albert(100, 2, seed=0)
        labels = _labels(per_class=300)
        parts = P.edge_focused(labels, g, seed=1)
        summ = P.partition_summary(labels, parts)
        holders = np.flatnonzero(summ[:, 5:].sum(axis=1) > 0)
        assert len(holders) == 10
        deg = g.degrees()
        assert deg[holders].max() <= np.sort(deg)[9]

    def test_equal_shares_per_class(self):
        """Paper: 'on the assigned classes, each node gets the same amount'."""
        g = T.erdos_renyi(50, 0.2, seed=2)
        labels = _labels(per_class=100)
        parts = P.hub_focused(labels, g, seed=3)
        summ = P.partition_summary(labels, parts)
        for c in range(5):
            counts = summ[:, c]
            assert counts.min() == counts.max() == 100 // 50

    def test_no_index_overlap(self):
        g = T.erdos_renyi(30, 0.2, seed=0)
        labels = _labels()
        parts = P.edge_focused(labels, g, seed=0)
        allidx = np.concatenate(parts)
        assert len(allidx) == len(set(allidx.tolist()))


class TestCommunityPartition:
    def test_exclusive_classes(self):
        g = T.stochastic_block_model([25] * 4, 0.5, 0.01, seed=0)
        labels = _labels(per_class=100)
        parts = P.community(labels, g, seed=1)
        summ = P.partition_summary(labels, parts)
        for comm in range(4):
            members = np.flatnonzero(g.blocks == comm)
            own = summ[members][:, 2 * comm : 2 * comm + 2]
            other = np.delete(summ[members], [2 * comm, 2 * comm + 1], axis=1)
            assert np.all(own > 0)
            assert np.all(other == 0)
        # classes 8, 9 discarded entirely
        assert summ[:, 8:].sum() == 0


class TestDirichlet:
    @given(st.floats(0.05, 10.0), st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_partition_complete(self, beta, seed):
        labels = _labels(per_class=30)
        parts = P.dirichlet(labels, 8, beta=beta, seed=seed)
        allidx = np.concatenate([p for p in parts if len(p)])
        assert len(allidx) == len(labels)
        assert len(set(allidx.tolist())) == len(labels)

    def test_per_class_share_conservation(self):
        """Every class's examples are fully dealt across nodes — per class,
        shares sum to the class size with no loss and no duplication."""
        labels = _labels(per_class=47, num_classes=10)  # odd size: cut rounding
        for beta in (0.1, 1.0, 5.0):
            parts = P.dirichlet(labels, 6, beta=beta, seed=9)
            summ = P.partition_summary(labels, parts)
            np.testing.assert_array_equal(summ.sum(axis=0), 47)
            allidx = np.concatenate([p for p in parts if len(p)])
            assert len(allidx) == len(set(allidx.tolist()))

    def test_skew_increases_as_beta_shrinks(self):
        """Dir(beta) label skew: small beta concentrates each class on few
        nodes, large beta approaches a uniform split."""
        labels = _labels(per_class=200)

        def max_share(beta):
            parts = P.dirichlet(labels, 8, beta=beta, seed=0)
            summ = P.partition_summary(labels, parts).astype(np.float64)
            return float((summ / summ.sum(axis=0, keepdims=True)).max(axis=0).mean())

        assert max_share(0.05) > max_share(10.0) + 0.2
