"""Non-IID partitioners (paper §5.1): focus-node selection with the paper's
tie-break, class allocation invariants, community splits."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import partition as P
from repro.core import topology as T


def _labels(per_class=60, num_classes=10):
    return np.repeat(np.arange(num_classes), per_class)


class TestFocusSelection:
    def test_exactly_ten_percent(self):
        g = T.barabasi_albert(100, 2, seed=0)
        hubs = P.select_extreme_degree_nodes(g, 0.10, highest=True, seed=0)
        leaves = P.select_extreme_degree_nodes(g, 0.10, highest=False, seed=0)
        assert len(hubs) == 10 and len(leaves) == 10
        deg = g.degrees()
        # every selected hub has degree >= every non-selected node's... at the
        # boundary ties are broken randomly, so compare against the threshold.
        assert deg[hubs].min() >= np.sort(deg)[::-1][9]
        assert deg[leaves].max() <= np.sort(deg)[9]

    @given(st.integers(20, 100), st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def test_disjoint_quota(self, n, seed):
        g = T.erdos_renyi(n, 0.2, seed=seed)
        k = max(1, round(0.1 * n))
        sel = P.select_extreme_degree_nodes(g, 0.1, highest=True, seed=seed)
        assert len(sel) == k
        assert len(set(sel.tolist())) == k


class TestFocusedPartitions:
    def test_hub_focused_allocation(self):
        g = T.barabasi_albert(100, 2, seed=0)
        labels = _labels(per_class=300)
        parts = P.hub_focused(labels, g, seed=1)
        summ = P.partition_summary(labels, parts)
        # G1 classes (0-4) on every node; G2 (5-9) only on the 10 hubs
        assert np.all(summ[:, :5].sum(axis=1) > 0)
        holders = np.flatnonzero(summ[:, 5:].sum(axis=1) > 0)
        assert len(holders) == 10
        deg = g.degrees()
        assert deg[holders].min() >= np.sort(deg)[::-1][9]

    def test_edge_focused_allocation(self):
        g = T.barabasi_albert(100, 2, seed=0)
        labels = _labels(per_class=300)
        parts = P.edge_focused(labels, g, seed=1)
        summ = P.partition_summary(labels, parts)
        holders = np.flatnonzero(summ[:, 5:].sum(axis=1) > 0)
        assert len(holders) == 10
        deg = g.degrees()
        assert deg[holders].max() <= np.sort(deg)[9]

    def test_equal_shares_per_class(self):
        """Paper: 'on the assigned classes, each node gets the same amount'."""
        g = T.erdos_renyi(50, 0.2, seed=2)
        labels = _labels(per_class=100)
        parts = P.hub_focused(labels, g, seed=3)
        summ = P.partition_summary(labels, parts)
        for c in range(5):
            counts = summ[:, c]
            assert counts.min() == counts.max() == 100 // 50

    def test_no_index_overlap(self):
        g = T.erdos_renyi(30, 0.2, seed=0)
        labels = _labels()
        parts = P.edge_focused(labels, g, seed=0)
        allidx = np.concatenate(parts)
        assert len(allidx) == len(set(allidx.tolist()))


class TestCommunityPartition:
    def test_exclusive_classes(self):
        g = T.stochastic_block_model([25] * 4, 0.5, 0.01, seed=0)
        labels = _labels(per_class=100)
        parts = P.community(labels, g, seed=1)
        summ = P.partition_summary(labels, parts)
        for comm in range(4):
            members = np.flatnonzero(g.blocks == comm)
            own = summ[members][:, 2 * comm : 2 * comm + 2]
            other = np.delete(summ[members], [2 * comm, 2 * comm + 1], axis=1)
            assert np.all(own > 0)
            assert np.all(other == 0)
        # classes 8, 9 discarded entirely
        assert summ[:, 8:].sum() == 0


class TestDirichlet:
    @given(st.floats(0.05, 10.0), st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_partition_complete(self, beta, seed):
        labels = _labels(per_class=30)
        parts = P.dirichlet(labels, 8, beta=beta, seed=seed)
        allidx = np.concatenate([p for p in parts if len(p)])
        assert len(allidx) == len(labels)
        assert len(set(allidx.tolist())) == len(labels)
