"""Fault-injection subsystem (core/faults.py): parser, trace, mixing, trainer.

Covers the ISSUE 7 acceptance surface end to end:

- spec grammar parse/validation errors and clause defaults;
- :class:`FaultTrace` determinism (same seed => byte-identical masks),
  targeted pools, deterministic kills gated on ``start``, static straggler
  delays, and ``drop`` edge semantics;
- renormalized-mixing semantics on real ``decavg_matrix`` W and its CSR
  twin — row-stochasticity under masks and the empty-neighborhood identity
  fallback (the bugfix satellite);
- dead nodes bit-unchanged and stragglers publishing genuinely stale
  snapshots through the ring buffer;
- engine/trainer gating (unsupported backends, faults+compress,
  gossip_first) and the capability matrix's ``faults`` column;
- the tentpole contract: trainer loop == fused under a combined
  churn+straggler+drop schedule at 1e-6, including ``@rewire`` and
  ``gossip_every=2`` (sharded twin lives in tests/test_fused_sharded.py's
  subprocess harness);
- run-id backward compatibility: a spec without ``faults`` hashes to its
  pre-subsystem run_id (pinned literal).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decavg
from repro.core import faults as F
from repro.core import mixing as M
from repro.core import partition as P
from repro.core import sparse as S
from repro.core import topology as T
from repro.data.loader import NodeLoader
from repro.train.trainer import DecentralizedTrainer

N = 16
DIM = 32
COMBINED = "churn:p_leave=0.15,p_join=0.5;straggler:frac=0.3,delay=3;drop:p_edge=0.2"


def sched(spec="ba:n=16,m=2", seed=0):
    return T.make_schedule(spec, seed=seed)


# ---------------------------------------------------------------------------
# Grammar
# ---------------------------------------------------------------------------


class TestParse:
    def test_defaults_and_overrides(self):
        (c,) = F.parse_faults("churn")
        assert c.kind == "churn" and c.target == "uniform"
        assert c.params["p_leave"] == pytest.approx(0.1)
        (c,) = F.parse_faults("churn:p_leave=0.4,start=8@targeted=hubs")
        assert c.params["p_leave"] == pytest.approx(0.4)
        assert c.params["start"] == 8 and c.target == "hubs"

    def test_multi_clause(self):
        clauses = F.parse_faults(COMBINED)
        assert [c.kind for c in clauses] == ["churn", "straggler", "drop"]
        sch = F.FaultSchedule.parse(COMBINED)
        assert sch.has_churn and sch.has_stragglers and sch.has_drop
        assert sch.max_delay == 3

    def test_parse_idempotent_on_schedule(self):
        sch = F.FaultSchedule.parse("drop:p_edge=0.3")
        assert F.FaultSchedule.parse(sch) is sch

    @pytest.mark.parametrize(
        "bad",
        [
            "",
            " ; ",
            "meteor:p=0.1",
            "churn:p_leave=1.5",
            "churn:bogus=1",
            "churn@targeted=mediums",
            "churn@flavor=hubs",
            "straggler:delay=0",
            "drop:p_edge=0.1@targeted=hubs",
        ],
    )
    def test_rejects(self, bad):
        with pytest.raises(ValueError):
            F.parse_faults(bad)


# ---------------------------------------------------------------------------
# FaultTrace
# ---------------------------------------------------------------------------


class TestTrace:
    def test_deterministic_and_incremental(self):
        a = F.FaultTrace(COMBINED, sched(), seed=7)
        b = F.FaultTrace(COMBINED, sched(), seed=7)
        a.ensure(12)  # bulk...
        for r in range(12):  # ...vs incremental must agree byte-for-byte
            np.testing.assert_array_equal(a.alive(r), b.alive(r))
            np.testing.assert_array_equal(a.dense_keep(r), b.dense_keep(r))
        c = F.FaultTrace(COMBINED, sched(), seed=8)
        c.ensure(12)
        assert any(
            not np.array_equal(a.alive(r), c.alive(r)) for r in range(12)
        )

    def test_targeted_kill_start_gated(self):
        spec = "churn:p_leave=1.0,p_join=0.0,frac=0.25,start=5@targeted=hubs"
        tr = F.FaultTrace(spec, sched(), seed=0)
        g = sched().graph_at(0)
        deg = g.degrees()
        k = int(np.ceil(0.25 * N))
        hubs = np.lexsort((np.arange(N), -deg))[:k]
        for r in range(5):
            assert tr.alive(r).all(), "no one dies before start"
        post = tr.alive(5)
        assert not post[hubs].any(), "every hub dies at start"
        assert post.sum() == N - k, "only hubs die"
        for r in range(6, 10):  # p_join=0 => they stay dead
            np.testing.assert_array_equal(tr.alive(r), post)

    def test_leaves_target_complements_hubs(self):
        kill = "churn:p_leave=1.0,p_join=0.0,frac=0.25,start=0@targeted={}"
        dead_h = ~F.FaultTrace(kill.format("hubs"), sched(), seed=0).alive(0)
        dead_l = ~F.FaultTrace(kill.format("leaves"), sched(), seed=0).alive(0)
        deg = sched().graph_at(0).degrees()
        assert deg[dead_h].min() >= deg[~dead_h].max()
        assert deg[dead_l].max() <= deg[~dead_l].min()

    def test_straggler_delays_static_and_bounded(self):
        tr = F.FaultTrace("straggler:frac=0.25,delay=4", sched(), seed=0)
        k = int(np.ceil(0.25 * N))
        assert tr.delay_max == 4
        assert (tr.delay == 4).sum() == k and set(np.unique(tr.delay)) <= {0, 4}

    def test_drop_everything_keeps_diagonal_only(self):
        tr = F.FaultTrace("drop:p_edge=1.0", sched(), seed=0)
        keep = tr.dense_keep(0)
        adj = np.asarray(sched().graph_at(0).adj, bool)
        assert np.diag(keep).all()
        assert not keep[adj & ~np.eye(N, dtype=bool)].any()
        assert tr.alive(0).all(), "drop never kills nodes"

    def test_drop_symmetric_and_seeded(self):
        tr = F.FaultTrace("drop:p_edge=0.5", sched(), seed=3)
        keep = tr.dense_keep(2)
        np.testing.assert_array_equal(keep, keep.T)
        assert tr.edge_kept(2, 0, 0) is True
        i, j = np.nonzero(np.asarray(sched().graph_at(0).adj, bool))
        kept = [tr.edge_kept(2, a, b) for a, b in zip(i, j)]
        assert any(kept) and not all(kept)

    def test_entry_keep_matches_dense_and_spares_padding(self):
        tr = F.FaultTrace(COMBINED, sched(), seed=1)
        w = M.decavg_matrix(sched().graph_at(0), np.ones(N))
        csr = S.csr_from_dense(w)
        rows, cols = np.asarray(csr.rows), np.asarray(csr.indices)
        keep = tr.entry_keep(3, rows, cols)
        np.testing.assert_array_equal(keep, tr.dense_keep(3)[rows, cols])
        # zero-valued (padding) slots are forced kept => inert under renorm
        vals = np.asarray(csr.values).copy()
        vals[0] = 0.0
        assert tr.entry_keep(3, rows, cols, vals)[0]


# ---------------------------------------------------------------------------
# Renormalized mixing on a real DecAvg matrix (bugfix satellite)
# ---------------------------------------------------------------------------


class TestRenorm:
    def _w(self, sizes=None):
        g = sched().graph_at(0)
        return g, M.decavg_matrix(
            g, np.ones(N) if sizes is None else sizes
        ).astype(np.float32)

    def test_renorm_row_stochastic_under_mask(self):
        _, w = self._w()
        rng = np.random.default_rng(0)
        keep = rng.random((N, N)) < 0.6
        keep |= np.eye(N, dtype=bool)
        wn, ok = F.renorm_dense(jnp.asarray(w), jnp.asarray(keep))
        assert np.asarray(ok).all()
        np.testing.assert_allclose(np.asarray(wn).sum(1), 1.0, atol=1e-6)
        assert (np.asarray(wn)[~keep] == 0).all()

    def test_empty_row_identity_fallback_dense(self):
        """A node whose entire row is masked keeps its own params exactly —
        the empty-neighborhood bug this PR fixes (previously a 0/0 row)."""
        _, w = self._w()
        keep = np.ones((N, N), bool)
        keep[4, :] = False  # node 4 loses everything, incl. self-loop
        alive = jnp.ones(N, bool)
        params = {"w": jnp.asarray(np.random.default_rng(1).standard_normal((N, 3, 2)), jnp.float32)}
        out = F.mix_faulted_dense(jnp.asarray(w), jnp.asarray(keep), alive, params)
        assert not jnp.isnan(out["w"]).any()
        np.testing.assert_array_equal(np.asarray(out["w"][4]), np.asarray(params["w"][4]))
        # the effective-W helper shows the same identity row
        eff = F.faulted_dense_w(w, keep, np.ones(N, bool))
        np.testing.assert_array_equal(eff[4], np.eye(N, dtype=np.float32)[4])
        np.testing.assert_allclose(eff.sum(1), 1.0, atol=1e-6)

    def test_empty_row_identity_fallback_csr(self):
        """Same fallback on the CSR path, triggered the realistic way: a
        zero-data node (data_sizes[i]=0 => row mass only on neighbors) whose
        neighbors all die."""
        g = sched().graph_at(0)
        sizes = np.ones(N)
        sizes[0] = 0.0  # node 0 weights itself 0 in DecAvg
        w = M.decavg_matrix(g, sizes).astype(np.float32)
        assert w[0, 0] == 0.0
        csr = S.csr_from_dense(w)
        alive = np.ones(N, bool)
        alive[np.flatnonzero(np.asarray(g.adj[0]))] = False  # kill 0's peers
        keep = alive[np.asarray(csr.rows)] & alive[np.asarray(csr.indices)]
        params = jnp.asarray(
            np.random.default_rng(2).standard_normal((N, 5)), jnp.float32
        )
        out = F.mix_faulted_csr(
            csr.rows, csr.indices, csr.values, jnp.asarray(keep),
            jnp.asarray(alive), N, params,
        )
        assert not jnp.isnan(out).any()
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(params[0]))

    def test_csr_matches_dense(self):
        _, w = self._w(np.random.default_rng(3).uniform(0.5, 5.0, N))
        tr = F.FaultTrace(COMBINED, sched(), seed=2)
        csr = S.csr_from_dense(w)
        alive = jnp.asarray(tr.alive(1))
        params = jnp.asarray(
            np.random.default_rng(4).standard_normal((N, 7)), jnp.float32
        )
        pub = params * 0.5  # pretend-stale snapshots exercise the two-operand path
        a = F.mix_faulted_dense(
            jnp.asarray(w), jnp.asarray(tr.dense_keep(1)), alive, params, pub
        )
        b = F.mix_faulted_csr(
            csr.rows, csr.indices, csr.values,
            jnp.asarray(tr.entry_keep(1, csr.rows, csr.indices)),
            alive, N, params, pub,
        )
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    def test_dead_nodes_bit_unchanged(self):
        _, w = self._w()
        tr = F.FaultTrace("churn:p_leave=0.5,p_join=0.0", sched(), seed=5)
        alive = tr.alive(0)
        assert not alive.all() and alive.any()
        params = jnp.asarray(
            np.random.default_rng(6).standard_normal((N, 4)), jnp.float32
        )
        out = F.mix_faulted_dense(
            jnp.asarray(w), jnp.asarray(tr.dense_keep(0)),
            jnp.asarray(alive), params,
        )
        np.testing.assert_array_equal(
            np.asarray(out[~alive]), np.asarray(params[~alive])
        )
        assert not np.allclose(np.asarray(out[alive]), np.asarray(params[alive]))

    def test_consensus_fixed_point_preserved_on_alive(self):
        _, w = self._w()
        tr = F.FaultTrace(COMBINED, sched(), seed=6)
        const = jnp.ones((N, 3), jnp.float32) * 2.5
        out = F.mix_faulted_dense(
            jnp.asarray(w), jnp.asarray(tr.dense_keep(0)),
            jnp.asarray(tr.alive(0)), const, const,
        )
        np.testing.assert_allclose(np.asarray(out), 2.5, atol=1e-6)


class TestHistory:
    def test_ring_buffer_publishes_stale_snapshots(self):
        delay = jnp.asarray([0, 1, 3], jnp.int32)
        hist = F.init_history(jnp.zeros((3, 2)), depth=4)
        snaps = []
        for r in range(6):
            params = jnp.full((3, 2), float(r))
            snaps.append(params)
            pub, hist = F.push_and_publish(params, hist, jnp.int32(r), delay)
            pub = np.asarray(pub)
            # node i publishes its params from min(delay_i, r) rounds ago
            for i, d in enumerate([0, 1, 3]):
                np.testing.assert_array_equal(
                    pub[i], np.asarray(snaps[r - min(d, r)][i])
                )

    def test_where_alive_freezes(self):
        alive = jnp.asarray([True, False])
        new = {"a": jnp.ones((2, 3)), "b": jnp.full((2,), 5.0)}
        old = {"a": jnp.zeros((2, 3)), "b": jnp.zeros((2,))}
        out = F.where_alive(alive, new, old)
        np.testing.assert_array_equal(np.asarray(out["a"]), [[1, 1, 1], [0, 0, 0]])
        np.testing.assert_array_equal(np.asarray(out["b"]), [5.0, 0.0])


class TestAnalytics:
    def test_churn_and_recovery_rounds(self):
        assert F.churn_rounds([16, 16, 12, 12, 13, 10], 16) == [2, 5]
        rounds = [0, 2, 4, 6, 8]
        accs = [0.2, 0.5, 0.1, 0.3, 0.6]
        assert F.recovery_rounds(rounds, accs, 3) == 5  # recovers at r=8
        assert F.recovery_rounds(rounds, [0.2, 0.5, 0.1, 0.3, 0.4], 3) is None
        assert F.recovery_rounds(rounds, accs, 0) is None  # no pre-event eval


# ---------------------------------------------------------------------------
# Engine / trainer gating
# ---------------------------------------------------------------------------


def _loader(seed=2):
    from repro.data.synthetic import make_mnist_like

    ds = make_mnist_like(train_per_class=40, test_per_class=10, dim=DIM, seed=0)
    parts = P.iid(ds.y_train, N, seed=1)
    return ds, NodeLoader(ds.x_train, ds.y_train, parts, batch_size=8, seed=seed)


@pytest.fixture(scope="module")
def data():
    return _loader()


def make_trainer(data, backend="dense", faults=COMBINED, **kw):
    _, loader = data
    return DecentralizedTrainer(
        "ba:n=16,m=2", loader, seed=0, in_dim=DIM, lr=0.05, momentum=0.9,
        mix_impl=backend, faults=faults, **kw
    )


class TestGating:
    def test_capabilities_faults_column(self):
        caps = decavg.GossipEngine.capabilities()
        assert {b for b, c in caps.items() if c["faults"]} == {
            "dense", "sparse", "sparse_sharded"
        }

    def test_engine_rejects_unsupported_backend(self):
        with pytest.raises(ValueError, match="does not support faults"):
            decavg.GossipEngine(
                "ring:n=16", backend="pallas", faults="drop:p_edge=0.1"
            )
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
        with pytest.raises(ValueError, match="does not support faults"):
            decavg.GossipEngine(
                "ring:n=16", backend="sharded", mesh=mesh,
                faults="drop:p_edge=0.1",
            )

    def test_engine_mix_requires_round(self):
        eng = decavg.GossipEngine("ring:n=16", faults="drop:p_edge=0.1")
        with pytest.raises(ValueError, match="round="):
            eng.mix(jnp.zeros((16, 3)))

    def test_fault_trace_requires_schedule(self):
        eng = decavg.GossipEngine("ring:n=16")
        with pytest.raises(ValueError, match="no fault schedule"):
            eng.fault_trace

    def test_trainer_rejects_compress(self, data):
        with pytest.raises(ValueError, match="compose with compress"):
            make_trainer(data, compress=0.5)

    def test_trainer_rejects_gossip_first(self, data):
        tr = make_trainer(data)
        with pytest.raises(ValueError, match="gossip_first"):
            tr.run(2, gossip_first=True)
        tr = make_trainer(data)
        with pytest.raises(ValueError, match="gossip_first"):
            tr.run_fused(2, gossip_first=True)


# ---------------------------------------------------------------------------
# Tentpole: trainer loop == fused under faults
# ---------------------------------------------------------------------------


def assert_trees_close(a, b, **kw):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


class TestTrainerFaulted:
    @pytest.mark.parametrize("backend", ["dense", "sparse"])
    @pytest.mark.parametrize("gossip_every", [1, 2])
    def test_loop_matches_fused(self, data, backend, gossip_every):
        ds, _ = data
        kw = dict(backend=backend, gossip_every=gossip_every)
        loop = make_trainer(data, **kw)
        loop.run(6, x_test=ds.x_test, y_test=ds.y_test, eval_every=3)
        fused = make_trainer(data, **kw)
        fused.run_fused(6, x_test=ds.x_test, y_test=ds.y_test, eval_every=3)
        assert_trees_close(loop.params, fused.params, rtol=1e-6, atol=1e-6)
        assert_trees_close(loop.opt_state, fused.opt_state, rtol=1e-6, atol=1e-6)

    def test_loop_matches_fused_rewire(self, data):
        for mode in ("loop", "fused"):
            tr = DecentralizedTrainer(
                "ba:n=16,m=2@rewire=3", data[1], seed=0, in_dim=DIM, lr=0.05,
                momentum=0.9, mix_impl="sparse", faults=COMBINED,
            )
            (tr.run if mode == "loop" else tr.run_fused)(7)
            if mode == "loop":
                ref = tr.params
        assert_trees_close(ref, tr.params, rtol=1e-6, atol=1e-6)

    def test_dead_nodes_frozen_through_training(self, data):
        """A node killed at round 2 holds exactly its post-round-1 params:
        two trainers share seeds, one stops right before the kill."""
        spec = "churn:p_leave=1.0,p_join=0.0,frac=0.25,start=2@targeted=hubs"
        pre = make_trainer(data, faults=spec)
        pre.run(2)  # rounds 0-1: everyone alive
        full = make_trainer(data, faults=spec)
        full.run(5)  # rounds 2-4: hubs dead (p_join=0)
        dead = ~full.engine.fault_trace.alive(4)
        assert dead.any() and not dead.all()
        for a, b in zip(jax.tree.leaves(pre.params), jax.tree.leaves(full.params)):
            np.testing.assert_array_equal(np.asarray(a)[dead], np.asarray(b)[dead])
            assert not np.allclose(np.asarray(a)[~dead], np.asarray(b)[~dead])

    def test_churn_only_runs_without_history(self, data):
        tr = make_trainer(data, faults="churn:p_leave=0.3,p_join=0.5")
        assert not tr._has_hist
        tr.run_fused(4)
        assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(tr.params))


# ---------------------------------------------------------------------------
# Satellite: run-id backward compatibility
# ---------------------------------------------------------------------------


class TestSpecCompat:
    def test_run_id_unchanged_without_faults(self):
        """Adding the ``faults`` field must not re-hash existing stores:
        the literal below was computed with a pre-subsystem spec.py."""
        from repro.experiments import ExperimentSpec

        s = ExperimentSpec(
            topology="ba:n=16,m=2", partitioner="hub_focused", seed=3,
            rounds=12, lr=0.05,
        )
        assert s.run_id == "ba-hub_focused-s3-b80c1156"
        assert "faults" not in s.canonical()

    def test_run_id_changes_with_faults(self):
        from repro.experiments import ExperimentSpec

        base = ExperimentSpec(topology="ba:n=16,m=2", seed=3)
        faulted = ExperimentSpec(
            topology="ba:n=16,m=2", seed=3, faults="drop:p_edge=0.1"
        )
        assert base.run_id != faulted.run_id
        assert faulted.canonical()["faults"] == "drop:p_edge=0.1"

    def test_spec_validates_faults_eagerly(self):
        from repro.experiments import ExperimentSpec

        with pytest.raises(ValueError, match="unknown fault kind"):
            ExperimentSpec(topology="ring:n=16", faults="meteor:p=1")
