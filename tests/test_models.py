"""Layer-level model tests: attention paths, MoE routing, Mamba/RWKV
recurrences (chunked vs exact single-step), frontends."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers as L
from repro.models import mamba as Mb
from repro.models import moe as Moe
from repro.models import rwkv as Rk


class TestAttention:
    @pytest.mark.parametrize("window", [None, 16, 64])
    @pytest.mark.parametrize("gqa", [(8, 8), (8, 2), (4, 1)])
    def test_chunked_matches_dense(self, window, gqa):
        h, hkv = gqa
        key = jax.random.PRNGKey(0)
        b, s, hd = 2, 150, 16
        q = jax.random.normal(key, (b, s, h, hd))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, hd))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, hd))
        pos = jnp.arange(s)
        d = L.dense_attention(q, k, v, pos, pos, causal=True, window=window)
        c = L.chunked_attention(
            q, k, v, pos, pos, causal=True, window=window, kv_chunk=32, q_chunk=64
        )
        np.testing.assert_allclose(np.asarray(d), np.asarray(c), rtol=2e-5, atol=2e-5)

    def test_matches_oracle(self):
        from repro.kernels import ref

        key = jax.random.PRNGKey(1)
        s, h, hkv, hd = 40, 8, 2, 32
        q = jax.random.normal(key, (1, s, h, hd))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, s, hkv, hd))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, s, hkv, hd))
        pos = jnp.arange(s)
        mine = L.dense_attention(q, k, v, pos, pos, causal=True, window=None)[0]
        want = ref.flash_attention_ref(q[0], k[0], v[0], causal=True)
        np.testing.assert_allclose(np.asarray(mine), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_rope_rotation_invariant(self):
        """RoPE preserves pairwise dot products under equal position shift."""
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (1, 6, 4, 32))
        a0 = L.apply_rope(x, jnp.arange(6), 10000.0)
        a5 = L.apply_rope(x, jnp.arange(6) + 5, 10000.0)
        d0 = jnp.einsum("bshd,bthd->st", a0, a0)
        d5 = jnp.einsum("bshd,bthd->st", a5, a5)
        np.testing.assert_allclose(np.asarray(d0), np.asarray(d5), rtol=1e-4, atol=1e-4)

    def test_decode_ring_buffer_wraps(self):
        """Sliding-window decode with a ring cache shorter than the sequence
        matches full-cache decode restricted to the window."""
        spec = L.AttnSpec(num_heads=4, num_kv_heads=2, head_dim=16, window=8)
        p = L.init_attention(jax.random.PRNGKey(0), 32, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 32))
        # full-sequence reference with window
        full, _ = L.attention_layer(p, x, spec)
        # ring cache of exactly window size
        cache = {
            "k": jnp.zeros((1, 8, 2, 16)),
            "v": jnp.zeros((1, 8, 2, 16)),
            "index": jnp.zeros((), jnp.int32),
        }
        outs = []
        for t in range(24):
            y, cache = L.attention_layer(p, x[:, t : t + 1], spec, cache=cache)
            outs.append(y)
        got = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=2e-4, atol=2e-4)


class TestMoE:
    def _spec(self, **kw):
        return Moe.MoESpec(num_experts=4, top_k=2, d_ff=32, **kw)

    def test_output_shape_and_aux(self):
        spec = self._spec()
        p = Moe.init_moe(jax.random.PRNGKey(0), 16, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
        y, aux = Moe.moe_ffn(p, x, spec)
        assert y.shape == x.shape
        assert float(aux) >= 1.0 - 1e-5  # switch aux loss lower bound at balance

    def test_dense_residual(self):
        spec = self._spec(dense_residual=True, dense_d_ff=32)
        p = Moe.init_moe(jax.random.PRNGKey(0), 16, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
        y, _ = Moe.moe_ffn(p, x, spec)
        # residual branch contributes: zeroing it changes the output
        p2 = dict(p)
        p2["dense"] = jax.tree.map(jnp.zeros_like, p["dense"])
        y2, _ = Moe.moe_ffn(p2, x, spec)
        assert not np.allclose(np.asarray(y), np.asarray(y2))

    def test_dropless_capacity_is_exact_mixture(self):
        """With unbounded capacity, the MoE equals the explicit per-token
        top-k mixture of expert FFNs."""
        spec = self._spec(capacity_factor=100.0)
        d = 8
        p = Moe.init_moe(jax.random.PRNGKey(0), d, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, d))
        y, _ = Moe.moe_ffn(p, x, spec)

        xt = x.reshape(-1, d)
        logits = xt @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        gv, gi = jax.lax.top_k(probs, 2)
        gv = gv / gv.sum(-1, keepdims=True)
        want = []
        for t in range(xt.shape[0]):
            acc = jnp.zeros(d)
            for j in range(2):
                e = int(gi[t, j])
                h = jax.nn.silu(xt[t] @ p["w_gate"][e]) * (xt[t] @ p["w_in"][e])
                acc = acc + gv[t, j] * (h @ p["w_out"][e])
            want.append(acc)
        want = jnp.stack(want).reshape(1, 6, d)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_capacity_drops_tokens(self):
        spec = self._spec(capacity_factor=0.25)
        p = Moe.init_moe(jax.random.PRNGKey(0), 16, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
        y, _ = Moe.moe_ffn(p, x, spec)
        # some token rows must be exactly zero (dropped by capacity)
        norms = np.linalg.norm(np.asarray(y).reshape(-1, 16), axis=1)
        assert (norms < 1e-9).any()


class TestMamba:
    def test_chunked_matches_stepwise(self):
        spec = Mb.MambaSpec(d_state=8, chunk=4)
        d = 16
        p = Mb.init_mamba(jax.random.PRNGKey(0), d, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 11, d))
        full, _ = Mb.mamba_block(p, x, spec)
        cache = Mb.init_mamba_cache(2, d, spec, jnp.float32)
        outs = []
        for t in range(11):
            y, cache = Mb.mamba_block(p, x[:, t : t + 1], spec, cache=cache)
            outs.append(y)
        got = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=2e-4, atol=2e-4)

    @given(st.integers(1, 40), st.integers(1, 8), st.integers(0, 10**4))
    @settings(max_examples=10, deadline=None)
    def test_chunk_size_invariance(self, s, chunk, seed):
        """The chunked associative scan is exact for any chunk size."""
        d = 8
        spec1 = Mb.MambaSpec(d_state=4, chunk=chunk)
        spec2 = Mb.MambaSpec(d_state=4, chunk=64)
        p = Mb.init_mamba(jax.random.PRNGKey(seed), d, spec1, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, s, d))
        y1, _ = Mb.mamba_block(p, x, spec1)
        y2, _ = Mb.mamba_block(p, x, spec2)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)


class TestRWKV:
    def test_chunked_matches_stepwise(self):
        spec = Rk.RWKVSpec(head_dim=8, decay_lora=4, chunk=4)
        d = 16
        p = Rk.init_rwkv(jax.random.PRNGKey(0), d, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 13, d)) * 0.5
        full, _ = Rk.rwkv_block(p, x, spec)
        cache = Rk.init_rwkv_cache(2, d, spec, jnp.float32)
        outs = []
        for t in range(13):
            y, cache = Rk.rwkv_block(p, x[:, t : t + 1], spec, cache=cache)
            outs.append(y)
        got = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=5e-4, atol=5e-4)

    def test_ffn_token_shift_cache(self):
        p = Rk.init_rwkv_ffn(jax.random.PRNGKey(0), 8, 16, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 7, 8))
        full, _ = Rk.rwkv_ffn(p, x)
        cache = {"shift": jnp.zeros((1, 8))}
        outs = []
        for t in range(7):
            y, cache = Rk.rwkv_ffn(p, x[:, t : t + 1], cache=cache)
            outs.append(y)
        got = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=1e-5, atol=1e-5)


class TestMLP:
    def test_shapes_and_grads(self):
        from repro.models.mlp import init_mlp, mlp_forward

        p = init_mlp(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 784))
        logits = mlp_forward(p, x)
        assert logits.shape == (5, 10)
        g = jax.grad(lambda p: mlp_forward(p, x).sum())(p)
        assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
