"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracle
(interpret mode executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.gossip_mix import gossip_mix_pallas


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)


SHAPES = [
    (4, 100),       # tiny, unpadded
    (100, 700),     # the paper's N=100
    (128, 512),     # exactly one block
    (130, 513),     # just over block boundaries
    (256, 1536),    # multi-block everywhere
    (1, 1),         # degenerate
]


@pytest.mark.parametrize("n,d", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_mix_matches_ref(n, d, dtype):
    key = jax.random.PRNGKey(n * 1000 + d)
    w = jax.nn.softmax(jax.random.normal(key, (n, n)), axis=-1)  # row-stochastic
    p = jax.random.normal(jax.random.fold_in(key, 1), (n, d)).astype(dtype)
    got = ops.gossip_mix(w, p, interpret=True)
    want = ref.gossip_mix_ref(w, p)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("block_sparse", [False, True])
def test_block_sparse_path(block_sparse):
    """A mixing matrix with whole zero blocks gives identical results with
    the block-skip optimization on and off."""
    n, d = 256, 1024
    key = jax.random.PRNGKey(0)
    w = jax.nn.softmax(jax.random.normal(key, (n, n)), axis=-1)
    w = w.at[:128, 128:].set(0.0)  # kill an off-diagonal block
    w = w / w.sum(axis=1, keepdims=True)
    p = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    got = ops.gossip_mix(w, p, interpret=True, block_sparse=block_sparse)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.gossip_mix_ref(w, p)), rtol=3e-5, atol=3e-5
    )


def test_custom_block_shapes():
    n, d = 128, 1024
    key = jax.random.PRNGKey(3)
    w = jax.nn.softmax(jax.random.normal(key, (n, n)), axis=-1)
    p = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    want = ref.gossip_mix_ref(w, p)
    for bm, bk, bd in [(64, 64, 256), (128, 128, 512), (32, 128, 128)]:
        got = ops.gossip_mix(w, p, bm=bm, bk=bk, bd=bd, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_padded_kernel_rejects_unpadded():
    w = jnp.ones((100, 100))
    p = jnp.ones((100, 300))
    with pytest.raises(ValueError):
        gossip_mix_pallas(w, p, interpret=True)  # raw kernel requires padding


@given(
    n=st.integers(2, 64),
    d=st.integers(1, 300),
    seed=st.integers(0, 10**6),
)
@settings(max_examples=15, deadline=None)
def test_gossip_mix_property(n, d, seed):
    key = jax.random.PRNGKey(seed)
    w = jax.nn.softmax(jax.random.normal(key, (n, n)), axis=-1)
    p = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    got = ops.gossip_mix(w, p, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.gossip_mix_ref(w, p)), rtol=5e-5, atol=5e-5
    )


def test_flash_attention_ref_self_consistency():
    """Oracle sanity: full attention == windowed attention with full window."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (24, 8, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (24, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (24, 2, 32))
    a = ref.flash_attention_ref(q, k, v, causal=True)
    b = ref.flash_attention_ref(q, k, v, causal=True, window=24)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


class TestFlashAttentionKernel:
    """Pallas flash-attention kernel vs the pure-jnp oracle (interpret)."""

    @pytest.mark.parametrize(
        "b,s,h,hkv,hd,window",
        [
            (1, 64, 4, 2, 32, None),
            (2, 100, 8, 2, 32, None),   # unpadded seq -> wrapper pads
            (1, 128, 4, 4, 64, 48),     # MHA + sliding window
            (1, 96, 8, 1, 32, 16),      # MQA + tight window
        ],
    )
    def test_matches_oracle(self, b, s, h, hkv, hd, window):
        from repro.kernels import ops

        key = jax.random.PRNGKey(s * 7 + h)
        q = jax.random.normal(key, (b, s, h, hd))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, hd))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, hd))
        got = ops.flash_attention(
            q, k, v, causal=True, window=window, bq=32, bk=32, interpret=True
        )
        want = jnp.stack(
            [
                ref.flash_attention_ref(q[i], k[i], v[i], causal=True, window=window)
                for i in range(b)
            ]
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)

    def test_bf16(self):
        from repro.kernels import ops

        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (1, 64, 4, 32)).astype(jnp.bfloat16)
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, 2, 32)).astype(jnp.bfloat16)
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 64, 2, 32)).astype(jnp.bfloat16)
        got = ops.flash_attention(q, k, v, bq=32, bk=32, interpret=True)
        want = ref.flash_attention_ref(q[0], k[0], v[0], causal=True)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(got[0], np.float32), np.asarray(want, np.float32),
            rtol=3e-2, atol=3e-2,
        )
