"""Clean counterpart to j002_trigger: donated buffers are either rebound to
a genuinely new value before the return, or copied into a fresh buffer."""

import jax
import jax.numpy as jnp


def _init_refs(params, scale):
    params = jnp.array(params, dtype=jnp.float32, copy=True)  # fresh buffer
    return params, scale * 2.0


init_refs = jax.jit(_init_refs, donate_argnums=(0,))


class Mixer:
    @staticmethod
    def _apply(params, delta):
        params = params + delta  # rebound: the donated buffer is consumed
        return params

    def make(self):
        return jax.jit(self._apply, donate_argnums=(0,))
