"""D002 trigger: wall clock in a run path — nondeterministic if it feeds
results, and the wrong clock (not monotonic) if it measures elapsed time."""

import time
from datetime import datetime


def measure(fn):
    t0 = time.time()
    fn()
    return time.time() - t0, datetime.now()
