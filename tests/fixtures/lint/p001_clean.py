"""P001 clean: aligned tiles, symbolic dims the rule must not guess at, and
the flash-prefill 3D (batch, block_q, head_dim) layout — a leading batch dim
of 1 is NOT a sublane dim and must not fire."""

BLOCK_ROWS = 8


def specs(pl, bd):
    return [
        pl.BlockSpec((BLOCK_ROWS, 128), lambda i, j: (i, j)),
        pl.BlockSpec((16, 256), lambda i, j: (i, j)),
        pl.BlockSpec((BLOCK_ROWS, bd), lambda i, j: (i, j)),  # bd unknown
        pl.BlockSpec((1, 128, 128), lambda b, i: (b, i, 0)),
        pl.BlockSpec((1, BLOCK_ROWS, 128), lambda b, i: (b, i, 0)),
    ]
