"""P001 clean: aligned tiles, plus symbolic dims the rule must not guess at."""

BLOCK_ROWS = 8


def specs(pl, bd):
    return [
        pl.BlockSpec((BLOCK_ROWS, 128), lambda i, j: (i, j)),
        pl.BlockSpec((16, 256), lambda i, j: (i, j)),
        pl.BlockSpec((BLOCK_ROWS, bd), lambda i, j: (i, j)),  # bd unknown
    ]
