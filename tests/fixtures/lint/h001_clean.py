"""Clean counterpart to h001_trigger: the new field is registered in
_HASH_OPTIONAL with its dataclass default, so default-valued specs keep
their pre-existing run ids and only non-default values hash."""

import dataclasses

from repro.experiments.spec import ExperimentSpec


@dataclasses.dataclass(frozen=True)
class CompatSpec(ExperimentSpec):
    fancy_new_knob: int = 3

    _HASH_OPTIONAL = {**ExperimentSpec._HASH_OPTIONAL, "fancy_new_knob": 3}
