"""L001 clean: the pragma carries its reason, so it suppresses the D002
finding and raises nothing itself."""

import time


def stamp():
    return time.time()  # lint: allow[D002] — wall-clock timestamp is the product here
