"""L001 trigger: a suppression pragma with no reason. It is a finding in
itself AND suppresses nothing, so the D002 underneath still fires."""

import time


def stamp():
    return time.time()  # lint: allow[D002]
