"""Regression fixture (PR 5 bug class): the loop run path re-built its
jitted round step every schedule period, so every period re-traced and
recompiled. J001 flags jit construction lexically inside a loop body."""

import jax


def run_rounds(step_fn, params, periods):
    for period in periods:
        step = jax.jit(step_fn, static_argnums=(1,))  # fresh cache every lap
        params = step(params, period)
    return params
