"""Clean counterpart to j001_trigger: the jit is hoisted out of the loop
(one trace), and the per-period variant keeps a bounded wrapper cache."""

import jax


def run_rounds(step_fn, params, periods):
    step = jax.jit(step_fn, static_argnums=(1,))
    for period in periods:
        params = step(params, period)
    return params


def run_rounds_cached(make_step, params, periods, max_cache=64):
    cache = {}
    for period in periods:
        if period not in cache:
            if len(cache) >= max_cache:
                cache.clear()
            cache[period] = _jit_for_period(make_step, period)
        params = cache[period](params)
    return params


def _jit_for_period(make_step, period):
    return jax.jit(make_step(period))
