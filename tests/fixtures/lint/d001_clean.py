"""D001 clean: every draw flows from an explicit spec-derived seed."""

import numpy as np


def sample_nodes(n, seed):
    rng = np.random.default_rng((seed, 0x6E6F6465))
    child = np.random.default_rng(np.random.SeedSequence(seed).spawn(1)[0])
    return int(rng.integers(0, n)), int(child.integers(0, n))
