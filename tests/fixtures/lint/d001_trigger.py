"""D001 trigger: every flavor of unseeded randomness the repo bans —
stdlib random, a bare default_rng(), and numpy's global RNG state."""

import random

import numpy as np


def sample_nodes(n):
    rng = np.random.default_rng()
    np.random.seed(0)
    return int(rng.integers(0, n)), random.random()
