"""P001 trigger: BlockSpec block shapes off the TPU (sublane=8, lane=128)
tile grid — an 8x8 trailing tile, a 1-row sublane block, and a 3D
flash-prefill-style (batch, block_q, head_dim) tile with a misaligned
block_q: only the trailing two dims sit on the sublane/lane grid, and the
rule must still check them behind a leading batch dim."""

BLOCK_ROWS = 8


def specs(pl):
    return [
        pl.BlockSpec((BLOCK_ROWS, BLOCK_ROWS), lambda i, j: (i, j)),
        pl.BlockSpec((1, 256), lambda i, j: (i, j)),
        pl.BlockSpec((1, 12, 128), lambda b, i: (b, i, 0)),
    ]
