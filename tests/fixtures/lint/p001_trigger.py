"""P001 trigger: BlockSpec block shapes off the TPU (sublane=8, lane=128)
tile grid — an 8x8 trailing tile and a 1-row sublane block."""

BLOCK_ROWS = 8


def specs(pl):
    return [
        pl.BlockSpec((BLOCK_ROWS, BLOCK_ROWS), lambda i, j: (i, j)),
        pl.BlockSpec((1, 256), lambda i, j: (i, j)),
    ]
