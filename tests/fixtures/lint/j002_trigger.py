"""Regression fixture (PR 5 bug class): CHOCO compress-state init returned
``p.astype(float32)`` — a no-op view when p is already f32 — so the
reference state aliased the params buffer, and the first jitted step that
donated both invalidated one through the other. J002 flags donated args
that reach a return value without being rebound."""

import jax
import jax.numpy as jnp


def _init_refs(params, scale):
    # astype to the same dtype returns the SAME buffer, not a copy
    return params.astype(jnp.float32), scale * 2.0


init_refs = jax.jit(_init_refs, donate_argnums=(0,))


class Mixer:
    def _apply(self, params, delta):
        return (params + delta).reshape(params.shape), params.ravel()

    def make(self):
        # bound method: donate_argnums=(0,) is ``params``, not ``self``
        return jax.jit(self._apply, donate_argnums=(0,))
