"""Regression fixture (PR 7 and PR 8 bug class): a new default-valued spec
field with no _HASH_OPTIONAL entry. ``canonical()`` then hashes the new
field for every spec, silently rewriting every pre-existing store's run ids
— resume and skip-completed stop matching. H001 flags the missing entry and
the golden-run-id drift."""

import dataclasses

from repro.experiments.spec import ExperimentSpec


@dataclasses.dataclass(frozen=True)
class DriftSpec(ExperimentSpec):
    fancy_new_knob: int = 3
