"""Experiment harness: spec identity/round-trip, store resume semantics,
runner streaming, analysis joins and the knowledge-spread orderings."""

import json
import os

import numpy as np
import pytest

from repro.experiments import analysis
from repro.experiments import presets
from repro.experiments import runner
from repro.experiments.spec import ExperimentSpec, expand_grid
from repro.experiments.store import ResultsStore

TINY = dict(
    rounds=2,
    eval_every=1,
    batch_size=8,
    data={"train_per_class": 40, "test_per_class": 10},
)


class TestSpec:
    def test_run_id_stable_and_content_addressed(self):
        a = ExperimentSpec(topology="ring:n=8", **TINY)
        b = ExperimentSpec(topology="ring:n=8", **TINY)
        assert a.run_id == b.run_id
        c = ExperimentSpec(topology="ring:n=8", lr=0.01, **TINY)
        assert c.run_id != a.run_id
        # tag is cosmetic: excluded from identity
        d = ExperimentSpec(topology="ring:n=8", tag="whatever", **TINY)
        assert d.run_id == a.run_id
        assert a.run_id.startswith("ring-iid-s0-")

    def test_json_round_trip(self):
        s = ExperimentSpec(
            topology="ba:n=16,m=2", partitioner="dirichlet",
            partitioner_params={"beta": 0.3}, seed=7, **TINY,
        )
        back = ExperimentSpec.from_json(json.loads(json.dumps(s.to_json())))
        assert back == s and back.run_id == s.run_id

    def test_from_json_rejects_unknown_fields(self):
        with pytest.raises(ValueError, match="unknown ExperimentSpec fields"):
            ExperimentSpec.from_json({"topology": "ring:n=8", "bogus": 1})

    def test_validation(self):
        with pytest.raises(ValueError, match="unknown partitioner"):
            ExperimentSpec(topology="ring:n=8", partitioner="nope")
        with pytest.raises(ValueError, match="rounds"):
            ExperimentSpec(topology="ring:n=8", rounds=0)
        with pytest.raises(ValueError, match="model kind"):
            ExperimentSpec(topology="ring:n=8", model={"kind": "gan"})

    def test_grid_expansion(self):
        specs = expand_grid(
            {"rounds": 3},
            topology=["ring:n=8", "star:n=8", "ba:n=8,m=2"],
            partitioner=["iid", "hub_focused"],
            seed=[0, 1],
        )
        assert len(specs) == 12
        assert len({s.run_id for s in specs}) == 12
        assert {s.family for s in specs} == {"ring", "star", "ba"}

    def test_presets_expand(self):
        for name in presets.PRESETS:
            specs = presets.get_preset(name)
            assert specs, name
            assert len({s.run_id for s in specs}) == len(specs)
        smoke = presets.get_preset("smoke")
        assert len({s.family for s in smoke}) >= 3  # >= 3 topology families
        parts = {s.partitioner for s in smoke}
        assert {"hub_focused", "edge_focused"} <= parts


class TestStore:
    def test_append_read_and_truncated_tail(self, tmp_path):
        st = ResultsStore(str(tmp_path / "r.jsonl"))
        st.run_start("a", {"x": 1})
        st.round("a", {"round": 0, "v": 1.0})
        with open(st.path, "a") as f:
            f.write('{"kind": "round", "run_id": "a", "rou')  # crashed writer
        recs = st.records()
        assert [r["kind"] for r in recs] == ["run_start", "round"]

    def test_resume_semantics(self, tmp_path):
        st = ResultsStore(str(tmp_path / "r.jsonl"))
        st.run_start("a", {})
        st.round("a", {"round": 0, "v": 1.0})
        assert st.completed() == set()  # no run_end: incomplete
        st.run_end("a", "failed", error="boom")
        assert st.completed() == set()  # failed doesn't count
        # second attempt supersedes the first's rounds
        st.run_start("a", {})
        st.round("a", {"round": 0, "v": 2.0})
        st.round("a", {"round": 1, "v": 3.0})
        st.run_end("a", "completed", final={"v": 3.0})
        assert st.completed() == {"a"}
        curve = st.curves("a")
        assert [r["v"] for r in curve] == [2.0, 3.0]
        assert st.finals()["a"]["final"] == {"v": 3.0}

    def test_latest_attempt_wins_even_over_older_completed(self, tmp_path):
        """completed()/finals()/curves() all describe the SAME attempt: a
        fresh re-run that fails supersedes an older completed attempt."""
        st = ResultsStore(str(tmp_path / "r.jsonl"))
        st.run_start("a", {})
        st.round("a", {"round": 0, "v": 1.0})
        st.run_end("a", "completed", final={"v": 1.0})
        st.run_start("a", {})  # --fresh re-run...
        st.round("a", {"round": 0, "v": 9.0})
        st.run_end("a", "failed", error="crash")  # ...that dies
        assert st.completed() == set()  # retried on next resume
        assert st.finals() == {}
        assert [r["v"] for r in st.curves("a")] == [9.0]
        # mid-flight (no run_end yet) is also not completed
        st.run_start("a", {})
        assert st.completed() == set() and st.curves("a") == []


@pytest.fixture(scope="module")
def tiny_sweep(tmp_path_factory):
    """Two completed tiny runs in one store (shared across tests)."""
    path = str(tmp_path_factory.mktemp("sweep") / "r.jsonl")
    specs = expand_grid(
        dict(TINY), topology=["ring:n=6", "star:n=6"], partitioner=["iid"], seed=[0]
    )
    summary = runner.run_sweep(specs, path)
    return specs, path, summary


class TestRunner:
    def test_streams_knowledge_spread_records(self, tiny_sweep):
        specs, path, summary = tiny_sweep
        assert summary["ran"] == 2 and not summary["failed"]
        st = ResultsStore(path)
        curve = st.curves(specs[0].run_id)
        assert len(curve) == TINY["rounds"]
        for key in ("mean_acc", "g1_acc", "g2_acc", "consensus_mean", "wall_s"):
            assert all(np.isfinite(r[key]) for r in curve), key
        final = st.finals()[specs[0].run_id]["final"]
        assert final["graph"]["nodes"] == 6
        assert "spectral_gap" in final["graph"]

    def test_rerun_is_idempotent(self, tiny_sweep):
        specs, path, _ = tiny_sweep
        before = os.path.getsize(path)
        summary = runner.run_sweep(specs, path)
        assert summary["ran"] == 0 and summary["skipped"] == 2
        assert os.path.getsize(path) == before  # nothing appended

    def test_failed_spec_recorded_and_survived(self, tmp_path):
        bad = ExperimentSpec(topology="ring:n=6", backend="sharded", **TINY)
        ok = ExperimentSpec(topology="ring:n=6", **TINY)
        summary = runner.run_sweep([bad, ok], str(tmp_path / "r.jsonl"))
        assert summary["failed"] == [bad.run_id]
        st = ResultsStore(str(tmp_path / "r.jsonl"))
        assert st.completed() == {ok.run_id}
        # the failed run is retried on resume, completed one is skipped
        summary2 = runner.run_sweep([bad, ok], st.path)
        assert summary2["skipped"] == 1 and summary2["failed"] == [bad.run_id]

    def test_matrix_kind_reaches_the_engine(self, tmp_path):
        """spec.matrix is part of the run identity, so it must actually be
        the mixing matrix used (mh = doubly stochastic, unlike decavg)."""
        spec = ExperimentSpec(topology="er:n=8,p=0.6", matrix="mh", **TINY)
        assert spec.run_id != ExperimentSpec(topology="er:n=8,p=0.6", **TINY).run_id
        from repro.data.synthetic import make_mnist_like
        from repro.data.loader import NodeLoader
        from repro.core import partition as P
        from repro.train.trainer import DecentralizedTrainer

        ds = make_mnist_like(train_per_class=40, test_per_class=10, seed=0)
        parts = P.iid(ds.y_train, 8, seed=0)
        loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=8, seed=0)
        tr = DecentralizedTrainer("er:n=8,p=0.6", loader, matrix="mh", seed=0)
        w = np.asarray(tr.engine.w)
        np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-6)  # doubly stochastic
        np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-6)
        # and through the runner end-to-end
        st = ResultsStore(str(tmp_path / "r.jsonl"))
        out = runner.run_spec(spec, st)
        assert out["status"] == "completed"

    def test_sparse_p_chunk_reaches_the_engine(self, tmp_path):
        """large_n-shaped specs must actually bound the gather transient:
        model.sparse_p_chunk flows spec -> trainer -> GossipEngine."""
        from repro.data.loader import NodeLoader
        from repro.data.synthetic import make_mnist_like
        from repro.core import partition as P
        from repro.train.trainer import DecentralizedTrainer

        ds = make_mnist_like(train_per_class=40, test_per_class=10, seed=0)
        parts = P.iid(ds.y_train, 8, seed=0)
        loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=8, seed=0)
        tr = DecentralizedTrainer("ring:n=8", loader, mix_impl="sparse",
                                  sparse_p_chunk="auto", seed=0)
        assert tr.engine.sparse_p_chunk == "auto"
        spec = ExperimentSpec(
            topology="ring:n=8", backend="sparse",
            model={"kind": "mlp", "hidden": [16], "sparse_p_chunk": 32}, **TINY,
        )
        out = runner.run_spec(spec, ResultsStore(str(tmp_path / "r.jsonl")))
        assert out["status"] == "completed"
        from repro.experiments.presets import get_preset

        assert all(
            s.model.get("sparse_p_chunk") == "auto" for s in get_preset("large_n")
        )

    def test_hub_vs_edge_partition_wiring(self):
        """Runner assigns G2 to hubs/leaves per the spec's partitioner."""
        from repro.core import topology as T
        from repro.core.partition import partition_summary
        from repro.data.synthetic import make_mnist_like

        ds = make_mnist_like(train_per_class=40, test_per_class=10, seed=0)
        g = T.make("ba:n=12,m=2", seed=3)
        spec = ExperimentSpec(topology="ba:n=12,m=2", partitioner="hub_focused",
                              seed=3, **TINY)
        parts = runner.build_partition(spec, g, ds.y_train)
        summ = partition_summary(ds.y_train, parts)
        holders = np.flatnonzero(summ[:, 5:].sum(axis=1) > 0)
        deg = g.degrees()
        assert deg[holders].min() >= np.sort(deg)[::-1][len(holders) - 1]


class TestBugfixRegressions:
    def test_graph_records_cover_every_schedule_period(self):
        """@regen/@rewire runs must not report period-0 graph properties as
        if they described the whole run (the old _graph_record-from-
        graph_at(0) bug)."""
        from repro.core import decavg as D

        e = D.GossipEngine("er:n=8,p=0.6@regen=2", seed=3)
        out = runner._graph_records(e, rounds=6)
        assert out["graph_num_periods"] == 3
        assert out["graph"]["period"] == 0
        assert [r["period"] for r in out["graph_periods"]] == [0, 1, 2]
        gaps = [r["spectral_gap"] for r in out["graph_periods"]]
        assert all(np.isfinite(g) for g in gaps)
        assert out["graph_mean"]["spectral_gap"] == pytest.approx(np.mean(gaps))
        assert "period" not in out["graph_mean"]
        # a static topology keeps the old single-record shape
        static = runner._graph_records(D.GossipEngine("ring:n=8"), rounds=6)
        assert static["graph_num_periods"] == 1
        assert "graph_periods" not in static and "graph_mean" not in static

    def test_summarize_prefers_period_mean_over_period0(self, tmp_path):
        st = ResultsStore(str(tmp_path / "r.jsonl"))
        st.run_start("x", {"topology": "er:n=8,p=0.5@regen=2",
                           "partitioner": "iid", "seed": 0})
        st.round("x", {"round": 0, "mean_acc": 0.5})
        st.run_end("x", "completed", final={
            "mean_acc": 0.5,
            "graph": {"nodes": 8, "spectral_gap": 0.9, "degree_mean": 4.0,
                      "period": 0},
            "graph_num_periods": 2,
            "graph_mean": {"spectral_gap": 0.6, "degree_mean": 3.5},
        })
        (row,) = analysis.summarize(st)
        assert row["spectral_gap"] == pytest.approx(0.6)  # mean, not period 0
        assert row["degree_mean"] == pytest.approx(3.5)
        assert row["nodes"] == 8 and row["topology_periods"] == 2

    def test_rewire_run_records_per_period_graphs_end_to_end(self, tmp_path):
        spec = ExperimentSpec(topology="er:n=6,p=0.6@regen=1", **TINY)
        out = runner.run_spec(spec, ResultsStore(str(tmp_path / "r.jsonl")))
        assert out["status"] == "completed"
        final = out["final"]
        assert final["graph_num_periods"] == TINY["rounds"]
        assert len(final["graph_periods"]) == TINY["rounds"]
        assert "spectral_gap" in final["graph_mean"]

    def test_consensus_distance_empty_pytree(self):
        from repro.train.metrics import consensus_distance

        out = np.asarray(consensus_distance({}))
        assert out.shape == (0,) and out.dtype == np.float32
        out = np.asarray(consensus_distance([]))
        assert out.shape == (0,)

    def test_stale_shards_salvaged_on_next_sweep(self, tmp_path):
        """A worker that died mid-run leaves its shard + the .shards dir
        behind; the next sweep must merge complete shards (skipped on
        resume), re-run partial ones, and drop the directory."""
        done_spec = ExperimentSpec(topology="ring:n=6", **TINY)
        partial_spec = ExperimentSpec(topology="star:n=6", **TINY)
        store_path = str(tmp_path / "r.jsonl")
        shard_dir = store_path + ".shards"
        os.makedirs(shard_dir)
        # complete shard: parent was killed after the worker finished but
        # before the merge
        done_shard = ResultsStore(os.path.join(shard_dir, f"{done_spec.run_id}.jsonl"))
        done_shard.run_start(done_spec.run_id, done_spec.to_json())
        done_shard.round(done_spec.run_id, {"round": 0, "mean_acc": 0.5})
        done_shard.run_end(done_spec.run_id, "completed", final={"mean_acc": 0.5})
        # stuck shard: worker died mid-run, no run_end
        stuck = ResultsStore(os.path.join(shard_dir, f"{partial_spec.run_id}.jsonl"))
        stuck.run_start(partial_spec.run_id, partial_spec.to_json())
        # stale = old: the startup salvage's age floor must not mistake these
        # for a concurrent sweep's in-flight shards
        for f in os.listdir(shard_dir):
            os.utime(os.path.join(shard_dir, f), (1, 1))
        summary = runner.run_sweep([done_spec, partial_spec], store_path)
        assert not os.path.exists(shard_dir)
        assert summary["skipped"] == 1  # salvaged complete shard counts
        assert summary["ran"] == 1 and not summary["failed"]  # partial re-ran
        st = ResultsStore(store_path)
        assert st.completed() == {done_spec.run_id, partial_spec.run_id}

    def test_salvage_tolerates_missing_dir(self, tmp_path):
        st = ResultsStore(str(tmp_path / "r.jsonl"))
        assert runner._salvage_shards(st, st.path + ".shards", False) == 0

    def test_salvage_age_floor_spares_inflight_shards(self, tmp_path):
        """A concurrent sweep's freshly-written shard must not be merged and
        deleted out from under its writer."""
        st = ResultsStore(str(tmp_path / "r.jsonl"))
        shard_dir = st.path + ".shards"
        os.makedirs(shard_dir)
        fresh = os.path.join(shard_dir, "live.jsonl")
        ResultsStore(fresh).run_start("live", {})
        assert runner._salvage_shards(st, shard_dir, False, min_age_s=60.0) == 0
        assert os.path.exists(fresh)  # left for its writer
        assert runner._salvage_shards(st, shard_dir, False) == 1  # age 0: take it
        assert not os.path.exists(shard_dir)

    def test_multiprocess_sweep_merges_and_cleans_up(self, tmp_path):
        specs = [
            ExperimentSpec(topology="ring:n=6", **TINY),
            ExperimentSpec(topology="star:n=6", **TINY),
        ]
        store_path = str(tmp_path / "r.jsonl")
        summary = runner.run_sweep(specs, store_path, processes=2)
        assert summary["ran"] == 2 and not summary["failed"]
        assert not os.path.exists(store_path + ".shards")
        st = ResultsStore(store_path)
        assert st.completed() == {s.run_id for s in specs}

    def test_graph_records_sampled_above_period_cap(self, monkeypatch):
        """Hundreds of @regen=1 periods must not mean hundreds of post-run
        eigensolves: records are evenly sampled, true count preserved."""
        from repro.core import decavg as D

        monkeypatch.setattr(runner, "_MAX_GRAPH_PERIODS", 4)
        e = D.GossipEngine("er:n=8,p=0.6@regen=1", seed=0)
        out = runner._graph_records(e, rounds=10)
        assert out["graph_num_periods"] == 10
        assert out["graph_periods_sampled"] is True
        assert len(out["graph_periods"]) == 4
        periods = [r["period"] for r in out["graph_periods"]]
        assert periods[0] == 0 and periods[-1] == 9  # endpoints always kept
        assert "spectral_gap" in out["graph_mean"]


class TestAnalysis:
    def _fabricated_store(self, tmp_path) -> ResultsStore:
        """Hand-written records with a known hub > edge ordering."""
        st = ResultsStore(str(tmp_path / "fab.jsonl"))
        runs = [
            ("ba-hub_focused-s0-aaaaaaaa", "hub_focused", [0.10, 0.30, 0.50]),
            ("ba-edge_focused-s0-bbbbbbbb", "edge_focused", [0.10, 0.12, 0.15]),
        ]
        for rid, part, curve in runs:
            st.run_start(rid, {"topology": "ba:n=16,m=2", "partitioner": part,
                               "seed": 0, "backend": "dense"})
            for i, v in enumerate(curve):
                st.round(rid, {"round": i, "mean_acc": 0.2, "g2_acc_spread": v})
            st.run_end(rid, "completed", wall_s=1.0, final={
                "mean_acc": 0.2, "g2_acc_spread": curve[-1],
                "graph": {"nodes": 16, "spectral_gap": 0.4},
            })
        return st

    def test_summarize_and_hub_vs_leaf(self, tmp_path):
        st = self._fabricated_store(tmp_path)
        rows = analysis.summarize(st)
        assert len(rows) == 2
        table = analysis.hub_vs_leaf_table(rows)
        assert table["ba"]["hub_minus_edge"] == pytest.approx(0.35)
        checks = analysis.qualitative_checks(rows)
        assert checks["hub_beats_edge"] is True
        assert checks["hub_beats_edge_by_family"] == {"ba": True}
        assert checks["gossip_learns_g2"] is True

    def test_write_bench_and_render(self, tmp_path):
        st = self._fabricated_store(tmp_path)
        out = str(tmp_path / "BENCH_sweep.json")
        bench = analysis.write_bench(st, out, extra={"preset": "test"})
        on_disk = json.load(open(out))
        assert on_disk["runs"] == 2 and on_disk["preset"] == "test"
        assert on_disk["checks"]["hub_beats_edge"] is True
        text = analysis.render_tables(analysis.summarize(st))
        assert "hub vs leaf" in text and "ba" in text

    def test_real_tiny_store_summarizes(self, tiny_sweep):
        specs, path, _ = tiny_sweep
        rows = analysis.summarize(ResultsStore(path))
        assert {r["family"] for r in rows} == {"ring", "star"}
        for r in rows:
            assert r["spectral_gap"] is not None
            assert np.isfinite(r["final_consensus"])


class TestKnowledgeSpreadEndToEnd:
    """THE acceptance property: hub-held knowledge spreads better than
    leaf-held knowledge on a scale-free graph (paper Fig. 3, smoke scale)."""

    @pytest.mark.slow
    def test_hub_beats_edge_on_ba(self, tmp_path):
        base = dict(
            rounds=8, eval_every=1, lr=0.05, momentum=0.9, batch_size=8,
            data={"train_per_class": 300, "test_per_class": 50},
            topology="ba:n=16,m=2",
        )
        specs = [
            ExperimentSpec(partitioner="hub_focused", **base),
            ExperimentSpec(partitioner="edge_focused", **base),
        ]
        path = str(tmp_path / "r.jsonl")
        summary = runner.run_sweep(specs, path)
        assert not summary["failed"]
        rows = analysis.summarize(ResultsStore(path))
        checks = analysis.qualitative_checks(rows)
        assert checks["hub_beats_edge"] is True
        table = analysis.hub_vs_leaf_table(rows)
        assert table["ba"]["hub_minus_edge"] > 0.05


class TestTrainerHook:
    def test_on_round_streams_group_metrics(self):
        from repro.core import partition as P
        from repro.core import topology as T
        from repro.data.loader import NodeLoader
        from repro.data.synthetic import make_mnist_like
        from repro.train.trainer import DecentralizedTrainer

        ds = make_mnist_like(train_per_class=40, test_per_class=10, seed=0)
        g = T.make("ring:n=6")
        parts = P.iid(ds.y_train, 6, seed=0)
        loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=8, seed=0)
        groups = np.array([0] * 5 + [1] * 5)
        tr = DecentralizedTrainer(g, loader, lr=0.05, seed=0, class_groups=groups)
        seen = []
        hist = tr.run(3, x_test=ds.x_test, y_test=ds.y_test,
                      on_round=lambda m: seen.append(m))
        assert [m.round for m in seen] == [0, 1, 2]
        for m in seen:
            assert m.group_acc.shape == (6, 2)
            assert m.consensus.shape == (6,)
            assert m.wall_s > 0
        assert len(hist) == len(seen) and all(h is s for h, s in zip(hist, seen))

    def test_gossip_every_zero_is_isolated(self):
        """gossip_every=0 never mixes: nodes with same init + same data seed
        but different batches drift apart and stay apart."""
        import jax

        from repro.core import partition as P
        from repro.core import topology as T
        from repro.data.loader import NodeLoader
        from repro.data.synthetic import make_mnist_like
        from repro.train.trainer import DecentralizedTrainer

        ds = make_mnist_like(train_per_class=40, test_per_class=10, seed=0)
        g = T.make("complete:n=4")
        parts = P.iid(ds.y_train, 4, seed=0)
        loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=8, seed=0)
        iso = DecentralizedTrainer(g, loader, lr=0.05, gossip_every=0, seed=0)
        iso.run(2)
        from repro.train.metrics import consensus_distance

        # complete graph with gossip contracts consensus to ~0; isolated doesn't
        loader2 = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=8, seed=0)
        mixed = DecentralizedTrainer(g, loader2, lr=0.05, gossip_every=1, seed=0)
        mixed.run(2)
        d_iso = float(np.asarray(consensus_distance(iso.params)).mean())
        d_mix = float(np.asarray(consensus_distance(mixed.params)).mean())
        assert d_mix < 1e-3  # complete-graph decavg averages everyone
        assert d_iso > 10 * max(d_mix, 1e-6)

    def test_auto_backend_resolves(self):
        from repro.core import partition as P
        from repro.data.loader import NodeLoader
        from repro.data.synthetic import make_mnist_like
        from repro.train.trainer import DecentralizedTrainer

        ds = make_mnist_like(train_per_class=20, test_per_class=10, seed=0)
        parts = P.iid(ds.y_train, 6, seed=0)
        loader = NodeLoader(ds.x_train, ds.y_train, parts, batch_size=8, seed=0)
        tr = DecentralizedTrainer("ring:n=6", loader, mix_impl="auto", seed=0)
        hist = tr.run(1, x_test=ds.x_test, y_test=ds.y_test)
        assert np.isfinite(hist[-1].mean_acc)
