# Tier-1 verification + common dev entry points.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: verify test bench-mixing bench quickstart install

verify:  ## tier-1 test suite (the CI gate)
	$(PY) -m pytest -x -q

test: verify

install:  ## editable install with test extras (hypothesis, networkx)
	$(PY) -m pip install -e ".[test]"

bench-mixing:  ## dense vs sparse gossip sweep -> BENCH_mixing.json
	$(PY) benchmarks/bench_mixing.py

bench:  ## quick paper-figure benchmark harness
	$(PY) benchmarks/run.py

quickstart:
	$(PY) examples/quickstart.py
