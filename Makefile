# Tier-1 verification + common dev entry points.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: verify test coverage lint bench-mixing bench-wire bench-rounds bench-lm-rounds bench-serve bench quickstart install sweep-smoke sweep-paper sweep-churn-smoke sweep-lm-smoke

verify:  ## tier-1 test suite (the CI gate)
	$(PY) -m pytest -x -q

lint:  ## ruff baseline (when installed) + repro.lint repo rules
	@if $(PY) -c "import ruff" >/dev/null 2>&1; then \
	    $(PY) -m ruff check .; \
	else \
	    echo "ruff not installed; skipping the ruff baseline"; \
	fi
	$(PY) -m repro.lint src

coverage:  ## tier-1 with line coverage gated on the mixing core + kernels
	$(PY) -m pytest -q --cov=repro.core --cov=repro.kernels \
	    --cov-report=term-missing --cov-fail-under=85

sweep-smoke:  ## 3-family smoke sweep (minutes, CPU) -> results/ + BENCH_sweep.json
	$(PY) -m repro.experiments.sweep --preset smoke \
	    --store results/sweep_smoke.jsonl --bench-out BENCH_sweep.json

sweep-large-n-smoke:  ## tiny-N large_n stand-in: fused sparse_sharded end to end
	$(PY) -m repro.experiments.sweep --preset large_n_smoke \
	    --store results/sweep_large_n_smoke.jsonl \
	    --bench-out BENCH_large_n_smoke.json

sweep-churn-smoke:  ## hub-kill vs leaf-kill churn gate (faults subsystem)
	$(PY) -m repro.experiments.sweep --preset churn_smoke \
	    --store results/sweep_churn_smoke.jsonl \
	    --bench-out BENCH_churn_smoke.json

sweep-lm-smoke:  ## LLM-cohort gate: ring/star gossip beats isolation on g2_token_spread
	$(PY) -m repro.experiments.sweep --preset lm_smoke \
	    --store results/sweep_lm_smoke.jsonl \
	    --bench-out BENCH_lm_smoke.json

sweep-paper:  ## the paper's N=100 matrix (ER/BA/SBM x splits x 3 seeds)
	$(PY) -m repro.experiments.sweep --preset paper \
	    --store results/sweep_paper.jsonl --bench-out BENCH_sweep.json

test: verify

install:  ## editable install with test extras (hypothesis, networkx)
	$(PY) -m pip install -e ".[test]"

bench-mixing:  ## dense vs sparse gossip sweep + halo wire volumes -> BENCH_mixing.json
	$(PY) benchmarks/bench_mixing.py

bench-wire:  ## wire-volume model only (allgather vs ring halo, S=8, fast)
	$(PY) benchmarks/bench_mixing.py --sizes "" --out BENCH_mixing_wire.json

bench-rounds:  ## fused (one lax.scan) vs Python-loop rounds/s -> BENCH_rounds.json
	$(PY) benchmarks/bench_rounds.py

bench-lm-rounds:  ## fused vs loop LM cohort rounds/s -> BENCH_lm_rounds.json
	$(PY) benchmarks/bench_lm_rounds.py

bench-serve:  ## chunked prefill + engine identity + routing delta -> BENCH_serve.json
	$(PY) benchmarks/bench_serve.py

bench:  ## quick paper-figure benchmark harness
	$(PY) benchmarks/run.py

quickstart:
	$(PY) examples/quickstart.py
